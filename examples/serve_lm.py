"""Serving example: batched prefill + decode with the cuSZ-compressed
(int8, error-bounded) KV cache, comparing outputs and cache footprint
against the bf16 cache.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve.engine import ServeConfig, generate

ARCH = "qwen2.5-3b"          # reduced same-family config for CPU


def cache_bytes(caches):
    total = 0
    for leaf in jax.tree.leaves(caches):
        total += leaf.size * leaf.dtype.itemsize
    return total


def main():
    cfg = configs.reduced(ARCH, n_periods=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S, NEW = 4, 32, 24
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))

    out = {}
    for name, compressed in (("bf16", False), ("cusz-int8", True)):
        scfg = ServeConfig(s_max=128, compressed_kv=compressed,
                           temperature=0.0)
        toks = generate(params, cfg, prompt, NEW, scfg)
        caches = M.init_caches(cfg, B, scfg.s_max, compressed_kv=compressed)
        out[name] = (np.asarray(toks), cache_bytes(caches))
        print(f"[{name:9s}] cache={cache_bytes(caches) / 1e3:8.1f} kB  "
              f"first-seq tokens: {np.asarray(toks)[0][:12].tolist()}")

    agree = float((out["bf16"][0] == out["cusz-int8"][0]).mean())
    print(f"greedy token agreement (bf16 vs compressed): {agree:.2%}")
    print(f"cache footprint reduction: "
          f"{out['bf16'][1] / out['cusz-int8'][1]:.2f}x")


if __name__ == "__main__":
    main()
