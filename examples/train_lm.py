"""End-to-end driver: train a ~100M-param dense LM on the synthetic
pipeline with cuSZ-compressed checkpointing and the full trainer loop
(NaN guard, straggler watchdog, restart).

    PYTHONPATH=src python examples/train_lm.py --steps 300

~100M params (d=512, 12 layers, 32k vocab).  --small switches to a ~6M
config for quick smoke runs.
"""
import argparse
import dataclasses

import numpy as np

from repro import configs
from repro.io.checkpoint import CheckpointPolicy
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, LoopConfig


def model_100m() -> ModelConfig:
    return ModelConfig(name="demo-100m", n_layers=12, d_model=512,
                       n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32000,
                       head_dim=64, pattern=("attn+mlp",), qk_norm=True)


def model_small() -> ModelConfig:
    return ModelConfig(name="demo-6m", n_layers=4, d_model=128, n_heads=4,
                       n_kv_heads=2, d_ff=512, vocab=4096, head_dim=32,
                       pattern=("attn+mlp",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    print(f"model {cfg.name}: ~{cfg.param_count() / 1e6:.0f}M params")
    tcfg = TrainConfig(microbatches=1, adamw=AdamWConfig(lr=1e-3))
    lcfg = LoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                      checkpoint_every=100, checkpoint_dir=args.ckpt_dir,
                      checkpoint_policy=CheckpointPolicy(codec="cusz",
                                                         eb_valrel=1e-5))
    tr = Trainer(cfg, tcfg, lcfg)
    hist = tr.run()
    losses = [h["loss"] for h in hist]
    k = max(1, len(losses) // 10)
    print(f"steps run          : {len(hist)}")
    print(f"loss first/last 10%: {np.mean(losses[:k]):.4f} -> "
          f"{np.mean(losses[-k:]):.4f}")
    print(f"straggler flags    : {len(tr.straggler.flagged)}")
    print(f"checkpoints under  : {args.ckpt_dir}")


if __name__ == "__main__":
    main()
