"""Checkpoint compression example: save a model's state losslessly and
with the cuSZ codec via the per-leaf `CheckpointPolicy`; compare sizes,
verify the error bound, and show the manifest's self-describing container
headers (the paper's compressor on the fault-tolerance write path).

    PYTHONPATH=src python examples/compress_checkpoint.py
"""
import glob
import json
import os
import shutil
import tempfile

import jax
import numpy as np

from repro import configs
from repro.io import checkpoint as CK
from repro.models import model as M
from repro.optim import adamw


def tree_bytes(d):
    return sum(os.path.getsize(p) for p in glob.glob(os.path.join(d, "*")))


def main():
    cfg = configs.reduced("qwen3-4b", n_periods=4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, adamw.AdamWConfig())
    state = (params, opt)

    base = tempfile.mkdtemp(prefix="repro_ckpt_")
    d0 = os.path.join(base, "lossless")
    os.makedirs(d0, exist_ok=True)
    CK.save_checkpoint(d0, 0, state,
                       policy=CK.CheckpointPolicy(codec="lossless"))
    raw = tree_bytes(os.path.join(d0, "step_00000000"))
    print(f"[lossless  ] {raw / 1e6:7.2f} MB")

    coded_entry = None
    for eb in (1e-3, 1e-5):
        d = os.path.join(base, f"cusz_{eb:g}")
        os.makedirs(d, exist_ok=True)
        CK.save_checkpoint(d, 0, state,
                           policy=CK.CheckpointPolicy(codec="cusz",
                                                      eb_valrel=eb))
        sz = tree_bytes(os.path.join(d, "step_00000000"))
        man = json.load(open(os.path.join(d, "step_00000000",
                                          "manifest.json")))
        coded = [t for t in man["tensors"].values()
                 if t.get("codec") == "cusz"]
        if coded_entry is None and coded:
            coded_entry = next((k, e) for k, e in man["tensors"].items()
                               if e["codec"] == "cusz")
        restored, _ = CK.load_checkpoint(d, state)
        worst = 0.0
        for (_, la), (_, lb) in zip(
                jax.tree_util.tree_flatten_with_path(state)[0],
                jax.tree_util.tree_flatten_with_path(restored)[0]):
            a, b = np.asarray(la), np.asarray(lb)
            if a.dtype == np.float32 and a.size:
                rng = a.max() - a.min()
                if rng > 0:
                    worst = max(worst, float(np.abs(a - b).max() / rng))
        print(f"[cusz eb={eb:5g}] {sz / 1e6:7.2f} MB  "
              f"reduction {raw / sz:4.2f}x  tensors coded {len(coded)} "
              f"(lossless-fallback {len(man['tensors']) - len(coded)})  "
              f"worst valrel err {worst:.2e} "
              f"({'HELD' if worst <= eb * 1.05 else 'VIOLATED'})")
    # every entry is a self-describing container: codec id + version +
    # header (dtype/shape/eb) — restore needs no caller-side metadata
    if coded_entry is not None:
        k, entry = coded_entry
        print(f"manifest[{k.split('::')[-1]}]: codec={entry['codec']} "
              f"v{entry['version']} header.dtype={entry['header']['dtype']} "
              f"eb={entry['header']['params']['eb']:.3e}")
    print("note: entropy-dense tensors (e.g. random init at tight eb) fall "
          "back to lossless — the codec never expands a checkpoint.")
    shutil.rmtree(base)


if __name__ == "__main__":
    main()
