"""Checkpoint compression example: save a model's state losslessly and
with the cuSZ codec via the per-leaf `CheckpointPolicy`; compare sizes,
verify the error bound, and show the manifest's self-describing container
headers (the paper's compressor on the fault-tolerance write path).

    PYTHONPATH=src python examples/compress_checkpoint.py
"""
import glob
import json
import os
import shutil
import tempfile

import jax
import numpy as np

from repro import configs
from repro.io import checkpoint as CK
from repro.models import model as M
from repro.optim import adamw


def tree_bytes(d):
    return sum(os.path.getsize(p) for p in glob.glob(os.path.join(d, "*")))


def main():
    cfg = configs.reduced("qwen3-4b", n_periods=4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, adamw.AdamWConfig())
    state = (params, opt)

    base = tempfile.mkdtemp(prefix="repro_ckpt_")
    d0 = os.path.join(base, "lossless")
    os.makedirs(d0, exist_ok=True)
    CK.save_checkpoint(d0, 0, state,
                       policy=CK.CheckpointPolicy(codec="lossless"))
    raw = tree_bytes(os.path.join(d0, "step_00000000"))
    print(f"[lossless  ] {raw / 1e6:7.2f} MB")

    coded_entry = None
    for eb in (1e-3, 1e-5):
        d = os.path.join(base, f"cusz_{eb:g}")
        os.makedirs(d, exist_ok=True)
        CK.save_checkpoint(d, 0, state,
                           policy=CK.CheckpointPolicy(codec="cusz",
                                                      eb_valrel=eb))
        sz = tree_bytes(os.path.join(d, "step_00000000"))
        man = json.load(open(os.path.join(d, "step_00000000",
                                          "manifest.json")))
        coded = [t for t in man["tensors"].values()
                 if t.get("codec") == "cusz"]
        if coded_entry is None and coded:
            coded_entry = next((k, e) for k, e in man["tensors"].items()
                               if e["codec"] == "cusz")
        restored, _ = CK.load_checkpoint(d, state)
        worst = 0.0
        for (_, la), (_, lb) in zip(
                jax.tree_util.tree_flatten_with_path(state)[0],
                jax.tree_util.tree_flatten_with_path(restored)[0]):
            a, b = np.asarray(la), np.asarray(lb)
            if a.dtype == np.float32 and a.size:
                rng = a.max() - a.min()
                if rng > 0:
                    worst = max(worst, float(np.abs(a - b).max() / rng))
        print(f"[cusz eb={eb:5g}] {sz / 1e6:7.2f} MB  "
              f"reduction {raw / sz:4.2f}x  tensors coded {len(coded)} "
              f"(lossless-fallback {len(man['tensors']) - len(coded)})  "
              f"worst valrel err {worst:.2e} "
              f"({'HELD' if worst <= eb * 1.05 else 'VIOLATED'})")
    # every entry is a self-describing container: codec id + version +
    # per-shard headers (dtype/shape/eb) — restore needs no caller metadata
    if coded_entry is not None:
        k, entry = coded_entry
        hdr = entry["shards"][0]["header"]
        print(f"manifest[{k.split('::')[-1]}]: codec={entry['codec']} "
              f"v{entry['version']} header.dtype={hdr['dtype']} "
              f"eb={hdr['params']['eb']:.3e}")
    print("note: entropy-dense tensors (e.g. random init at tight eb) fall "
          "back to lossless — the codec never expands a checkpoint.")

    # sharded + async: one shard file per host, write overlapped with the
    # caller via a bounded AsyncWriter, committed atomically (manifest v3)
    d4 = os.path.join(base, "sharded_async")
    os.makedirs(d4, exist_ok=True)
    with CK.AsyncWriter(max_pending=1) as w:
        CK.save_checkpoint(d4, 0, state, nshards=4, writer=w,
                           policy=CK.CheckpointPolicy(codec="cusz",
                                                      eb_valrel=1e-3))
        w.wait()                       # barrier; re-raises write failures
    step_dir = os.path.join(d4, "step_00000000")
    man = json.load(open(os.path.join(step_dir, "manifest.json")))
    sizes = {f: os.path.getsize(os.path.join(step_dir, f))
             for f in sorted(os.listdir(step_dir)) if f.startswith("shard_")}
    split = sum(1 for t in man["tensors"].values() if t["axis"] is not None)
    print(f"[sharded x{man['nshards']}] "
          + "  ".join(f"{f}={s / 1e6:.2f}MB" for f, s in sizes.items()))
    print(f"manifest v{man['format']}: {split} split tensors, "
          f"{len(man['tensors']) - split} owner-assigned "
          f"(cusz leaves stay whole — chunked prediction isn't "
          f"split-stable); elastic restore reassembles from any host count")
    # elastic restore onto this host's mesh: split-stable leaves decode
    # jitted on-device — the host->device move carries the stored
    # containers (int8 q + scales / raw), not decoded f32
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state)
    restored, _ = CK.load_checkpoint(d4, state, shardings=shardings)
    print(f"elastic reload OK from {man['nshards']} shards "
          f"(stats: {CK.LAST_RESTORE_STATS['wire_leaves']} container-moved "
          f"leaves, {CK.LAST_RESTORE_STATS['wire_bytes'] / 1e6:.2f}MB wire)")
    shutil.rmtree(base)


if __name__ == "__main__":
    main()
