"""Quickstart: error-bounded compression of a scientific field (the
paper's core use case) in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import compressor as C, metrics as M
from repro.data import scidata

# a Hurricane-Isabel-like 3D field (synthetic SDRBench stand-in)
field = jnp.asarray(scidata.hurricane_like((25, 125, 125)))

# compress at the paper's headline setting: value-range-relative 1e-4
cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
recon, blob, eb, ratio = C.roundtrip(field, cfg)

print(f"field             : {field.shape} float32 "
      f"({field.size * 4 / 1e6:.1f} MB)")
print(f"error bound (abs) : {eb:.3e}")
print(f"compression ratio : {ratio:.2f}x "
      f"({C.compressed_bytes(blob, cfg.nbins) / 1e6:.2f} MB)")
print(f"PSNR              : {float(M.psnr(field, recon)):.1f} dB")
print(f"max |d - d'|      : {float(M.max_abs_err(field, recon)):.3e}")
print(f"bound held        : {M.verify_error_bound(field, recon, eb)}")
print(f"outliers          : {int(blob.n_outliers)} "
      f"(capacity {blob.out_idx.shape[0]})")
