"""Quickstart: error-bounded compression of a scientific field (the
paper's core use case) through the unified codec API in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro import codecs
from repro.core import metrics as M
from repro.data import scidata

# a Hurricane-Isabel-like 3D field (synthetic SDRBench stand-in)
field = jnp.asarray(scidata.hurricane_like((25, 125, 125)))

# compress at the paper's headline setting: value-range-relative 1e-4.
# The returned Container is self-describing: codec id, resolved abs eb,
# dtype and shape all ride in its header.
codec = codecs.get("cusz", eb=1e-4, eb_mode="valrel")
container = codec.encode(field)
recon = codecs.decode(container)          # nothing else needed

eb = container.header.param("eb")
nbytes = codec.stored_nbytes(container)
print(f"field             : {field.shape} float32 "
      f"({field.size * 4 / 1e6:.1f} MB)")
print(f"container         : {container}")
print(f"error bound (abs) : {eb:.3e}")
print(f"compression ratio : {field.nbytes / nbytes:.2f}x "
      f"({nbytes / 1e6:.2f} MB)")
print(f"PSNR              : {float(M.psnr(field, recon)):.1f} dB")
print(f"max |d - d'|      : {float(M.max_abs_err(field, recon)):.3e}")
print(f"bound held        : {M.verify_error_bound(field, recon, eb)}")

# the same contract runs every codec in the registry
for name in ("int8", "zfp"):
    c = codecs.get(name).encode(field)
    r = codecs.decode(c)
    print(f"{name:18}: ratio "
          f"{field.nbytes / codecs.get(name).stored_nbytes(c):5.2f}x  "
          f"PSNR {float(M.psnr(field, r)):6.1f} dB")
