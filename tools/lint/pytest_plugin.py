"""Pytest fixtures bridging the static waiver layer to the runtime guards.

Loaded from the root ``tests/conftest.py`` via ``pytest_plugins``.  The
statically waived ``allow[host-sync]`` statement spans become the
runtime allowlist, so a sync is legal at runtime exactly where the
linter was told it is legal in the source.
"""
from __future__ import annotations

import os

import pytest

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", ".."))
_SRC_REPRO = os.path.join(_REPO_ROOT, "src", "repro")


@pytest.fixture(scope="session")
def lint_waived_sites():
    """{abs path: [(start, end, reason)]} of allow[host-sync] waivers."""
    from tools.lint import waived_spans

    return waived_spans(_SRC_REPRO)


@pytest.fixture
def host_sync_sanitizer(lint_waived_sites):
    """Factory: ``with host_sync_sanitizer() as log: ...`` fails the test
    on any repro-code sync outside the statically waived sites."""
    from repro.debug import host_sync_guard

    def make(**kwargs):
        return host_sync_guard(lint_waived_sites, **kwargs)

    return make


@pytest.fixture
def recompile_guard():
    """The `no_recompiles` context manager (budgeted compile counting)."""
    from repro.debug import no_recompiles

    return no_recompiles


@pytest.fixture
def transfer_sanitizer():
    """The `no_implicit_transfers` context manager."""
    from repro.debug import no_implicit_transfers

    return no_implicit_transfers
