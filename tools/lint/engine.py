"""Rule engine: file walking, module indexing, waiver pragmas, reporting.

The engine parses every ``.py`` file under the given roots into a
`ModuleInfo` (AST + import map + function table), links them into an
`Index` with a best-effort cross-module call graph, computes the set of
functions reachable from a ``jax.jit`` / ``pallas_call`` region, runs
each rule over the index, and applies waiver pragmas to the findings.

Everything here is static: the linted code is never imported, so the
linter runs in a bare environment and cannot be fooled by import-time
side effects.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

REPORT_VERSION = 1

#: waiver categories (the `allow[...]` tags) by rule id
CATEGORIES = {
    "R1-host-sync": "host-sync",
    "R2-jit-cache": "jit-cache",
    "R3-codec-registry": "codec-registry",
    "R4-kernel-dispatch": "kernel-dispatch",
    "R5-tracer-branch": "tracer-branch",
}

_PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*allow\[([a-z0-9_, -]+)\]\s*(.*?)\s*$")


# ---------------------------------------------------------------------------
# Data model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Waiver:
    category: str
    reason: str
    pragma_line: int
    span: Tuple[int, int]           # statement lines covered (inclusive)

    def covers(self, line: int) -> bool:
        return self.span[0] <= line <= self.span[1]


@dataclasses.dataclass
class Finding:
    rule: str
    path: str                       # as-given (relative) path
    line: int
    col: int
    message: str
    waived: bool = False
    waiver_reason: Optional[str] = None

    @property
    def category(self) -> str:
        return CATEGORIES.get(self.rule, self.rule)

    def to_json(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "waived": self.waived, "waiver_reason": self.waiver_reason}

    def __str__(self) -> str:
        tag = " [waived: %s]" % self.waiver_reason if self.waived else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}: "
                f"{self.message}{tag}")


@dataclasses.dataclass
class FunctionInfo:
    module: "ModuleInfo"
    qualname: str                   # "f", "Class.m", "outer.inner"
    node: ast.AST                   # FunctionDef / AsyncFunctionDef
    parent_class: Optional[str] = None
    jit_root: bool = False
    jit_reachable: bool = False
    static_params: Tuple[str, ...] = ()

    @property
    def key(self) -> Tuple[str, str]:
        return (self.module.modname, self.qualname)


class ModuleInfo:
    def __init__(self, path: str, modname: str, source: str):
        self.path = path
        self.modname = modname
        self.source = source
        self.lines = source.splitlines()
        self.tree: Optional[ast.Module] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(source)
        except SyntaxError as e:
            self.parse_error = e
            return
        self.parents = _parent_map(self.tree)
        # import maps
        self.imports: Dict[str, str] = {}       # alias -> dotted module
        self.from_names: Dict[str, Tuple[str, str]] = {}  # name -> (mod, attr)
        self._collect_imports()
        # function table (module-level, class methods, one level of nesting)
        self.functions: Dict[str, FunctionInfo] = {}
        self._collect_functions()
        self.waivers: List[Waiver] = _parse_waivers(self)

    # -- imports ------------------------------------------------------------
    def _rel_base(self, level: int) -> str:
        """Package that a `from ...` import of `level` dots resolves in."""
        parts = self.modname.split(".")
        # the module's own package drops the trailing module name; each
        # additional dot beyond the first climbs one more package
        keep = len(parts) - level
        return ".".join(parts[:max(keep, 0)])

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
                    if a.asname:
                        self.imports[a.asname] = a.name
            elif isinstance(node, ast.ImportFrom):
                base = (node.module or "")
                if node.level:
                    rel = self._rel_base(node.level)
                    base = f"{rel}.{base}" if base else rel
                for a in node.names:
                    local = a.asname or a.name
                    # `from X import y`: y may be a submodule or a name;
                    # record both views and let resolution pick
                    self.imports.setdefault(local, f"{base}.{a.name}"
                                            if base else a.name)
                    self.from_names[local] = (base, a.name)

    # -- functions ----------------------------------------------------------
    def _collect_functions(self) -> None:
        def visit(body, prefix, parent_class):
            for node in body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{prefix}{node.name}"
                    self.functions[q] = FunctionInfo(
                        self, q, node, parent_class=parent_class)
                    visit(node.body, f"{q}.", parent_class)
                elif isinstance(node, ast.ClassDef):
                    visit(node.body, f"{node.name}.", node.name)
        visit(self.tree.body, "", None)

    def enclosing_function(self, node: ast.AST) -> Optional[FunctionInfo]:
        cur = node
        while cur is not None:
            cur = self.parents.get(cur)
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for fi in self.functions.values():
                    if fi.node is cur:
                        return fi
        return None


def _parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _parse_waivers(mod: ModuleInfo) -> List[Waiver]:
    """Attach each `# repro-lint: allow[...]` pragma to a statement span.

    Trailing pragma -> the innermost statement on that line (a pragma on
    a `def` line covers the whole function); comment-only line -> the
    next statement below it.
    """
    stmts = [n for n in ast.walk(mod.tree)
             if isinstance(n, ast.stmt) and hasattr(n, "end_lineno")]
    out: List[Waiver] = []
    for i, text in enumerate(mod.lines, start=1):
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        cats = [c.strip() for c in m.group(1).split(",") if c.strip()]
        reason = m.group(2).strip()
        comment_only = text.strip().startswith("#")
        if comment_only:
            below = [s for s in stmts if s.lineno > i]
            target = min(below, key=lambda s: s.lineno) if below else None
        else:
            containing = [s for s in stmts
                          if s.lineno <= i <= s.end_lineno]
            target = (max(containing, key=lambda s: s.lineno)
                      if containing else None)
        span = (target.lineno, target.end_lineno) if target is not None \
            else (i, i)
        for c in cats:
            out.append(Waiver(c, reason, i, span))
    return out


# ---------------------------------------------------------------------------
# Index: cross-module resolution + jit reachability
# ---------------------------------------------------------------------------

#: attribute roots treated as the jax / numpy namespaces after alias
#: normalization (``import jax.numpy as jnp`` -> "jax.numpy")
JAX_JIT_CHAINS = {"jax.jit", "jit"}
PALLAS_CALL_SUFFIX = "pallas_call"


class Index:
    def __init__(self, modules: Sequence[ModuleInfo]):
        self.modules = [m for m in modules if m.tree is not None]
        self.by_name: Dict[str, ModuleInfo] = {m.modname: m
                                               for m in self.modules}
        self._mark_jit_roots()
        self._propagate_reachability()

    # -- name / chain resolution -------------------------------------------
    def attr_chain(self, mod: ModuleInfo, node: ast.AST) -> Optional[str]:
        """Dotted name of an expression ("jnp.asarray" -> "jax.numpy.asarray"
        after alias normalization), or None for non-name expressions."""
        parts: List[str] = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            return None
        parts.append(cur.id)
        parts.reverse()
        root = parts[0]
        if root in mod.imports:
            parts[0] = mod.imports[root]
        elif root in mod.from_names:
            base, orig = mod.from_names[root]
            parts[0] = f"{base}.{orig}" if base else orig
        return ".".join(parts)

    def find_module(self, dotted: str) -> Optional[ModuleInfo]:
        if dotted in self.by_name:
            return self.by_name[dotted]
        # suffix match lets fixture trees resolve without a package root
        tail = "." + dotted
        hits = [m for n, m in self.by_name.items() if n.endswith(tail)]
        return hits[0] if len(hits) == 1 else None

    def resolve_call(self, mod: ModuleInfo, scope: Optional[FunctionInfo],
                     func: ast.AST) -> Optional[FunctionInfo]:
        """Best-effort: the FunctionInfo a call expression refers to."""
        if isinstance(func, ast.Name):
            name = func.id
            if scope is not None:                      # inner def
                inner = mod.functions.get(f"{scope.qualname}.{name}")
                if inner is not None:
                    return inner
            if name in mod.functions:
                return mod.functions[name]
            if scope is not None and scope.parent_class:
                meth = mod.functions.get(f"{scope.parent_class}.{name}")
                if meth is not None:
                    return meth
            if name in mod.from_names:
                base, orig = mod.from_names[name]
                target = self.find_module(base) if base else None
                if target is not None and orig in target.functions:
                    return target.functions[orig]
        elif isinstance(func, ast.Attribute):
            base = func.value
            if isinstance(base, ast.Name):
                if base.id == "self" and scope is not None \
                        and scope.parent_class:
                    meth = mod.functions.get(
                        f"{scope.parent_class}.{func.attr}")
                    if meth is not None:
                        return meth
                dotted = mod.imports.get(base.id)
                if dotted is None and base.id in mod.from_names:
                    b, o = mod.from_names[base.id]
                    dotted = f"{b}.{o}" if b else o
                if dotted is not None:
                    target = self.find_module(dotted)
                    if target is not None:
                        return target.functions.get(func.attr)
        return None

    # -- jit roots ----------------------------------------------------------
    def _decorator_static_names(self, mod: ModuleInfo,
                                deco: ast.AST) -> Tuple[str, ...]:
        """static_argnames of a partial(jax.jit, ...) / jax.jit(...) deco."""
        if not isinstance(deco, ast.Call):
            return ()
        for kw in deco.keywords:
            if kw.arg == "static_argnames":
                names = []
                for n in ast.walk(kw.value):
                    if isinstance(n, ast.Constant) and isinstance(n.value,
                                                                  str):
                        names.append(n.value)
                return tuple(names)
        return ()

    def _is_jit_decorator(self, mod: ModuleInfo, deco: ast.AST) -> bool:
        chain = self.attr_chain(mod, deco)
        if chain in JAX_JIT_CHAINS:
            return True
        if isinstance(deco, ast.Call):
            fchain = self.attr_chain(mod, deco.func)
            if fchain in JAX_JIT_CHAINS:
                return True
            if fchain in ("functools.partial", "partial") and deco.args:
                return self.attr_chain(mod, deco.args[0]) in JAX_JIT_CHAINS
        return False

    def _inner_defs(self, fi: FunctionInfo) -> List[FunctionInfo]:
        prefix = fi.qualname + "."
        return [f for q, f in fi.module.functions.items()
                if q.startswith(prefix)]

    def _mark_root(self, fi: FunctionInfo,
                   static_names: Tuple[str, ...] = ()) -> None:
        fi.jit_root = True
        fi.jit_reachable = True
        if static_names:
            fi.static_params = tuple(sorted(set(fi.static_params)
                                            | set(static_names)))

    def _mark_jit_roots(self) -> None:
        for mod in self.modules:
            # decorated roots
            for fi in mod.functions.values():
                for deco in getattr(fi.node, "decorator_list", []):
                    if self._is_jit_decorator(mod, deco):
                        self._mark_root(
                            fi, self._decorator_static_names(mod, deco))
                    chain = self.attr_chain(
                        mod, deco.func if isinstance(deco, ast.Call)
                        else deco)
                    if chain and chain.endswith(PALLAS_CALL_SUFFIX):
                        self._mark_root(fi)
            # call-site roots: jax.jit(f) / jax.jit(factory(...)) /
            # pallas_call(kernel_fn, ...)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call) or not node.args:
                    continue
                fchain = self.attr_chain(mod, node.func)
                is_jit = fchain in JAX_JIT_CHAINS
                is_pallas = bool(fchain) and fchain.endswith(
                    PALLAS_CALL_SUFFIX)
                if not (is_jit or is_pallas):
                    continue
                statics = self._decorator_static_names(mod, node)
                scope = mod.enclosing_function(node)
                arg0 = node.args[0]
                target = None
                if isinstance(arg0, (ast.Name, ast.Attribute)):
                    target = self.resolve_call(mod, scope, arg0)
                    if target is not None:
                        self._mark_root(target, statics)
                elif isinstance(arg0, ast.Call):
                    # jax.jit(make_step(cfg)): the jitted fn is the
                    # factory's closure — mark the factory's inner defs
                    factory = self.resolve_call(mod, scope, arg0.func)
                    if factory is not None:
                        inner = self._inner_defs(factory)
                        for f in (inner or [factory]):
                            self._mark_root(f, statics)

    # -- reachability -------------------------------------------------------
    def calls_of(self, fi: FunctionInfo) -> List[FunctionInfo]:
        """Resolved callees of `fi`'s own body (nested defs excluded —
        they are separate nodes in the graph; lambdas included)."""
        out = []
        skip: Set[ast.AST] = set()
        for node in ast.walk(fi.node):
            if node is not fi.node and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                skip.update(ast.walk(node))
        for node in ast.walk(fi.node):
            if node in skip or not isinstance(node, ast.Call):
                continue
            target = self.resolve_call(fi.module, fi, node.func)
            if target is not None:
                out.append(target)
        return out

    def _propagate_reachability(self) -> None:
        frontier = [fi for mod in self.modules
                    for fi in mod.functions.values() if fi.jit_root]
        seen: Set[Tuple[str, str]] = {fi.key for fi in frontier}
        while frontier:
            fi = frontier.pop()
            for callee in self.calls_of(fi):
                if callee.key not in seen:
                    seen.add(callee.key)
                    callee.jit_reachable = True
                    frontier.append(callee)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _iter_py_files(paths: Iterable[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            files.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames)
                             if f.endswith(".py"))
    return sorted(set(files))


def _modname_for(path: str, roots: Sequence[str]) -> str:
    """Dotted module name relative to the scan root (src/ stripped)."""
    norm = path.replace(os.sep, "/")
    best = ""
    for r in roots:
        rn = r.rstrip("/").replace(os.sep, "/")
        if os.path.isfile(rn):
            rn = os.path.dirname(rn)
        if rn and (norm == rn or norm.startswith(rn + "/")):
            if len(rn) > len(best):
                best = rn
    rel = norm[len(best):].lstrip("/") if best else norm
    if rel.startswith("src/"):
        rel = rel[4:]
    rel = rel[:-3] if rel.endswith(".py") else rel
    if rel.endswith("/__init__"):
        rel = rel[:-len("/__init__")]
    return rel.replace("/", ".")


def build_index(paths: Sequence[str]) -> Index:
    mods = []
    for f in _iter_py_files(paths):
        with open(f, "r", encoding="utf-8") as fh:
            src = fh.read()
        mods.append(ModuleInfo(f, _modname_for(f, paths), src))
    return Index(mods)


@dataclasses.dataclass
class Report:
    findings: List[Finding]
    roots: List[str]
    rules: List[str]

    @property
    def unwaived(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived]

    def to_json(self) -> Dict:
        fs = sorted(self.findings, key=lambda f: (f.path, f.line, f.rule))
        return {"version": REPORT_VERSION, "roots": list(self.roots),
                "rules": sorted(self.rules),
                "counts": {"total": len(fs),
                           "waived": sum(f.waived for f in fs),
                           "unwaived": len(self.unwaived)},
                "findings": [f.to_json() for f in fs]}

    def dump(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")


def _apply_waivers(index: Index, findings: List[Finding]) -> None:
    by_path = {m.path: m for m in index.modules}
    for f in findings:
        mod = by_path.get(f.path)
        if mod is None:
            continue
        for w in mod.waivers:
            if w.category == f.category and w.covers(f.line):
                f.waived = True
                f.waiver_reason = w.reason or "(no reason given)"
                break


def lint_paths(paths: Sequence[str],
               rules: Optional[Sequence[str]] = None) -> Report:
    """Run the rule set over `paths`, returning a `Report` with waivers
    applied.  `rules` filters by rule id ("R1-host-sync") or short
    prefix ("R1")."""
    from .rules import all_rules

    index = build_index(paths)
    selected = all_rules()
    if rules:
        want = {r.lower() for r in rules}
        selected = [r for r in selected
                    if r.RULE_ID.lower() in want
                    or r.RULE_ID.split("-")[0].lower() in want]
    findings: List[Finding] = []
    for mod in index.modules:
        if mod.parse_error is not None:
            findings.append(Finding(
                "parse-error", mod.path, mod.parse_error.lineno or 1, 0,
                f"syntax error: {mod.parse_error.msg}"))
    for rule in selected:
        findings.extend(rule.run(index))
    # orphan-waiver check: a pragma that waives nothing is stale
    by_path: Dict[str, List[Finding]] = {}
    for f in findings:
        by_path.setdefault(f.path, []).append(f)
    _apply_waivers(index, findings)
    for mod in index.modules:
        for w in mod.waivers:
            if w.category not in CATEGORIES.values():
                findings.append(Finding(
                    "waiver-error", mod.path, w.pragma_line, 0,
                    f"unknown waiver category {w.category!r}; known: "
                    f"{sorted(CATEGORIES.values())}"))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(findings, list(paths), [r.RULE_ID for r in selected])


# ---------------------------------------------------------------------------
# Runtime bridge: waived host-sync sites for the pytest sanitizers
# ---------------------------------------------------------------------------

def waived_spans(root: str, category: str = "host-sync"
                 ) -> Dict[str, List[Tuple[int, int, str]]]:
    """{absolute file path: [(start_line, end_line, reason), ...]} of every
    `category` waiver under `root`.  The runtime host-sync sanitizer uses
    this to allow syncs originating from statically waived statements."""
    out: Dict[str, List[Tuple[int, int, str]]] = {}
    for f in _iter_py_files([root]):
        with open(f, "r", encoding="utf-8") as fh:
            src = fh.read()
        mod = ModuleInfo(f, _modname_for(f, [root]), src)
        if mod.tree is None:
            continue
        spans = [(w.span[0], w.span[1], w.reason) for w in mod.waivers
                 if w.category == category]
        if spans:
            out[os.path.abspath(f)] = spans
    return out
