"""CLI: ``python -m tools.lint src/ [--json report.json] [--rules R1,R2]``.

Exit code 0 when every finding is waived (or none exist), 1 otherwise.
"""
from __future__ import annotations

import argparse
import sys

from .engine import lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST-based static analysis for the repro JAX stack")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the machine-readable findings report here")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids or prefixes "
                         "(e.g. R1,R4-kernel-dispatch); default: all")
    ap.add_argument("--include-waived", action="store_true",
                    help="also print waived findings")
    args = ap.parse_args(argv)

    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    report = lint_paths(args.paths, rules=rules)
    if args.json:
        report.dump(args.json)

    shown = (report.findings if args.include_waived else report.unwaived)
    for f in shown:
        print(f)
    n_waived = sum(f.waived for f in report.findings)
    print(f"repro-lint: {len(report.findings)} finding(s), "
          f"{n_waived} waived, {len(report.unwaived)} unwaived "
          f"({len(report.rules)} rules)", file=sys.stderr)
    return 1 if report.unwaived else 0


if __name__ == "__main__":
    sys.exit(main())
