"""R1: no host-sync calls in device-side code.

Two tiers:

* Inside functions **reachable from a jit/pallas region**, every sync
  form is flagged: ``jax.device_get``, ``.item()``,
  ``.block_until_ready()``, ``np.asarray``/``np.array`` on anything, and
  ``float()``/``int()``/``bool()`` on values tainted as traced arrays.
  A sync here either breaks tracing outright or silently forces a
  device round-trip per call.
* On **host paths** (everything else), only the *blocking* forms are
  flagged — ``jax.device_get``, ``.block_until_ready()``, ``.item()``,
  and ``float()/int()/bool()`` on tainted locals.  These are legal but
  each one is a pipeline stall, so intentional ones must carry a
  ``# repro-lint: allow[host-sync] <reason>`` waiver.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import Finding, Index
from ._taint import arrayish, own_nodes, tainted_names

RULE_ID = "R1-host-sync"
CATEGORY = "host-sync"

_BLOCKING_CHAINS = {"jax.device_get", "jax.block_until_ready"}
_NUMPY_PULL_CHAINS = {"numpy.asarray", "numpy.array"}
_CAST_BUILTINS = {"float", "int", "bool", "complex"}


def _sync_form(index, mod, call: ast.Call, tainted, *, jit_side: bool):
    """Return a description of the sync this call performs, or None."""
    chain = index.attr_chain(mod, call.func)
    if chain in _BLOCKING_CHAINS:
        return f"`{chain}`"
    if isinstance(call.func, ast.Attribute):
        if call.func.attr == "block_until_ready":
            return "`.block_until_ready()`"
        if call.func.attr == "item" and not call.args and not call.keywords:
            return "`.item()`"
    if jit_side and chain in _NUMPY_PULL_CHAINS:
        return f"`{chain}` (device->host pull)"
    if (isinstance(call.func, ast.Name)
            and call.func.id in _CAST_BUILTINS
            and len(call.args) == 1 and not call.keywords
            and arrayish(index, mod, call.args[0], tainted)):
        return f"`{call.func.id}()` on a traced/device value"
    return None


def run(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules:
        scopes = [(fi, own_nodes(fi.node)) for fi in mod.functions.values()]
        scopes.append((None, own_nodes(mod.tree, into_classes=True)))
        for fi, nodes in scopes:
            jit_side = fi is not None and fi.jit_reachable
            tainted = (tainted_names(index, fi, taint_params=fi.jit_root)
                       if fi is not None else set())
            where = (f"jit-reachable function `{fi.qualname}`" if jit_side
                     else (f"host-path function `{fi.qualname}`"
                           if fi is not None else "module level"))
            for n in nodes:
                if not isinstance(n, ast.Call):
                    continue
                form = _sync_form(index, mod, n, tainted, jit_side=jit_side)
                if form is None:
                    continue
                kind = ("host sync" if jit_side else "blocking host sync")
                findings.append(Finding(
                    RULE_ID, mod.path, n.lineno, n.col_offset,
                    f"{kind} {form} in {where}"))
    return findings
