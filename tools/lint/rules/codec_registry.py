"""R3: codec- and stage-registry completeness.

Every ``register("<id>", factory)`` call in ``repro/codecs/`` must point
at a class that statically implements the `Codec` protocol:

* ``encode`` and ``decode`` defined (not inherited from the abstract
  `Codec` base, whose versions raise);
* the PR-4 sharded-encode surface — ``shard_axis`` **and**
  ``payload_axes`` overridden (``encode_parts`` may use the generic
  base loop) — **or** an explicit ``shardable = False`` class attribute
  opting the codec out of split-stable encode;
* header parameters passed to ``make_header`` / ``with_params`` /
  ``Header`` must be JSON-representable: no dict/set displays, lambdas
  or bytes literals (tuples are fine — they serialize as lists).

The staged pipeline's registries (``core.stages``) are held to the same
standard: every ``register_predictor("<id>", Factory)`` must resolve to
a class defining ``predict`` and ``reconstruct``, every
``register_encoder("<id>", Factory)`` to one defining ``encode`` and
``decode``, and both must declare a ``kernels`` tuple (the dispatch
keys R4 cross-checks against the kernels/<op>/ops.py registrations).
The abstract `Predictor`/`Encoder` bases do not satisfy the method
requirement — their versions raise.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from ..engine import Finding, Index, ModuleInfo

RULE_ID = "R3-codec-registry"
CATEGORY = "codec-registry"

_ABSTRACT_BASE = "Codec"
_HEADER_CALLS = {"make_header", "with_params", "Header"}

#: stage-registry calls -> the methods the factory class must define
_STAGE_CALLS = {"register_predictor": ("predict", "reconstruct"),
                "register_encoder": ("encode", "decode")}
#: abstract stage bases whose raising method stubs must not count
_STAGE_ABSTRACT = {"Predictor", "Encoder", _ABSTRACT_BASE}


def _class_defs(mod: ModuleInfo) -> Dict[str, ast.ClassDef]:
    return {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, ast.ClassDef)}


def _resolve_class(index: Index, mod: ModuleInfo,
                   name: str) -> Optional[ast.ClassDef]:
    cd = _class_defs(mod).get(name)
    if cd is not None:
        return cd
    if name in mod.from_names:
        base, orig = mod.from_names[name]
        target = index.find_module(base) if base else None
        if target is not None:
            return _class_defs(target).get(orig)
    return None


def _factory_class(index: Index, mod: ModuleInfo,
                   factory: ast.AST) -> Optional[str]:
    """Class name a register() factory constructs, best effort."""
    if isinstance(factory, ast.Lambda):
        body = factory.body
        if isinstance(body, ast.Call) and isinstance(body.func, ast.Name):
            return body.func.id
    if isinstance(factory, ast.Attribute) and isinstance(factory.value,
                                                         ast.Name):
        return factory.value.id           # CuszCodec.make
    if isinstance(factory, ast.Name):
        return factory.id
    return None


def _own_names(index: Index, mod: ModuleInfo, cd: ast.ClassDef,
               depth: int = 0, abstract=frozenset({_ABSTRACT_BASE})
               ) -> Dict[str, bool]:
    """{name: True} of methods/attrs defined on `cd` or a concrete
    ancestor (abstract bases, whose stubs raise, do not count)."""
    names: Dict[str, bool] = {}
    for n in cd.body:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names[n.name] = True
        elif isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    names[t.id] = True
        elif isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name):
            names[n.target.id] = True
    if depth < 4:
        for b in cd.bases:
            if isinstance(b, ast.Name) and b.id not in abstract:
                parent = _resolve_class(index, mod, b.id)
                if parent is not None:
                    for k in _own_names(index, mod, parent, depth + 1,
                                        abstract):
                        names.setdefault(k, True)
    return names


def _shardable_false(cd: ast.ClassDef) -> bool:
    for n in cd.body:
        val = None
        if isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "shardable"
                for t in n.targets):
            val = n.value
        elif (isinstance(n, ast.AnnAssign)
              and isinstance(n.target, ast.Name)
              and n.target.id == "shardable"):
            val = n.value
        if (isinstance(val, ast.Constant) and val.value is False):
            return True
    return False


def _json_scalar(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return not isinstance(node.value, bytes)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_json_scalar(e) for e in node.elts)
    if isinstance(node, (ast.Dict, ast.Set, ast.Lambda, ast.SetComp,
                         ast.DictComp)):
        return False
    return True        # names/calls/arith: not statically decidable


def _check_stage_registrations(index: Index, mod: ModuleInfo,
                               findings: List[Finding]) -> None:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) or len(node.args) < 2:
            continue
        fname = (node.func.attr if isinstance(node.func, ast.Attribute)
                 else node.func.id if isinstance(node.func, ast.Name)
                 else None)
        if fname not in _STAGE_CALLS:
            continue
        if not (isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            continue
        stage_id = node.args[0].value
        kind = "predictor" if fname == "register_predictor" else "encoder"
        cls_name = _factory_class(index, mod, node.args[1])
        cd = (_resolve_class(index, mod, cls_name)
              if cls_name is not None else None)
        if cd is None:
            findings.append(Finding(
                RULE_ID, mod.path, node.lineno, node.col_offset,
                f"{kind} stage `{stage_id}`: cannot statically resolve "
                "the factory to a class definition"))
            continue
        names = _own_names(index, mod, cd,
                           abstract=frozenset(_STAGE_ABSTRACT))
        for required in _STAGE_CALLS[fname]:
            if required not in names:
                findings.append(Finding(
                    RULE_ID, mod.path, cd.lineno, cd.col_offset,
                    f"{kind} stage `{stage_id}` ({cd.name}) does not "
                    f"define `{required}`"))
        if "kernels" not in names:
            findings.append(Finding(
                RULE_ID, mod.path, cd.lineno, cd.col_offset,
                f"{kind} stage `{stage_id}` ({cd.name}) does not declare "
                "a `kernels` tuple (the dispatch keys the stage resolves "
                "through the pipeline policy)"))


def run(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules:
        _check_stage_registrations(index, mod, findings)
        if "/codecs/" not in mod.path.replace("\\", "/"):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = (node.func.attr if isinstance(node.func, ast.Attribute)
                     else node.func.id if isinstance(node.func, ast.Name)
                     else None)
            # header-params JSON check applies to any codec-module call
            if fname in _HEADER_CALLS:
                for kw in node.keywords:
                    if kw.arg is not None and not _json_scalar(kw.value):
                        findings.append(Finding(
                            RULE_ID, mod.path, kw.value.lineno,
                            kw.value.col_offset,
                            f"header param `{kw.arg}` is not a JSON-scalar "
                            "type (dict/set/lambda/bytes values do not "
                            "survive the manifest round-trip)"))
            if fname != "register" or len(node.args) < 2:
                continue
            if not (isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            codec_id = node.args[0].value
            cls_name = _factory_class(index, mod, node.args[1])
            cd = (_resolve_class(index, mod, cls_name)
                  if cls_name is not None else None)
            if cd is None:
                findings.append(Finding(
                    RULE_ID, mod.path, node.lineno, node.col_offset,
                    f"codec `{codec_id}`: cannot statically resolve the "
                    "factory to a class definition"))
                continue
            names = _own_names(index, mod, cd)
            for required in ("encode", "decode"):
                if required not in names:
                    findings.append(Finding(
                        RULE_ID, mod.path, cd.lineno, cd.col_offset,
                        f"codec `{codec_id}` ({cd.name}) does not define "
                        f"`{required}`"))
            has_shard = "shard_axis" in names and "payload_axes" in names
            if not has_shard and not _shardable_false(cd):
                findings.append(Finding(
                    RULE_ID, mod.path, cd.lineno, cd.col_offset,
                    f"codec `{codec_id}` ({cd.name}) neither overrides the "
                    "sharded-encode surface (`shard_axis` + `payload_axes`)"
                    " nor opts out with `shardable = False`"))
    return findings
