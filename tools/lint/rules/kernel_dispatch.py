"""R4: kernel-dispatch completeness.

* Every ``kernels/<op>/`` directory that ships a ``kernel.py`` (a pallas
  implementation) must register ``"pallas"`` in its
  ``dispatch.register(...)`` call — a written-but-unregistered kernel is
  dead code the auto policy can never pick.
* Every op *without* a ``kernel.py`` must register ``impls=("jax",)``
  **and** pass an explicit ``jax_only_reason=...`` so
  ``resolve(impl="pallas")`` can raise an actionable error instead of
  silently using the reference path.
* Every stage named in ``dispatch.PIPELINE_STAGES`` must be registered
  by some ``ops.py`` — a stage the pipeline policy resolves but nothing
  registers fails at runtime.
* Every kernel key a predictor/encoder stage class declares in its
  ``kernels`` tuple (``core.stages`` registrations) must likewise be
  registered by some ``ops.py`` — a stage whose pipeline-policy lookup
  cannot resolve fails on first use.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Tuple

from ..engine import Finding, Index, ModuleInfo
from .codec_registry import _factory_class, _resolve_class

_STAGE_REGISTER_CALLS = ("register_predictor", "register_encoder")

RULE_ID = "R4-kernel-dispatch"
CATEGORY = "kernel-dispatch"


def _register_calls(mod: ModuleInfo) -> List[ast.Call]:
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = (node.func.attr if isinstance(node.func, ast.Attribute)
                 else node.func.id if isinstance(node.func, ast.Name)
                 else None)
        if fname == "register" and node.args \
                and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, str):
            out.append(node)
    return out


def _impls_of(call: ast.Call) -> Optional[Tuple[str, ...]]:
    expr = None
    if len(call.args) > 1:
        expr = call.args[1]
    for kw in call.keywords:
        if kw.arg == "impls":
            expr = kw.value
    if expr is None:
        return None                      # register() default
    if isinstance(expr, (ast.Tuple, ast.List)) and all(
            isinstance(e, ast.Constant) and isinstance(e.value, str)
            for e in expr.elts):
        return tuple(e.value for e in expr.elts)
    return None


def _jax_only_reason(call: ast.Call) -> Optional[str]:
    for kw in call.keywords:
        if kw.arg == "jax_only_reason":
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, str) and kw.value.value.strip():
                return kw.value.value
            return ""
    return None


def _class_kernels(cd: ast.ClassDef) -> Optional[Tuple[str, ...]]:
    """The literal `kernels = ("...", ...)` tuple of a stage class."""
    for n in cd.body:
        val = None
        if isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "kernels"
                for t in n.targets):
            val = n.value
        elif (isinstance(n, ast.AnnAssign)
              and isinstance(n.target, ast.Name)
              and n.target.id == "kernels"):
            val = n.value
        if isinstance(val, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in val.elts):
            return tuple(e.value for e in val.elts)
    return None


def _stage_kernel_decls(index: Index) -> List[Tuple[ModuleInfo, ast.Call,
                                                    str, Tuple[str, ...]]]:
    """(module, call, stage id, kernels tuple) per stage registration."""
    out = []
    for mod in index.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or len(node.args) < 2:
                continue
            fname = (node.func.attr
                     if isinstance(node.func, ast.Attribute)
                     else node.func.id if isinstance(node.func, ast.Name)
                     else None)
            if fname not in _STAGE_REGISTER_CALLS:
                continue
            if not (isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            cls_name = _factory_class(index, mod, node.args[1])
            cd = (_resolve_class(index, mod, cls_name)
                  if cls_name is not None else None)
            kernels = _class_kernels(cd) if cd is not None else None
            out.append((mod, node, node.args[0].value, kernels or ()))
    return out


def run(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    registered_names: Dict[str, str] = {}   # kernel name -> ops path
    dispatch_mod: Optional[ModuleInfo] = None
    for mod in index.modules:
        norm = mod.path.replace("\\", "/")
        if norm.endswith("kernels/dispatch.py"):
            dispatch_mod = mod
        if "/kernels/" not in norm or not norm.endswith("/ops.py"):
            continue
        op_dir = os.path.dirname(mod.path)
        has_kernel = os.path.exists(os.path.join(op_dir, "kernel.py"))
        calls = _register_calls(mod)
        if not calls:
            findings.append(Finding(
                RULE_ID, mod.path, 1, 0,
                "kernels ops module has no dispatch.register(...) call"))
            continue
        for call in calls:
            name = call.args[0].value
            registered_names[name] = mod.path
            impls = _impls_of(call)
            if has_kernel:
                if impls is None or "pallas" not in impls:
                    findings.append(Finding(
                        RULE_ID, mod.path, call.lineno, call.col_offset,
                        f"kernel `{name}` ships a kernel.py but does not "
                        "register a 'pallas' impl — the pallas path is "
                        "unreachable through dispatch"))
            else:
                if impls != ("jax",):
                    findings.append(Finding(
                        RULE_ID, mod.path, call.lineno, call.col_offset,
                        f"kernel `{name}` has no kernel.py; it must "
                        "register impls=('jax',) explicitly"))
                reason = _jax_only_reason(call)
                if reason is None or not reason.strip():
                    findings.append(Finding(
                        RULE_ID, mod.path, call.lineno, call.col_offset,
                        f"jax-only kernel `{name}` must declare "
                        "jax_only_reason=... so resolve(impl='pallas') "
                        "raises an actionable error"))
    if dispatch_mod is not None:
        for node in ast.walk(dispatch_mod.tree):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "PIPELINE_STAGES"
                    for t in node.targets):
                if isinstance(node.value, (ast.Tuple, ast.List)):
                    for e in node.value.elts:
                        if isinstance(e, ast.Constant) \
                                and isinstance(e.value, str) \
                                and e.value not in registered_names:
                            findings.append(Finding(
                                RULE_ID, dispatch_mod.path, e.lineno,
                                e.col_offset,
                                f"pipeline stage `{e.value}` is not "
                                "registered by any kernels/<op>/ops.py"))
    for mod, call, stage_id, kernels in _stage_kernel_decls(index):
        for kname in kernels:
            if kname not in registered_names:
                findings.append(Finding(
                    RULE_ID, mod.path, call.lineno, call.col_offset,
                    f"stage `{stage_id}` declares kernel `{kname}` that "
                    "no kernels/<op>/ops.py registers — the pipeline-"
                    "policy lookup fails on first use"))
    return findings
