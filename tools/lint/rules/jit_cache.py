"""R2: no `jax.jit` constructed inside a function body without a cache.

A `jax.jit(...)` built per call throws away the compilation cache every
time — the serve-path re-jit bug fixed by hand in PR 5, generalized.
Allowed shapes:

* module-level ``step = jax.jit(fn)``;
* any enclosing function carrying ``functools.lru_cache`` /
  ``functools.cache`` (the jit object is memoized with its key);
* assignment into a subscript, e.g. ``_cache[key] = jax.jit(fn)`` —
  the module-dict-cache idiom used by `io/checkpoint._jitted_decode`;
* ``self.attr = jax.jit(...)`` inside ``__init__`` (built once per
  object, e.g. `train/trainer.Trainer`).

Everything else needs a ``# repro-lint: allow[jit-cache] <reason>``.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import Finding, Index, JAX_JIT_CHAINS

RULE_ID = "R2-jit-cache"
CATEGORY = "jit-cache"

_CACHE_DECOS = {"functools.lru_cache", "lru_cache", "functools.cache",
                "cache"}


def _has_cache_decorator(index: Index, mod, node) -> bool:
    for deco in getattr(node, "decorator_list", []):
        target = deco.func if isinstance(deco, ast.Call) else deco
        if index.attr_chain(mod, target) in _CACHE_DECOS:
            return True
    return False


def _enclosing_stmt(mod, node: ast.AST):
    cur = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = mod.parents.get(cur)
    return cur


def run(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if index.attr_chain(mod, node.func) not in JAX_JIT_CHAINS:
                continue
            fi = mod.enclosing_function(node)
            if fi is None:
                continue                      # module level: fine
            # any cached ancestor function memoizes the jit object
            cached, cur = False, node
            while cur is not None:
                if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if _has_cache_decorator(index, mod, cur):
                        cached = True
                        break
                cur = mod.parents.get(cur)
            if cached:
                continue
            stmt = _enclosing_stmt(mod, node)
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                targets = [stmt.target]
            else:
                targets = []
            if any(isinstance(t, ast.Subscript) for t in targets):
                continue                      # dict-cache idiom
            if (fi.node.name == "__init__"
                    and any(isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self" for t in targets)):
                continue                      # built once per object
            findings.append(Finding(
                RULE_ID, mod.path, node.lineno, node.col_offset,
                f"`jax.jit` constructed inside `{fi.qualname}` without a "
                "cache (lru_cache / module-dict / self-attr-in-__init__); "
                "a fresh jit per call recompiles every time"))
    return findings
