"""Rule registry: each rule module exposes RULE_ID, CATEGORY, run(index)."""
from __future__ import annotations

from . import (codec_registry, host_sync, jit_cache, kernel_dispatch,
               tracer_control_flow)

_ALL = (host_sync, jit_cache, codec_registry, kernel_dispatch,
        tracer_control_flow)


def all_rules():
    return list(_ALL)
