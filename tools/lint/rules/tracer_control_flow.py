"""R5: no Python `if`/`while` on traced values inside jitted functions.

Python control flow runs at trace time: a branch on a tracer raises
`TracerBoolConversionError` at best, and at worst (via a cached
`.aval`-dependent path) silently bakes one branch into the compiled
program.  Inside a jit root, non-static parameters and everything
derived from the jax array namespaces are traced; branching on them
must go through `lax.cond` / `lax.while_loop` / `jnp.where`.

Branches on *static* arguments (``static_argnames``) are fine — that is
the standard impl-selection idiom in the kernels' ops wrappers.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import Finding, Index
from ._taint import arrayish, own_nodes, tainted_names

RULE_ID = "R5-tracer-branch"
CATEGORY = "tracer-branch"


def run(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules:
        for fi in mod.functions.values():
            if not fi.jit_root:
                continue
            tainted = tainted_names(index, fi, taint_params=True)
            for n in own_nodes(fi.node):
                if not isinstance(n, (ast.If, ast.While)):
                    continue
                if arrayish(index, mod, n.test, tainted):
                    kw = "if" if isinstance(n, ast.If) else "while"
                    findings.append(Finding(
                        RULE_ID, mod.path, n.lineno, n.col_offset,
                        f"Python `{kw}` on a traced value inside jitted "
                        f"function `{fi.qualname}`; use lax.cond/"
                        "lax.while_loop/jnp.where"))
                # comprehension/ternary on tracers inside the test are
                # covered by the same arrayish() walk above
    return findings
