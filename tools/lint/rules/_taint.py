"""Shared local taint analysis: which names plausibly hold traced/device
arrays inside one function body.

This is deliberately conservative-by-construction rather than sound: we
taint values produced by the jax array namespaces (``jnp.*``, ``lax.*``,
``jax.random.*`` …) and anything derived from them, and *untaint* the
handful of attributes that are host scalars by contract (``.shape``,
``.ndim``, ``.dtype``, ``.size``).  ``jax.device_get`` output is a host
numpy value, so it never taints.
"""
from __future__ import annotations

import ast
from typing import List, Set

#: attributes of an array that are static/host values, not arrays
NONARRAY_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "sharding",
                  "device", "itemsize", "weak_type"}

#: method calls on an array that yield host values, not arrays
NONARRAY_METHODS = NONARRAY_ATTRS | {"item", "tolist", "to_py"}

#: dotted-prefixes whose call results are treated as device arrays
ARRAY_NAMESPACES = ("jax.numpy.", "jax.lax.", "jax.nn.", "jax.random.",
                    "jax.scipy.", "jax.ops.")
ARRAY_CALLS = {"jax.device_put", "jax.block_until_ready"}


def own_nodes(root: ast.AST, *, into_classes: bool = False) -> List[ast.AST]:
    """All AST nodes of `root`'s body, excluding nested function bodies
    (they are analyzed as their own scopes).  Lambdas are kept — they
    share the enclosing scope's locals."""
    out: List[ast.AST] = []

    def rec(n: ast.AST) -> None:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        if isinstance(n, ast.ClassDef) and not into_classes:
            return
        out.append(n)
        for c in ast.iter_child_nodes(n):
            rec(c)

    for c in ast.iter_child_nodes(root):
        rec(c)
    return out


def _target_names(t: ast.AST) -> List[str]:
    if isinstance(t, ast.Name):
        return [t.id]
    if isinstance(t, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in t.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(t, ast.Starred):
        return _target_names(t.value)
    return []


def arrayish(index, mod, expr: ast.AST, tainted: Set[str]) -> bool:
    """Does `expr` plausibly evaluate to a traced/device array?"""
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Attribute):
        if expr.attr in NONARRAY_ATTRS:
            return False
        return arrayish(index, mod, expr.value, tainted)
    if isinstance(expr, ast.Subscript):
        return arrayish(index, mod, expr.value, tainted)
    if isinstance(expr, ast.BinOp):
        return (arrayish(index, mod, expr.left, tainted)
                or arrayish(index, mod, expr.right, tainted))
    if isinstance(expr, ast.UnaryOp):
        return arrayish(index, mod, expr.operand, tainted)
    if isinstance(expr, ast.Compare):
        return (arrayish(index, mod, expr.left, tainted)
                or any(arrayish(index, mod, c, tainted)
                       for c in expr.comparators))
    if isinstance(expr, ast.BoolOp):
        return any(arrayish(index, mod, v, tainted) for v in expr.values)
    if isinstance(expr, ast.IfExp):
        return (arrayish(index, mod, expr.body, tainted)
                or arrayish(index, mod, expr.orelse, tainted))
    if isinstance(expr, ast.Call):
        chain = index.attr_chain(mod, expr.func)
        if chain is not None:
            if chain == "jax.device_get":
                return False            # host numpy out
            if chain in ARRAY_CALLS:
                return True
            if any(chain.startswith(p) for p in ARRAY_NAMESPACES):
                return True
        if isinstance(expr.func, ast.Attribute):
            # method on an array: x.astype(...), x.reshape(...), x.at[...]
            if expr.func.attr in NONARRAY_METHODS:
                return False
            return arrayish(index, mod, expr.func.value, tainted)
        return False
    return False


def tainted_names(index, fi, *, taint_params: bool = False) -> Set[str]:
    """Fixed-point taint over `fi`'s assignments.  With `taint_params`,
    non-static parameters seed the set (jit roots: params are tracers)."""
    tainted: Set[str] = set()
    if taint_params:
        a = fi.node.args
        for p in (list(getattr(a, "posonlyargs", [])) + a.args + a.kwonlyargs):
            if p.arg not in fi.static_params and p.arg not in ("self", "cls"):
                tainted.add(p.arg)
    nodes = own_nodes(fi.node)
    changed = True
    while changed:
        changed = False
        for n in nodes:
            targets, value = [], None
            if isinstance(n, ast.Assign):
                targets, value = n.targets, n.value
            elif isinstance(n, (ast.AnnAssign, ast.AugAssign)):
                targets, value = [n.target], n.value
            elif isinstance(n, ast.NamedExpr):
                targets, value = [n.target], n.value
            elif isinstance(n, (ast.For, ast.comprehension)):
                it = n.iter
                if arrayish(index, fi.module, it, tainted):
                    targets, value = [n.target], None
                    for name in _target_names(n.target):
                        if name not in tainted:
                            tainted.add(name)
                            changed = True
                continue
            if value is None:
                continue
            if arrayish(index, fi.module, value, tainted):
                for t in targets:
                    for name in _target_names(t):
                        if name not in tainted:
                            tainted.add(name)
                            changed = True
    return tainted
