"""`repro-lint` — AST-based static analysis for the JAX/Pallas stack.

Run as::

    python -m tools.lint src/

Rules (see ``tools/lint/rules/``):

    R1-host-sync        no host-sync calls (`jax.device_get`, `.item()`,
                        `.block_until_ready()`, `np.asarray`, `float()`/
                        `int()`/`bool()` on traced values) in jit-reachable
                        code; blocking syncs flagged on host paths too
    R2-jit-cache        no `jax.jit` constructed inside a function body
                        without an lru/module-level cache (the per-call
                        re-jit bug class)
    R3-codec-registry   every registered codec implements the full `Codec`
                        protocol incl. the sharded-encode surface or
                        explicitly opts out; header params stay JSON-able
    R4-kernel-dispatch  every `kernels/<op>/` with a `kernel.py` registers
                        a pallas impl; ops without one declare themselves
                        jax-only with a reason; the pipeline-stage table is
                        fully registered
    R5-tracer-branch    no Python `if`/`while` on traced values inside
                        jitted functions

Intentional violations carry a waiver pragma with a reason::

    x = jax.device_get(stats)   # repro-lint: allow[host-sync] one scalar sync

A pragma on its own line covers the next statement; a trailing pragma
covers the statement it sits on (a pragma on a ``def`` line covers the
whole function).  Unwaived findings fail the run (exit code 1).
"""
from .engine import (Finding, Report, Waiver, lint_paths,  # noqa: F401
                     waived_spans)

__all__ = ["Finding", "Report", "Waiver", "lint_paths", "waived_spans"]
