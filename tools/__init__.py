"""Repo-local developer tooling (not part of the installed `repro` package)."""
