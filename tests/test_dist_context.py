"""dist.context / dist.sharding / dist.fault unit tests: context
nesting+restoration, spec/shape tree parity, straggler behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import context as ctx
from repro.dist import fault, sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import model as M


def _is_spec(x):
    return isinstance(x, P)


class TestContextNesting:
    def test_mesh_nesting_and_exception_restores(self):
        assert ctx.current_mesh() is None
        m1 = make_host_mesh()
        m2 = make_host_mesh()
        with ctx.use_mesh(m1):
            assert ctx.current_mesh() is m1
            with ctx.use_mesh(m2):
                assert ctx.current_mesh() is m2
            assert ctx.current_mesh() is m1
            with pytest.raises(RuntimeError):
                with ctx.use_mesh(m2):
                    assert ctx.current_mesh() is m2
                    raise RuntimeError("boom")
            assert ctx.current_mesh() is m1          # restored past the raise
        assert ctx.current_mesh() is None

    def test_param_specs_and_flags_restore(self):
        specs = {"w": P(None, "model")}
        assert ctx.current_param_specs() is None
        with pytest.raises(ValueError):
            with ctx.use_param_specs(specs), ctx.use_weight_compress(True), \
                    ctx.use_a2a_compress(True):
                assert ctx.current_param_specs() is specs
                raise ValueError("boom")
        assert ctx.current_param_specs() is None
        assert not ctx.a2a_compress_active()
        assert ctx.weight_gather_info() is None

    def test_dp_axes_override(self):
        mesh = make_host_mesh()
        with ctx.use_mesh(mesh):
            assert ctx.current_dp_axes() == ("data",)
            with ctx.dp_axes_override(("data", "model")):
                assert ctx.current_dp_axes() == ("data", "model")
            assert ctx.current_dp_axes() == ("data",)

    def test_constrain_noop_off_mesh(self):
        x = jnp.ones((4, 8))
        y = ctx.constrain(x, "dp", "model")
        assert y is x                                # identity, not a copy

    def test_constrain_divisibility_fallback_on_mesh(self):
        mesh = make_host_mesh()
        x = jnp.ones((3, 5))                         # divides nothing
        with ctx.use_mesh(mesh):
            y = ctx.constrain(x, "dp", "model")
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_constrain_over_rank_and_unknown_axis_replicate(self):
        mesh = make_host_mesh()                      # no 'pod' axis
        with ctx.use_mesh(mesh):
            x = jnp.ones((4,))
            y = ctx.constrain(x, "dp", None, "model")   # spec rank > x rank
            np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
            z = ctx.constrain(jnp.ones((4, 4)), "pod", ("pod", "data"))
            assert z.shape == (4, 4)

    def test_constrain_like_params_lead_axis_off_pod_mesh(self):
        mesh = make_host_mesh()
        tree = {"w": jnp.ones((2, 4, 4))}            # extra leading pod dim
        with ctx.use_mesh(mesh), ctx.use_param_specs(
                {"w": P(None, "model")}):
            out = ctx.constrain_like_params(tree, lead_axis="pod")
            assert out["w"].shape == (2, 4, 4)

    def test_param_specs_fsdp_marks_data_axis(self):
        """fsdp=True must put 'data' on large leaves — the int8
        weight-gather keys off it (core.weights._has_data)."""
        mesh = make_host_mesh()
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        shapes = M.param_shapes(cfg)
        specs = SH.param_specs(shapes, mesh, fsdp=True)

        def has_data(spec):
            return any(e == "data" or (isinstance(e, tuple) and "data" in e)
                       for e in tuple(spec))

        assert has_data(specs["layers"][0]["mlp"]["w_up"])
        assert not has_data(specs["layers"][0]["pre_norm"])
        plain = SH.param_specs(shapes, mesh)
        assert not any(has_data(s) for s in
                       jax.tree.leaves(plain, is_leaf=_is_spec))

    def test_constrain_like_params_noop_without_specs(self):
        tree = {"w": jnp.ones((4, 4))}
        with ctx.use_mesh(make_host_mesh()):
            assert ctx.constrain_like_params(tree) is tree


class TestSpecShapeParity:
    @pytest.mark.parametrize("name", sorted(configs.ARCHS))
    def test_param_specs_tree_parity(self, name):
        """specs must mirror param_shapes exactly: same treedef, one
        PartitionSpec per leaf, rank(spec) <= rank(leaf)."""
        mesh = make_host_mesh()
        shapes = M.param_shapes(configs.get(name))
        specs = SH.param_specs(shapes, mesh)
        sdef = jax.tree.structure(specs, is_leaf=_is_spec)
        pdef = jax.tree.structure(shapes)
        assert sdef == pdef
        for leaf, spec in zip(jax.tree.leaves(shapes),
                              jax.tree.leaves(specs, is_leaf=_is_spec)):
            assert isinstance(spec, P)
            assert len(tuple(spec)) <= leaf.ndim, (spec, leaf.shape)

    def test_weight_gather_info_layout(self):
        """specs_tuple aligns with tuple(params['layers']) with the
        leading period dim stripped from every leaf spec."""
        mesh = make_host_mesh()
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        shapes = M.param_shapes(cfg)
        specs = SH.param_specs(shapes, mesh)
        with ctx.use_mesh(mesh), ctx.use_param_specs(specs), \
                ctx.use_weight_compress(True):
            wg = ctx.weight_gather_info()
            assert wg is not None
            specs_tuple, m = wg
            assert m is mesh
            assert len(specs_tuple) == len(shapes["layers"])
            for ls, ss in zip(shapes["layers"], specs_tuple):
                for leaf, spec in zip(
                        jax.tree.leaves(ls),
                        jax.tree.leaves(ss, is_leaf=_is_spec)):
                    assert len(tuple(spec)) <= leaf.ndim - 1

    def test_batch_spec(self):
        mesh = make_host_mesh()
        assert SH.batch_spec(mesh) == P(("data",), None)
        assert SH.batch_spec(mesh, podded=True) == P("pod", "data", None)
        assert SH.dp_axes(mesh) == ("data",)


class TestStraggler:
    def test_warmup_never_flags(self):
        det = fault.StragglerDetector(threshold=1.5, warmup=4)
        # wildly varying warmup durations: still never flagged
        assert not any(det.observe(i, d)
                       for i, d in enumerate([0.1, 1.0, 0.05, 2.0]))

    def test_threshold_boundary(self):
        det = fault.StragglerDetector(threshold=2.0, warmup=1, alpha=0.0)
        det.observe(0, 0.1)                          # ema frozen at 0.1
        assert det.observe(1, 0.1) is False
        assert det.observe(2, 0.2) is False          # == threshold: not slow
        assert det.observe(3, 0.21) is True          # just over
        assert det.n_flagged == 1

    def test_flagged_step_excluded_from_ema(self):
        det = fault.StragglerDetector(threshold=2.0, warmup=1, alpha=0.5)
        det.observe(0, 0.1)
        assert det.observe(1, 10.0) is True
        assert det.ema == pytest.approx(0.1)         # outlier not absorbed
        assert det.observe(2, 0.1) is False

    def test_warmup_straggler_does_not_poison_baseline(self):
        """Regression (ISSUE satellite): a straggler landing during
        warmup (steps 2..warmup) used to be EMA-folded into the baseline
        and suppress all later detection.  The warmup baseline is the
        median of the window, so one outlier leaves it intact and a
        post-warmup 3x step still flags."""
        det = fault.StragglerDetector(threshold=2.0, warmup=5, alpha=0.2)
        for i, d in enumerate([0.1, 0.1, 10.0, 0.1, 0.1]):  # straggler @2
            assert det.observe(i, d) is False        # warmup never flags
        assert det.ema == pytest.approx(0.1)         # robust baseline
        assert det.observe(5, 0.3) is True           # 3x baseline flags
        assert det.n_flagged == 1

    def test_warmup_majority_slow_is_the_baseline(self):
        """The median tracks the *typical* step: if most warmup steps are
        slow, that IS the baseline (not treated as outliers)."""
        det = fault.StragglerDetector(threshold=2.0, warmup=4)
        for i, d in enumerate([1.0, 1.1, 0.9, 1.0]):
            det.observe(i, d)
        assert det.ema == pytest.approx(1.0, rel=0.1)
        assert det.observe(4, 1.2) is False

    def test_trainer_step_log_surfaces_n_flagged(self):
        """The trainer's history records the running straggler count —
        the hook straggler mitigation keys off."""
        from repro import configs
        from repro.models.config import ModelConfig  # noqa: F401
        from repro.train.trainer import LoopConfig, Trainer
        from repro.train.train_step import TrainConfig
        from repro.io.checkpoint import CheckpointPolicy

        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        tr = Trainer(cfg, TrainConfig(), LoopConfig(
            steps=3, batch=2, seq=16, checkpoint_dir=None,
            checkpoint_policy=CheckpointPolicy()))
        hist = tr.run()
        assert hist and all("n_flagged" in h for h in hist)
        assert hist[-1]["n_flagged"] == tr.straggler.n_flagged

    def test_loss_is_bad(self):
        assert fault.loss_is_bad(float("nan"))
        assert fault.loss_is_bad(jnp.float32(-np.inf))
        assert not fault.loss_is_bad(jnp.float32(0.0))
        assert not fault.loss_is_bad(np.float64(1e30))
