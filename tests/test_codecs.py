"""Unified `repro.codecs` API tests: registry, container format, per-codec
parity vs the pre-redesign entry points, dtype self-description (the bf16
regression), checkpoint policy integration and the deprecation shims."""
import dataclasses
import json
import os
import tempfile
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import codecs
from repro.core import compressor as CZ
from repro.core import gradient as G
from repro.core import kvcache as KV
from repro.core import metrics as M
from repro.core import weights as W
from repro.core import zfp_like as Z
from repro.io import checkpoint as CK


def _field(shape, seed=0, smooth=True):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(np.cumsum(x, axis=-1) if smooth else x)


def _quiet(fn, *a, **k):
    """Call a deprecated entry point with its warning suppressed."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*a, **k)


class TestRegistry:
    def test_names_cover_all_surfaces(self):
        for name in ("cusz", "int8", "int16", "int8-block", "zfp",
                     "lossless"):
            assert name in codecs.names()

    def test_get_configures(self):
        c = codecs.get("cusz", eb=1e-3, eb_mode="valrel", chunk_size=512)
        assert c.cfg.eb == 1e-3 and c.cfg.chunk_size == 512
        b = codecs.get("int8-block", axis=2, block=64)
        assert b.axis == 2 and b.block == 64
        assert codecs.get("int16").qmax == 2 ** 15 - 1

    def test_get_default_cached(self):
        assert codecs.get("lossless") is codecs.get("lossless")

    def test_unknown_codec_raises(self):
        with pytest.raises(KeyError):
            codecs.get("zstd")

    def test_version_gate(self):
        c = codecs.get("lossless").encode(jnp.zeros(4))
        newer = c.replace(header=dataclasses.replace(c.header, version=99))
        with pytest.raises(ValueError, match="v99"):
            codecs.decode(newer)


class TestContainer:
    def test_header_json_roundtrip(self):
        x = _field((6, 8))
        c = codecs.get("cusz", eb=1e-4, eb_mode="valrel").encode(x)
        h2 = codecs.Header.from_json(
            json.loads(json.dumps(c.header.to_json())))
        assert h2 == c.header          # incl. tuple-valued params (block)

    def test_container_is_pytree(self):
        c = codecs.get("int8").encode(_field((4, 4)))
        c2 = jax.tree.map(lambda a: a, c)
        assert isinstance(c2, codecs.Container)
        assert c2.header == c.header

    def test_to_from_arrays_npz(self):
        x = _field((12, 16))
        codec = codecs.get("int8-block", axis=0, block=4)
        hjson, arrs = codecs.to_arrays(codec.pack(codec.encode(x)))
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "c.npz")
            np.savez(p, **arrs)
            z = np.load(p)
            c2 = codecs.from_arrays(hjson, {k: z[k] for k in z.files})
            np.testing.assert_allclose(np.asarray(codecs.decode(c2)),
                                       np.asarray(codecs.decode(
                                           codec.encode(x))))

    def test_jit_boundary(self):
        codec = codecs.get("int8-block", axis=1, block=8)
        x = _field((4, 32))
        enc = jax.jit(lambda v: codec.encode(v))
        dec = jax.jit(lambda c: codec.decode(c))
        out = dec(enc(x))
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(codec.decode(codec.encode(x))))


class TestParityWithLegacyEntryPoints:
    """All five codecs round-trip bit-exactly through
    codecs.get(name).encode/decode vs their pre-redesign entry points."""

    def test_cusz_vs_compressor_roundtrip(self):
        x = _field((30, 50, 20), seed=1)
        cfg = CZ.CompressorConfig(eb=1e-4, eb_mode="valrel", chunk_size=512,
                                  outlier_frac=1.0)
        ref, blob, eb, _ = CZ.roundtrip(x, cfg)
        c = codecs.get("cusz", cfg=cfg).encode(x)
        assert c.header.param("eb") == pytest.approx(eb, rel=0, abs=0)
        np.testing.assert_array_equal(np.asarray(codecs.decode(c)),
                                      np.asarray(ref))

    def test_cusz_packed_payload_matches_pack_blob(self):
        x = _field((40, 64), seed=2)
        cfg = CZ.CompressorConfig(eb=1e-4, eb_mode="valrel", chunk_size=512,
                                  outlier_frac=1.0)
        blob, eb = CZ.compress(x, cfg)
        legacy = CZ.pack_blob(blob)
        codec = codecs.get("cusz", cfg=cfg)
        packed = codec.pack(codec.encode(x))
        assert set(legacy) == set(packed.payload)
        for k in legacy:
            np.testing.assert_array_equal(np.asarray(legacy[k]),
                                          np.asarray(packed.payload[k]))

    def test_cusz_vs_gradient_blob(self):
        g = _field((40, 130), seed=3) * 1e-3
        cfg = CZ.CompressorConfig(eb=1e-5, eb_mode="valrel", chunk_size=512,
                                  outlier_frac=1.0)
        packed, eb = _quiet(G.cusz_compress_gradient, g, cfg)
        ref = _quiet(G.cusz_decompress_gradient, packed, eb, g.shape, cfg)
        out = codecs.decode(codecs.get("cusz", cfg=cfg).encode(g))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_cusz_vs_kv_offload(self):
        x = _field((4, 256, 8), seed=4)
        cfg = CZ.CompressorConfig(eb=1e-4, eb_mode="valrel", chunk_size=512,
                                  outlier_frac=1.0)
        packed, eb = _quiet(KV.kv_offload_pack, x, cfg)
        ref = _quiet(KV.kv_offload_restore, packed, eb, x.shape, cfg,
                     dtype=jnp.float32)
        out = codecs.decode(codecs.get("cusz", cfg=cfg).encode(x))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_int8_vs_quantize_tensor(self):
        g = _field((64, 32), seed=5, smooth=False)
        q, scale = G.quantize_tensor(g, "int8")
        ref = G.dequantize_tensor(q, scale)
        c = codecs.get("int8").encode(g)
        np.testing.assert_array_equal(np.asarray(c.payload["q"]),
                                      np.asarray(q))
        np.testing.assert_array_equal(np.asarray(codecs.decode(c)),
                                      np.asarray(ref))

    def test_int8_block_vs_kv_quantize(self):
        x = _field((2, 4, 512, 16), seed=6, smooth=False)
        qkv = KV.kv_quantize(x, seq_axis=2)
        c = codecs.get("int8-block", axis=2, block=KV.SEQ_BLOCK).encode(x)
        np.testing.assert_array_equal(np.asarray(c.payload["q"]),
                                      np.asarray(qkv.q))
        np.testing.assert_array_equal(np.asarray(c.payload["scale"]),
                                      np.asarray(qkv.scale))
        np.testing.assert_array_equal(
            np.asarray(codecs.decode(c, like=x)),
            np.asarray(KV.kv_dequantize(qkv, 2, jnp.float32)))

    def test_int8_block_vs_weights_qdq(self):
        x = _field((16, 256), seed=7, smooth=False)
        ref = W._qdq(x)
        c = codecs.get("int8-block", axis=-1, block=W.QBLOCK).encode(x)
        np.testing.assert_array_equal(np.asarray(codecs.decode(c)),
                                      np.asarray(ref))

    @pytest.mark.parametrize("shape", [(64, 80), (30, 40, 20),
                                       (3, 9, 10, 11)])
    def test_zfp_vs_compress_decompress(self, shape):
        x = _field(shape, seed=8)
        ref, rate = Z.compress_decompress(x, 12)
        codec = codecs.get("zfp", rate_bits=12)
        c = codec.encode(x)
        np.testing.assert_array_equal(np.asarray(codecs.decode(c)),
                                      np.asarray(ref))
        assert codec.achieved_bitrate(c) == pytest.approx(rate)

    def test_lossless_exact(self):
        x = _field((5, 7), seed=9)
        np.testing.assert_array_equal(
            np.asarray(codecs.decode(codecs.get("lossless").encode(x))),
            np.asarray(x))


class TestSelfDescribingContainer:
    """A Container alone (no caller-side eb/shape/dtype) suffices to
    decode — including the bf16 regression the old `(packed, eb)` + shape
    plumbing lost (restore hardcoded the caller's dtype)."""

    @pytest.mark.parametrize("name,kw", [
        ("cusz", {"eb": 1e-3, "eb_mode": "valrel"}),
        ("int8", {}),
        ("int8-block", {"axis": 1, "block": 128}),
        ("lossless", {}),
    ])
    def test_bf16_dtype_restored(self, name, kw):
        x32 = _field((6, 256), seed=10)
        x = x32.astype(jnp.bfloat16)
        codec = codecs.get(name, **kw)
        c = codec.encode(x)
        assert c.header.dtype == "bfloat16"
        assert c.header.shape == (6, 256)
        y = codecs.decode(c)               # no dtype passed anywhere
        assert y.dtype == jnp.bfloat16 and y.shape == x.shape
        if name != "lossless":
            rel = float(jnp.max(jnp.abs(
                y.astype(jnp.float32) - x.astype(jnp.float32))))
            amax = float(jnp.max(jnp.abs(x32)))
            assert rel <= amax * 0.05      # bf16 + codec bound, loose
        else:
            np.testing.assert_array_equal(np.asarray(y, np.float32),
                                          np.asarray(x, np.float32))

    def test_cusz_storage_roundtrip_container_only(self):
        """encode -> pack -> npz -> from_arrays -> decode, nothing else."""
        x = _field((25, 40, 16), seed=11)
        codec = codecs.get("cusz", eb=1e-4, eb_mode="valrel", chunk_size=512,
                           outlier_frac=1.0)
        hjson, arrs = codecs.to_arrays(codec.pack(codec.encode(x)))
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "c.npz")
            np.savez(p, **arrs)
            z = np.load(p)
            c = codecs.from_arrays(hjson, {k: z[k] for k in z.files})
        y = codecs.decode(c)
        assert y.shape == x.shape and y.dtype == x.dtype
        eb = c.header.param("eb")
        assert M.verify_error_bound(x, y, eb)

    def test_decode_honors_like_override(self):
        x = _field((4, 128))
        c = codecs.get("int8-block").encode(x)
        out = codecs.get("int8-block").decode(
            c, like=jax.ShapeDtypeStruct((4, 128), jnp.float16))
        assert out.dtype == jnp.float16

    def test_cusz_v1_gapless_container_still_decodes(self):
        """Back-compat: a format-v1 container (no gap arrays, no sub_size
        header param) decodes through the legacy sequential path."""
        x = _field((40, 64), seed=21)
        codec = codecs.get("cusz", eb=1e-3, eb_mode="valrel", chunk_size=512)
        c = codec.encode(x)
        assert c.header.version == 2
        assert "gap_bits" in c.payload and "gap_syms" in c.payload
        v1 = codecs.Container(
            dataclasses.replace(c.header.without_params("sub_size"),
                                version=1),
            {k: v for k, v in c.payload.items()
             if k not in ("gap_bits", "gap_syms")})
        y = codecs.decode(v1)
        assert M.verify_error_bound(x, y, c.header.param("eb"))
        # bit-exact with the gap-array decode of the same stream
        np.testing.assert_array_equal(np.asarray(y),
                                      np.asarray(codecs.decode(c)))
        # packed v1 storage form roundtrips too
        p1 = codec.pack(v1)
        assert "gap_bits" not in p1.payload
        np.testing.assert_array_equal(np.asarray(codecs.decode(p1)),
                                      np.asarray(y))

    def test_cusz_future_version_rejected_actionably(self):
        c = codecs.get("cusz", eb=1e-3, eb_mode="valrel").encode(
            _field((8, 64)))
        newer = c.replace(header=dataclasses.replace(c.header, version=7))
        with pytest.raises(ValueError, match=r"cusz v7"):
            codecs.decode(newer)

    def test_cusz_valid_flags_outlier_overflow(self):
        # tiny outlier capacity + rough data -> overflow -> invalid
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
        codec = codecs.get("cusz", eb=1e-6, eb_mode="valrel",
                           outlier_frac=0.001)
        c = codec.encode(x)
        assert not codec.valid(c)
        smooth = _field((64, 64), seed=13)
        ok = codecs.get("cusz", eb=1e-3, eb_mode="valrel", outlier_frac=1.0)
        assert ok.valid(ok.encode(smooth))


class TestDeprecationShims:
    @pytest.fixture(autouse=True)
    def _fresh_warn_once(self):
        """`warn_once` is process-wide: the parity tests above call the
        same shims (via _quiet, which suppresses but still *consumes*
        the one warning), so re-arm the keys this class asserts on."""
        from repro import _compat
        for key in ("cusz_compress_gradient", "cusz_decompress_gradient",
                    "kv_offload_pack", "kv_offload_restore",
                    "save_checkpoint-mode"):
            _compat._WARNED.discard(key)

    def test_cusz_gradient_shims_warn_and_work(self):
        g = _field((40, 130), seed=14) * 1e-3
        cfg = CZ.CompressorConfig(eb=1e-5, eb_mode="valrel", chunk_size=512,
                                  outlier_frac=1.0)
        with pytest.warns(DeprecationWarning):
            packed, eb = G.cusz_compress_gradient(g, cfg)
        with pytest.warns(DeprecationWarning):
            out = G.cusz_decompress_gradient(packed, eb, g.shape, cfg)
        assert M.verify_error_bound(g, out, eb)

    def test_kv_offload_shims_warn_and_work(self):
        x = _field((4, 256, 8), seed=15)
        cfg = CZ.CompressorConfig(eb=1e-4, eb_mode="valrel", chunk_size=512,
                                  outlier_frac=1.0)
        with pytest.warns(DeprecationWarning):
            packed, eb = KV.kv_offload_pack(x, cfg)
        with pytest.warns(DeprecationWarning):
            out = KV.kv_offload_restore(packed, eb, x.shape, cfg,
                                        dtype=jnp.float32)
        assert float(jnp.max(jnp.abs(out - x))) <= eb * (1 + 1e-4) + 1e-9

    def test_save_checkpoint_mode_warns_and_works(self):
        rng = np.random.default_rng(16)
        tree = {"w": jnp.asarray(np.cumsum(
            rng.standard_normal((64, 128)), axis=-1).astype(np.float32))}
        with tempfile.TemporaryDirectory() as d:
            with pytest.warns(DeprecationWarning):
                CK.save_checkpoint(d, 1, tree, mode="cusz", eb_valrel=1e-4)
            out, step = CK.load_checkpoint(d, tree)
        assert step == 1
        w, w2 = np.asarray(tree["w"]), np.asarray(out["w"])
        assert np.abs(w - w2).max() <= 1.05e-4 * (w.max() - w.min())

    def test_checkpoint_codec_config_warns(self):
        with pytest.warns(DeprecationWarning):
            cfg = W.checkpoint_codec_config(1e-5, kernel_impl="jax")
        assert cfg.eb_mode == "valrel" and cfg.use_tpu_blocks


class TestConsumersThroughRegistry:
    def test_serve_engine_kv_codec(self):
        """ServeConfig.compressed_kv builds the cache via the registered
        kv codec; greedy generation still mostly agrees."""
        from repro import configs
        from repro.models import model as MM
        from repro.serve.engine import ServeConfig, generate
        cfg = configs.reduced("qwen3-4b", n_periods=1)
        params = MM.init_params(jax.random.PRNGKey(6), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        a = np.asarray(generate(params, cfg, prompt, 6,
                                ServeConfig(s_max=128)))
        b = np.asarray(generate(params, cfg, prompt, 6,
                                ServeConfig(s_max=128, compressed_kv=True,
                                            kv_codec="int8-block")))
        assert (a == b).mean() > 0.6

    def test_gradient_psum_uses_registry_codec(self):
        rng = np.random.default_rng(17)
        npods = 2
        g = rng.standard_normal((npods, 64, 32)).astype(np.float32) * 0.01
        out = G.compressed_psum_mean({"w": jnp.asarray(g)}, "int8",
                                     npods)["w"]
        ref = g.mean(axis=0)
        qmax = codecs.get("int8").qmax
        scale = np.abs(g).max() / (qmax // npods)
        assert np.abs(np.asarray(out) - ref).max() <= scale / 2 + 1e-12

    def test_checkpoint_kernel_impl_via_policy(self):
        rng = np.random.default_rng(18)
        tree = {"w": jnp.asarray(np.cumsum(
            rng.standard_normal((64, 128)), axis=-1).astype(np.float32))}
        pol = CK.CheckpointPolicy(codec="cusz", eb_valrel=1e-4,
                                  kernel_impl="pallas-interpret")
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 0, tree, policy=pol)
            out, _ = CK.load_checkpoint(d, tree,
                                        kernel_impl="pallas-interpret")
        w, w2 = np.asarray(tree["w"]), np.asarray(out["w"])
        assert np.abs(w - w2).max() <= 1.05e-4 * (w.max() - w.min())

    def test_a2a_hook_carries_codec_name(self):
        from repro.dist import context as ctx
        from repro.launch.mesh import make_host_mesh
        with ctx.use_mesh(make_host_mesh()):
            assert ctx.a2a_codec() is None
            with ctx.use_a2a_compress(True):
                assert ctx.a2a_codec() == "int8-block"
            with ctx.use_a2a_compress("int8"):
                # legacy mode string = the blockwise wire codec
                assert ctx.a2a_codec() == "int8-block"
            with ctx.use_a2a_compress("none"):
                assert ctx.a2a_codec() is None
        assert ctx.weight_compress_codec() is None
        with ctx.use_weight_compress(True):
            assert ctx.weight_compress_codec() == "int8-block"
        with pytest.raises(ValueError, match="unknown compression codec"):
            ctx.use_weight_compress("int08")

    def test_block_codec_lookup_rejects_non_blockwise(self):
        assert codecs.get_block_codec("int8-block", axis=2,
                                      block=64).block == 64
        with pytest.raises(ValueError, match="blockwise"):
            codecs.get_block_codec("cusz", axis=2, block=64)
        with pytest.raises(ValueError, match="blockwise"):
            codecs.get_block_codec("int8", axis=2, block=64)

    def test_checkpoint_block_misaligned_leaf_falls_back(self):
        """A rule routing a block-misaligned leaf to int8-block must fall
        back to lossless, not abort the save."""
        rng = np.random.default_rng(19)
        tree = {"odd": jnp.asarray(
            rng.standard_normal((100, 70)).astype(np.float32))}
        pol = CK.CheckpointPolicy(rules=(("odd", "int8-block"),))
        with tempfile.TemporaryDirectory() as d:
            final = CK.save_checkpoint(d, 0, tree, policy=pol)
            man = json.load(open(os.path.join(final, "manifest.json")))
            assert man["tensors"]["odd"]["codec"] == "lossless"
            out, _ = CK.load_checkpoint(d, tree)
        np.testing.assert_array_equal(np.asarray(out["odd"]),
                                      np.asarray(tree["odd"]))

    def test_load_rejects_unknown_manifest_format(self):
        tree = {"w": jnp.zeros((4,), jnp.float32)}
        with tempfile.TemporaryDirectory() as d:
            final = CK.save_checkpoint(d, 0, tree)
            p = os.path.join(final, "manifest.json")
            man = json.load(open(p))
            man.pop("format")                  # simulate a format-1 file
            json.dump(man, open(p, "w"))
            with pytest.raises(ValueError, match="format 1"):
                CK.load_checkpoint(d, tree)
