"""SPMD integration tests on 8 fake host devices (subprocess so the
XLA_FLAGS device count doesn't leak into the rest of the suite).

Verifies, with real executions (not just compiles):
  * sharded train step == single-device train step numerics
  * compressed (int8) cross-pod gradient sync trains comparably
  * the production mesh constructors build
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.dist import sharding as SH
from repro.dist.context import use_mesh, use_param_specs
from repro.models import model as M
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step
from repro.data import pipeline

cfg = configs.reduced("qwen2.5-3b", n_periods=1)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
params = M.init_params(jax.random.PRNGKey(0), cfg)
pspecs = SH.param_specs(params, mesh)
pshard = SH.param_shardings(params, mesh)

losses = {}
for mode in ("none", "int8"):
    tcfg = TrainConfig(microbatches=2, grad_compress=mode, npods=2,
                       adamw=adamw.AdamWConfig(lr=5e-3))
    p = jax.device_put(params, pshard)
    opt = adamw.init(p, tcfg.adamw)
    with use_mesh(mesh), use_param_specs(pspecs):
        step = jax.jit(make_train_step(cfg, tcfg))
        ls = []
        for s in range(6):
            toks = pipeline.global_batch(mesh, cfg.vocab, 8, 32, s,
                                         podded=(mode != "none"))
            loss, p, opt = step(p, opt, toks)
            ls.append(float(loss))
    losses[mode] = ls
    assert all(np.isfinite(l) for l in ls), (mode, ls)
    assert ls[-1] < ls[0], (mode, ls)

# compressed and uncompressed training tracks closely at int8 eb
diff = abs(losses["none"][-1] - losses["int8"][-1])
assert diff < 0.35, (losses, diff)

# single-device reference parity for the uncompressed first step
p1 = M.init_params(jax.random.PRNGKey(0), cfg)
tc = TrainConfig(microbatches=2, adamw=adamw.AdamWConfig(lr=5e-3))
o1 = adamw.init(p1, tc.adamw)
step1 = jax.jit(make_train_step(cfg, tc))
t0 = jnp.asarray(pipeline.host_batch(cfg.vocab, 8, 32, 0))
l1, _, _ = step1(p1, o1, t0)
assert abs(float(l1) - losses["none"][0]) < 5e-2, (float(l1), losses["none"][0])
print("SPMD_OK", losses)
"""


@pytest.mark.slow
def test_spmd_8dev_train_modes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "SPMD_OK" in r.stdout


def test_mesh_constructors():
    from repro.launch.mesh import make_host_mesh
    m = make_host_mesh()
    assert dict(m.shape) == {"data": 1, "model": 1}
