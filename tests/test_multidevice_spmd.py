"""SPMD integration tests on 8 fake host devices (subprocess so the
XLA_FLAGS device count doesn't leak into the rest of the suite).

Verifies, with real executions (not just compiles):
  * sharded train step == single-device train step numerics
  * compressed (int8) cross-pod gradient sync trains comparably
  * the production mesh constructors build
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.dist import sharding as SH
from repro.dist.context import use_mesh, use_param_specs
from repro.models import model as M
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step
from repro.data import pipeline

cfg = configs.reduced("qwen2.5-3b", n_periods=1)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
params = M.init_params(jax.random.PRNGKey(0), cfg)
pspecs = SH.param_specs(params, mesh)
pshard = SH.param_shardings(params, mesh)

losses = {}
for mode in ("none", "int8"):
    tcfg = TrainConfig(microbatches=2, grad_compress=mode, npods=2,
                       adamw=adamw.AdamWConfig(lr=5e-3))
    p = jax.device_put(params, pshard)
    opt = adamw.init(p, tcfg.adamw)
    with use_mesh(mesh), use_param_specs(pspecs):
        step = jax.jit(make_train_step(cfg, tcfg))
        ls = []
        for s in range(6):
            toks = pipeline.global_batch(mesh, cfg.vocab, 8, 32, s,
                                         podded=(mode != "none"))
            loss, p, opt = step(p, opt, toks)
            ls.append(float(loss))
    losses[mode] = ls
    assert all(np.isfinite(l) for l in ls), (mode, ls)
    assert ls[-1] < ls[0], (mode, ls)

# compressed and uncompressed training tracks closely at int8 eb
diff = abs(losses["none"][-1] - losses["int8"][-1])
assert diff < 0.35, (losses, diff)

# single-device reference parity for the uncompressed first step
p1 = M.init_params(jax.random.PRNGKey(0), cfg)
tc = TrainConfig(microbatches=2, adamw=adamw.AdamWConfig(lr=5e-3))
o1 = adamw.init(p1, tc.adamw)
step1 = jax.jit(make_train_step(cfg, tc))
t0 = jnp.asarray(pipeline.host_batch(cfg.vocab, 8, 32, 0))
l1, _, _ = step1(p1, o1, t0)
assert abs(float(l1) - losses["none"][0]) < 5e-2, (float(l1), losses["none"][0])
print("SPMD_OK", losses)
"""


FSDP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.dist import sharding as SH
from repro.dist.context import use_mesh, use_param_specs
from repro.models import model as M
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step
from repro.data import pipeline

# ZeRO-3 layout: all 8 devices on 'data' so every quantizable leaf's
# feature dim stays QBLOCK-aligned after the (trivial) model shard, and
# the weight all-gather moves int8 + scales (ROADMAP: FSDP int8 weight-
# gather numerics on a real multi-device run, not just dry-run HLO)
cfg = configs.reduced("qwen2.5-3b", n_periods=1)
mesh = jax.make_mesh((8, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
params = M.init_params(jax.random.PRNGKey(0), cfg)
pspecs = SH.param_specs(params, mesh, fsdp=True)
pshard = SH.param_shardings(params, mesh, fsdp=True)

# the int8 gather hook must actually see fsdp-sharded quantizable leaves
from repro.core import weights as W
assert any(
    W._quantizable([str(getattr(k, "key", "")) for k in path], leaf)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0])

losses = {}
for mode in ("none", "int8"):
    tcfg = TrainConfig(weight_compress=mode,
                       adamw=adamw.AdamWConfig(lr=5e-3))
    p = jax.device_put(params, pshard)
    opt = adamw.init(p, tcfg.adamw)
    with use_mesh(mesh), use_param_specs(pspecs):
        step = jax.jit(make_train_step(cfg, tcfg))
        ls = []
        for s in range(6):
            toks = pipeline.global_batch(mesh, cfg.vocab, 8, 32, s)
            loss, p, opt = step(p, opt, toks)
            ls.append(float(loss))
    losses[mode] = ls
    assert all(np.isfinite(l) for l in ls), (mode, ls)
    assert ls[-1] < ls[0], (mode, ls)

# int8 weight-gather trains within the blockwise-int8 bound of the
# uncompressed run (loss parity)
diff = abs(losses["none"][-1] - losses["int8"][-1])
assert diff < 0.35, (losses, diff)
print("FSDP_OK", losses)
"""


KV_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import codecs
from repro.core import kvcache as KVC

# serving KV layout: batch over 'data', cache seq over 'model'
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
B, H, S, hd = 4, 2, 512, 16
rng = np.random.default_rng(0)
k = jnp.asarray(rng.standard_normal((B, H, S, hd)).astype(np.float32))
spec = P("data", None, "model", None)
k = jax.device_put(k, NamedSharding(mesh, spec))

codec = codecs.get("int8-block", axis=2, block=KVC.SEQ_BLOCK)

@jax.jit
def quantize_sharded(x):
    x = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    c = codec.encode(x)
    q = jax.lax.with_sharding_constraint(
        c.payload["q"], NamedSharding(mesh, spec))
    return c.replace(payload={"q": q, "scale": c.payload["scale"]})

@jax.jit
def restore(c):
    return codec.decode(c, like=jax.ShapeDtypeStruct(k.shape, k.dtype))

cont = quantize_sharded(k)
assert cont.payload["q"].dtype == jnp.int8
out = restore(cont)
eb = np.repeat(np.asarray(cont.payload["scale"]) / 2.0, KVC.SEQ_BLOCK,
               axis=2)
assert (np.abs(np.asarray(out) - np.asarray(k)) <= eb * 2 + 1e-12).all()

# offload leg: the evicted block goes through the cusz wire codec — the
# container alone restores it (dtype/shape/eb all in the header)
wire = codecs.get("cusz", eb=1e-4, eb_mode="valrel", chunk_size=512,
                  outlier_frac=1.0)
src = out.astype(jnp.bfloat16)
c2 = wire.pack(wire.encode(src))
back = codecs.decode(codecs.from_arrays(*codecs.to_arrays(c2)))
assert back.dtype == jnp.bfloat16 and back.shape == (B, H, S, hd)
err = float(jnp.max(jnp.abs(back.astype(jnp.float32)
                            - src.astype(jnp.float32))))
# bound: codec eb + the final bf16 rounding of the reconstruction
amax = float(jnp.max(jnp.abs(src.astype(jnp.float32))))
tol = float(c2.header.param("eb")) * (1 + 1e-3) + amax * 2.0 ** -8 + 1e-6
assert err <= tol, (err, tol)
print("KV_SHARD_OK", err)
"""


RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.dist import sharding as SH
from repro.dist.context import resolve_sharding, use_mesh
from repro.models import model as M
from repro.serve import engine as E

cfg = configs.reduced("qwen2.5-3b", n_periods=1)
params = M.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompt = jnp.asarray(rng.integers(0, cfg.vocab, (4, 20)).astype(np.int32))
scfg = E.ServeConfig(s_max=256, compressed_kv=True,
                     compute_dtype=jnp.float32)

# single-mesh compressed reference
ref = np.asarray(E.generate(params, cfg, prompt, 6, scfg))

# prefill mesh: batch over data(4), cache seq over model(2); decode mesh
# split differently: data(2) x model(4)
mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)

params_a = jax.device_put(params, SH.param_shardings(params, mesh_a))
prompt_a = jax.device_put(prompt,
                          resolve_sharding(mesh_a, prompt.shape, "data"))
with use_mesh(mesh_a):
    last, caches, plen = E.prefill(params_a, cfg, prompt_a, scfg)
    handoff = E.encode_handoff(caches, cfg, scfg, plen=plen)
hs = dict(E.LAST_HANDOFF_STATS)
assert hs["wire_bytes"] < hs["raw_bf16_bytes"], hs
# what crosses the boundary: int8 payloads + f32 block scales, no f32 KV
for kind, entry in zip(handoff.kinds, handoff.entries):
    assert kind == "kv", kind
    for parts in entry:
        for p in parts:
            assert p.header.codec == "int8-block"
            assert np.asarray(p.payload["q"]).dtype == np.int8

params_b = jax.device_put(params, SH.param_shardings(params, mesh_b))
last_b = jax.device_put(np.asarray(last),
                        resolve_sharding(mesh_b, last.shape, "data"))
with use_mesh(mesh_b):
    caches_b = E.reshard_caches(handoff, cfg, scfg)
    rs = dict(E.LAST_RESHARD_STATS)
    # int8-block payload adopted as QuantKV: zero f32 round trip
    assert rs["adopted_quantkv"] == 2 and rs["decoded"] == 0, rs
    q = caches_b.entries[0][0].q
    assert q.dtype == jnp.int8 and q.sharding.mesh.shape == mesh_b.shape
    toks = np.asarray(E.decode_tokens(params_b, cfg, scfg, last_b,
                                      caches_b, handoff.plen, 6))
assert (toks == ref).all(), (toks.tolist(), ref.tolist())

# cusz offload leg: containers cross, decode requantizes under mesh_b
with use_mesh(mesh_a):
    h2 = E.encode_handoff(caches, cfg, scfg, wire="cusz", plen=plen)
assert dict(E.LAST_HANDOFF_STATS)["wire_bytes"] < hs["raw_bf16_bytes"]
with use_mesh(mesh_b):
    caches_c = E.reshard_caches(h2, cfg, scfg)
    assert dict(E.LAST_RESHARD_STATS)["adopted_quantkv"] == 0
    toks_c = np.asarray(E.decode_tokens(params_b, cfg, scfg, last_b,
                                        caches_c, plen, 6))
assert toks_c.shape == ref.shape and (toks_c == ref).mean() > 0.5
print("RESHARD_OK", hs)
"""


ELASTIC_CKPT_SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.dist import sharding as SH
from repro.dist.context import use_mesh
from repro.io import checkpoint as CK
from repro.io.async_writer import AsyncWriter
from repro.models import model as M

cfg = configs.reduced("qwen2.5-3b", n_periods=1)
params = M.init_params(jax.random.PRNGKey(0), cfg)
# smooth the leaves (cumsum = Lorenzo-predictable) so the cusz policy
# genuinely codes instead of falling back to lossless on random init
params = jax.tree_util.tree_map(
    lambda x: jnp.cumsum(x, axis=-1) / 8
    if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

# save from a (4, 2) mesh; restore onto a differently-shaped (2, 4) mesh
mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
params = jax.device_put(params, SH.param_shardings(params, mesh_a,
                                                   fsdp=True))
shard_b = SH.param_shardings(params, mesh_b, fsdp=True)

def bits(x):
    x = np.asarray(x)
    return x.view(np.uint16) if x.dtype == jnp.bfloat16 else x

for pol in (CK.CheckpointPolicy(codec="lossless"),
            CK.CheckpointPolicy(codec="int8"),
            CK.CheckpointPolicy(codec="cusz", eb_valrel=1e-4)):
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d4:
        # synchronous single-file reference save
        CK.save_checkpoint(d1, 0, params, policy=pol, nshards=1)
        # sharded + async save (4 host shards, overlapped write)
        with AsyncWriter(max_pending=1) as w:
            assert CK.save_checkpoint(d4, 0, params, policy=pol,
                                      nshards=4, writer=w) is w
            w.wait()
        with use_mesh(mesh_b):
            a, _ = CK.load_checkpoint(d1, params, shardings=shard_b)
            b, _ = CK.load_checkpoint(d4, params, shardings=shard_b)
        stats = dict(CK.LAST_RESTORE_STATS)
        assert stats["saved_nshards"] == 4
        assert stats["wire_leaves"] > 0, stats   # containers moved, not f32
        if pol.codec != "lossless":              # and moved compressed
            assert stats["wire_bytes"] < stats["raw_bytes"], stats
            import json
            man = json.load(open(os.path.join(d4, "step_00000000",
                                              "manifest.json")))
            coded = [e["codec"] for e in man["tensors"].values()]
            assert pol.codec in coded, coded
        for (pa, la), (pb, lb) in zip(
                jax.tree_util.tree_flatten_with_path(a)[0],
                jax.tree_util.tree_flatten_with_path(b)[0]):
            np.testing.assert_array_equal(bits(la), bits(lb), err_msg=str(pa))
        # restored leaves actually live on the new mesh's placement
        leaf = jax.tree_util.tree_leaves(b)[0]
        assert leaf.sharding.mesh.shape == mesh_b.shape
    print("policy", pol.codec, "elastic bitwise OK")
print("ELASTIC_OK")
"""


RESILIENCE_SCRIPT = r"""
import glob, os, tempfile, time
from repro.launch import env as launch_env
launch_env.setup_runtime(launch_env.RuntimeConfig(host_device_count=8))
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.dist import chaos, fault, sharding as SH
from repro.dist.context import use_mesh, use_param_specs
from repro.io import checkpoint as CK
from repro.models import model as M
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step
from repro.data import pipeline

assert jax.device_count() == 8, jax.devices()
cfg = configs.reduced("qwen2.5-3b", n_periods=1)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
params = M.init_params(jax.random.PRNGKey(0), cfg)
pspecs = SH.param_specs(params, mesh)
pshard = SH.param_shardings(params, mesh)
tcfg = TrainConfig(microbatches=2, adamw=adamw.AdamWConfig(lr=5e-3))
p = jax.device_put(params, pshard)
opt = adamw.init(p, tcfg.adamw)

with use_mesh(mesh), use_param_specs(pspecs):
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    # fault-free baseline: median compiled-step wall time
    warm = []
    for s in range(4):
        toks = pipeline.global_batch(mesh, cfg.vocab, 8, 32, s)
        t0 = time.perf_counter()
        loss, p, opt = step_fn(p, opt, toks)
        loss.block_until_ready()
        warm.append(time.perf_counter() - t0)
    base = float(np.median(warm[1:]))        # drop the compile step

    # -- leg 1: injected slow host, mitigation recovers wall-clock -------
    ccfg = chaos.ChaosConfig(nhosts=8, straggler_host=3,
                             straggler_delay_s=4.0 * base)
    policy = fault.MitigationPolicy(8)
    ratios = []
    with chaos.use_chaos(ccfg) as monkey:
        for s in range(4, 16):
            toks = pipeline.global_batch(mesh, cfg.vocab, 8, 32, s)
            loss, p, opt = step_fn(p, opt, toks)
            loss.block_until_ready()
            # the injected delay is a real sleep; model compute at the
            # stable baseline so the recovery ratio is well-defined
            total, host_dts = monkey.inject_step(s, base, policy.shares)
            policy.observe(s, host_dts)
            ratios.append(total / base)
            assert not (policy.on_bad_loss(s, float(loss)))
    assert ratios[0] >= 3.0, ratios          # the fault was real
    assert max(ratios[-3:]) <= 1.25, ratios  # recovered within ~1.2x
    assert any(e["kind"] == "rebalance" for e in policy.events)
    assert not policy.excluded

# -- leg 2: corrupted checkpoint shard restores from last good step -----
with tempfile.TemporaryDirectory() as d:
    CK.save_checkpoint(d, 0, (p, opt),
                       policy=CK.CheckpointPolicy(codec="lossless"),
                       nshards=2)
    CK.save_checkpoint(d, 1, (p, opt),
                       policy=CK.CheckpointPolicy(codec="lossless"),
                       nshards=2)
    shard = sorted(glob.glob(os.path.join(d, "step_00000001", "*.npz")))[0]
    chaos.corrupt_file(shard)
    (p2, opt2), step = CK.load_checkpoint(d, (p, opt))
    assert step == 0, step
    reports = CK.LAST_RESTORE_STATS["quarantine"]
    assert len(reports) == 1 and reports[0]["step"] == 1, reports
    assert os.path.exists(os.path.join(d, "step_00000001",
                                       "QUARANTINE.json"))
    for a, b in zip(jax.tree_util.tree_leaves((p, opt)),
                    jax.tree_util.tree_leaves((p2, opt2))):
        x, y = np.asarray(a), np.asarray(b)
        if x.dtype == jnp.bfloat16:
            x, y = x.view(np.uint16), y.view(np.uint16)
        np.testing.assert_array_equal(x, y)
print("RESILIENCE_OK", [round(r, 3) for r in ratios])
"""


def _run_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


@pytest.mark.slow
def test_spmd_8dev_train_modes():
    r = _run_subprocess(SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "SPMD_OK" in r.stdout


@pytest.mark.slow
def test_spmd_8dev_fsdp_int8_weight_gather():
    """ROADMAP item: 8-fake-device numerics run with weight_compress=int8
    and fsdp=True shardings — loss parity vs uncompressed within the
    int8 bound (previously only dry-run HLO inspection)."""
    r = _run_subprocess(FSDP_SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "FSDP_OK" in r.stdout


@pytest.mark.slow
def test_spmd_8dev_sharded_kv_codec():
    """Sharded KV serving: batch over 'data', cache seq over 'model',
    int8-block quantization under jit on the fake mesh and the cusz
    offload leg through the self-describing container."""
    r = _run_subprocess(KV_SHARD_SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "KV_SHARD_OK" in r.stdout


@pytest.mark.slow
def test_spmd_8dev_prefill_decode_reshard():
    """Acceptance (ISSUE 5 tentpole): prefill on a (4,2) data/model mesh,
    the caches cross to a differently-split (2,4) decode mesh as
    int8-block Containers (adopted directly as QuantKV, zero f32 round
    trip), and the generated tokens are identical to the single-mesh
    compressed path; the cusz offload leg decodes+requantizes under the
    decode mesh."""
    r = _run_subprocess(RESHARD_SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "RESHARD_OK" in r.stdout


@pytest.mark.slow
def test_spmd_8dev_elastic_sharded_checkpoint():
    """Acceptance: sharded+async save on an 8-fake-device mesh restores
    onto a differently-shaped mesh (elastic) bit-for-bit with the
    synchronous single-file path, per codec policy, and the restore
    moves containers (compressed payloads) rather than decoded f32."""
    r = _run_subprocess(ELASTIC_CKPT_SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout


@pytest.mark.slow
def test_spmd_8dev_straggler_mitigation_and_quarantine():
    """Acceptance (ISSUE 7 tentpole): on 8 fake devices, an injected slow
    host (real sleeps) is rebalanced by MitigationPolicy to within ~1.2x
    of the fault-free step time, and a corrupted checkpoint shard
    restores from the last good step with a quarantine report instead of
    raising."""
    r = _run_subprocess(RESILIENCE_SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "RESILIENCE_OK" in r.stdout


def test_mesh_constructors():
    from repro.launch.mesh import make_host_mesh
    m = make_host_mesh()
    assert dict(m.shape) == {"data": 1, "model": 1}
