"""Runtime-sanitizer tests (repro.debug.guards + the lint pytest plugin).

The acceptance contract from the static-analysis PR: the serve decode
loop compiles exactly once across repeated `generate` calls and runs
without implicit transfers; the checkpoint encode phase performs zero
host syncs outside statically waived sites; codec roundtrips are
sync-clean; deprecated shims warn exactly once per process.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import _compat, configs
from repro.core.compressor import CompressorConfig
from repro.debug import (HostSyncError, RecompileError, host_sync_guard,
                         no_recompiles)
from repro.models import model as M
from repro.serve import engine as E


# ---------------------------------------------------------------------------
# Guard mechanics (unit level)
# ---------------------------------------------------------------------------

class TestGuardMechanics:
    def test_no_recompiles_counts_and_raises(self, recompile_guard):
        @jax.jit
        def f2(x):
            return x * 3

        x = jnp.ones(16)
        with recompile_guard(max_compiles=1, match=r"^f2$") as log:
            f2(x)
            f2(x)                       # cached: no second compile
        assert log.compiles == ["f2"]

        with pytest.raises(RecompileError, match="no_recompiles"):
            with recompile_guard(max_compiles=0, match=r"^f3$"):
                @jax.jit
                def f3(x):
                    return x - 1
                f3(x)

    def test_host_sync_guard_attributes_library_syncs(self):
        from repro.core import compressor as CZ

        data = jnp.linspace(0.0, 1.0, 4096).reshape(64, 64)
        blob, _eb = CZ.compress(data, CompressorConfig())
        with pytest.raises(HostSyncError, match="compressor.py"):
            with host_sync_guard({}):   # empty allowlist: everything trips
                CZ.compressed_bytes(blob, CompressorConfig().nbins)

    def test_host_sync_guard_ignores_test_code_syncs(self):
        with host_sync_guard({}) as log:
            jax.device_get(jnp.ones(4))     # issued by the harness: fine
        assert log.violations == []


# ---------------------------------------------------------------------------
# Serve decode loop (pins the PR-5 STEP_TRACES fix under the sanitizer)
# ---------------------------------------------------------------------------

class TestServeUnderGuards:
    def test_serve_step_compiles_exactly_once(self, recompile_guard):
        cfg = configs.reduced("qwen3-4b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        # distinct s_max (multiple of the KV block): fresh jit cache key
        scfg = E.ServeConfig(s_max=256, compressed_kv=True)
        E.STEP_TRACES.pop((cfg, scfg), None)
        E.get_serve_step.cache_clear()
        with recompile_guard(max_compiles=1, match=r"^step$") as log:
            a = np.asarray(E.generate(params, cfg, prompt, 4, scfg))
            b = np.asarray(E.generate(params, cfg, prompt, 4, scfg))
        assert log.compiles == ["step"]     # compiled once, reused once
        assert E.STEP_TRACES[(cfg, scfg)] == 1
        np.testing.assert_array_equal(a, b)

    def test_decode_steady_state_zero_compiles(self, recompile_guard):
        cfg = configs.reduced("qwen3-4b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        scfg = E.ServeConfig(s_max=512, compressed_kv=True)
        E.generate(params, cfg, prompt, 4, scfg)          # warmup
        with recompile_guard(max_compiles=0, match=r"^step$"):
            E.generate(params, cfg, prompt, 6, scfg)      # longer decode


# ---------------------------------------------------------------------------
# Checkpoint encode + codec roundtrip under the host-sync sanitizer
# ---------------------------------------------------------------------------

class TestSyncCleanPaths:
    def test_checkpoint_encode_zero_unwaived_syncs(self, tmp_path,
                                                   host_sync_sanitizer):
        from repro.io import checkpoint as CK

        rng = np.random.default_rng(0)
        tree = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
                "b": jnp.asarray(rng.standard_normal((64,)), jnp.float32),
                "step": jnp.asarray(3, jnp.int32)}
        policy = CK.CheckpointPolicy(codec="cusz")
        with host_sync_sanitizer() as log:
            CK.save_checkpoint(str(tmp_path), 0, tree, policy=policy)
        assert log.violations == []
        # the boundary crossings that did happen are the waived ones
        assert log.allowed_hits

    def test_cusz_valid_sync_is_waived_on_restore_path(self,
                                                       host_sync_sanitizer):
        """`CuszCodec.valid` reads back one scalar (`n_outliers`) — a
        deliberate, statically waived host sync.  The restore-side
        validity check must stay inside that waiver: zero unwaived
        violations, and the sync that does happen hits the allowlist."""
        from repro import codecs

        x = jnp.linspace(-2.0, 2.0, 4096).reshape(32, 128)
        codec = codecs.get("cusz")
        c = codec.encode(x)
        with host_sync_sanitizer() as log:
            assert codec.valid(c)
        assert log.violations == []
        assert log.allowed_hits           # the waived device_get fired
        # packed containers are post-validation: no sync at all
        p = codec.pack(c)
        with host_sync_sanitizer() as log2:
            assert codec.valid(p)
        assert log2.violations == []

    def test_codec_roundtrip_sync_clean(self, host_sync_sanitizer):
        from repro import codecs

        x = jnp.linspace(-1.0, 1.0, 8192).reshape(64, 128)
        for name in ("int8-block", "cusz", "lossless"):
            codec = codecs.get(name)
            with host_sync_sanitizer() as log:
                c = codec.encode(x)
                y = codec.decode(c, like=x)
            assert log.violations == [], name
            y.block_until_ready()
            assert y.shape == x.shape


# ---------------------------------------------------------------------------
# Deprecated shims: exactly once per process
# ---------------------------------------------------------------------------

class TestWarnOnce:
    def _count(self, fn, *args, calls=3, **kw):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")     # defeat location dedup
            for _ in range(calls):
                fn(*args, **kw)
        return sum(issubclass(x.category, DeprecationWarning) for x in w)

    def test_kv_offload_shims_warn_once(self):
        from repro.core import kvcache as KVC

        _compat._WARNED.discard("kv_offload_pack")
        _compat._WARNED.discard("kv_offload_restore")
        cfg = CompressorConfig()
        x = jnp.linspace(0.0, 1.0, 1024).reshape(32, 32)
        assert self._count(KVC.kv_offload_pack, x, cfg) == 1
        packed, eb = KVC.kv_offload_pack(x, cfg)
        assert self._count(KVC.kv_offload_restore, packed, eb,
                           x.shape, cfg) == 1

    def test_gradient_shims_warn_once(self):
        from repro.core import gradient as G

        _compat._WARNED.discard("cusz_compress_gradient")
        cfg = CompressorConfig()
        g = jnp.linspace(0.0, 1.0, 1024).reshape(32, 32)
        assert self._count(G.cusz_compress_gradient, g, cfg) == 1

    def test_save_checkpoint_mode_warns_once(self, tmp_path):
        from repro.io import checkpoint as CK

        _compat._WARNED.discard("save_checkpoint-mode")
        tree = {"w": jnp.ones((8, 8), jnp.float32)}

        def legacy(i):
            CK.save_checkpoint(str(tmp_path), i, tree, mode="lossless")

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            legacy(0)
            legacy(1)
            legacy(2)
        assert sum(issubclass(x.category, DeprecationWarning)
                   for x in w) == 1
