"""Train-step semantics + serving engine tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.optim import adamw
from repro.serve.engine import ServeConfig, generate, prefill, make_serve_step
from repro.train.train_step import TrainConfig, make_train_step, loss_fn, \
    _microbatched_grads


class TestTrainStep:
    def test_microbatched_grads_match_full(self):
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)).astype(np.int32))
        t1 = TrainConfig(microbatches=1)
        t4 = TrainConfig(microbatches=4)
        l1, g1 = _microbatched_grads(params, cfg, t1, toks, None)
        l4, g4 = _microbatched_grads(params, cfg, t4, toks, None)
        assert abs(float(l1) - float(l4)) < 1e-4   # both return the mean
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-2, atol=2e-4)

    def test_step_reduces_loss(self):
        cfg = configs.reduced("qwen3-4b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        tcfg = TrainConfig(adamw=adamw.AdamWConfig(lr=5e-3))
        opt = adamw.init(params, tcfg.adamw)
        step = jax.jit(make_train_step(cfg, tcfg))
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, 64, (8, 64)).astype(np.int32))
        losses = []
        for _ in range(8):
            loss, params, opt = step(params, opt, toks)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_podded_layout_no_compress_flattens(self):
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(2), cfg)
        tcfg = TrainConfig(grad_compress="none", npods=2)
        opt = adamw.init(params, tcfg.adamw)
        step = jax.jit(make_train_step(cfg, tcfg))
        toks = jnp.zeros((2, 4, 32), jnp.int32)       # podded layout
        loss, params, opt = step(params, opt, toks)
        assert np.isfinite(float(loss))


class TestServe:
    def test_generate_greedy_deterministic(self):
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(3), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        scfg = ServeConfig(s_max=64)
        a = np.asarray(generate(params, cfg, prompt, 8, scfg))
        b = np.asarray(generate(params, cfg, prompt, 8, scfg))
        np.testing.assert_array_equal(a, b)

    def test_prefill_then_decode_matches_forward(self):
        """prefill caches + one decode step == teacher-forced logits."""
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(4), cfg)
        rng = np.random.default_rng(4)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 9)).astype(np.int32))
        scfg = ServeConfig(s_max=32, compute_dtype=jnp.float32)
        last, caches, plen = prefill(params, cfg, toks[:, :8], scfg)
        step = make_serve_step(cfg, ServeConfig(s_max=32,
                                                compute_dtype=jnp.float32))
        lg, _ = step(params, toks[:, 8:9], caches, jnp.int32(8))
        full, _ = M.forward(params, cfg, toks, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, -1]), rtol=2e-3,
                                   atol=2e-3)
        np.testing.assert_allclose(np.asarray(last),
                                   np.asarray(full[:, 7]), rtol=2e-3,
                                   atol=2e-3)

    def test_mamba_generate(self):
        cfg = configs.reduced("mamba2-1.3b", n_periods=2)
        params = M.init_params(jax.random.PRNGKey(5), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        toks = generate(params, cfg, prompt, 6, ServeConfig(s_max=32))
        assert toks.shape == (2, 6)

    def test_compressed_kv_serving(self):
        cfg = configs.reduced("qwen3-4b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(6), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        a = np.asarray(generate(params, cfg, prompt, 8,
                                ServeConfig(s_max=128)))
        b = np.asarray(generate(params, cfg, prompt, 8,
                                ServeConfig(s_max=128, compressed_kv=True)))
        assert (a == b).mean() > 0.6          # greedy mostly agrees
