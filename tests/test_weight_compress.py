"""int8 weight-gather compression (STE) tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import weights as W
from repro.models import model as M
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step


class TestWeightCompress:
    def test_qdq_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((16, 256)).astype(np.float32))
        y = W._qdq(x)
        # blockwise bound: |x - qdq(x)| <= blockmax/127/2 per block
        xb = np.asarray(x).reshape(16, 2, 128)
        bound = np.abs(xb).max(-1, keepdims=True) / 127.0 / 2 * 1.01 + 1e-12
        err = np.abs(np.asarray(y).reshape(16, 2, 128) - xb)
        assert (err <= bound).all()

    def test_ste_gradient_identity(self):
        """d loss/d master through compress_for_gather == through identity."""
        rng = np.random.default_rng(1)
        p = {"w_up": jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))}
        v = jnp.asarray(rng.standard_normal((128,)).astype(np.float32))

        def loss_c(params):
            q = W.compress_for_gather(params)
            return jnp.sum(jnp.tanh(q["w_up"] @ v))

        g = jax.grad(loss_c)(p)["w_up"]
        # STE: gradient computed at the quantized point, identity through
        # the quantizer — matches the analytic grad at qdq(w)
        wq = W._qdq(p["w_up"])
        ref = jax.grad(lambda w: jnp.sum(jnp.tanh(w @ v)))(wq)
        np.testing.assert_allclose(np.asarray(g), np.asarray(ref), rtol=1e-6)

    def test_norms_skipped(self):
        p = {"pre_norm": jnp.ones((128,)), "w_up": jnp.ones((128, 128))}
        q = W.compress_for_gather(p)
        np.testing.assert_array_equal(np.asarray(q["pre_norm"]),
                                      np.asarray(p["pre_norm"]))

    def test_training_still_converges(self):
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        tcfg = TrainConfig(weight_compress="int8",
                           adamw=adamw.AdamWConfig(lr=5e-3))
        opt = adamw.init(params, tcfg.adamw)
        step = jax.jit(make_train_step(cfg, tcfg))
        rng = np.random.default_rng(2)
        toks = jnp.asarray(rng.integers(0, 64, (8, 64)).astype(np.int32))
        losses = []
        for _ in range(8):
            loss, params, opt = step(params, opt, toks)
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        assert all(np.isfinite(l) for l in losses)
