"""Huffman stage tests: losslessness, canonical properties, build parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import huffman as hf


def _random_codes(rng, n, k, skew=2.0):
    """Zipf-ish distributed symbols (like quant codes around the radius)."""
    p = 1.0 / np.arange(1, k + 1) ** skew
    p /= p.sum()
    return rng.choice(k, size=n, p=p).astype(np.int32)


class TestTreeBuild:
    @pytest.mark.parametrize("k,n", [(16, 500), (256, 5000), (1024, 20000)])
    def test_device_matches_host_cost(self, k, n):
        """Two-queue device build is optimal iff its weighted codelength
        equals the heap oracle's (optimality is unique in cost)."""
        rng = np.random.default_rng(k)
        codes = _random_codes(rng, n, k)
        freq = np.bincount(codes, minlength=k).astype(np.int32)
        lh = hf.codeword_lengths_host(freq)
        ld = np.asarray(hf.codeword_lengths(jnp.asarray(freq)))
        assert (freq * lh).sum() == (freq * ld).sum()
        assert (ld[freq == 0] == 0).all() and (ld[freq > 0] > 0).all()

    def test_kraft_equality(self):
        """Optimal prefix code satisfies Kraft with equality."""
        rng = np.random.default_rng(7)
        freq = np.bincount(_random_codes(rng, 3000, 64), minlength=64)
        ld = np.asarray(hf.codeword_lengths(jnp.asarray(freq.astype(np.int32))))
        act = ld[ld > 0]
        assert abs(np.sum(2.0 ** -act) - 1.0) < 1e-9

    def test_single_symbol(self):
        freq = jnp.zeros(32, jnp.int32).at[5].set(100)
        ld = np.asarray(hf.codeword_lengths(freq))
        assert ld[5] == 1 and (np.delete(ld, 5) == 0).all()

    def test_two_symbols(self):
        freq = jnp.zeros(8, jnp.int32).at[1].set(10).at[6].set(90)
        ld = np.asarray(hf.codeword_lengths(freq))
        assert ld[1] == 1 and ld[6] == 1


class TestCanonical:
    def test_prefix_free(self):
        rng = np.random.default_rng(11)
        freq = np.bincount(_random_codes(rng, 10000, 128), minlength=128)
        cb = hf.canonical_codebook(hf.codeword_lengths(jnp.asarray(freq.astype(np.int32))))
        lens = np.asarray(cb.lengths); codes = np.asarray(cb.codes)
        act = np.nonzero(lens)[0]
        for i in act:
            for j in act:
                if i == j:
                    continue
                li, lj = lens[i], lens[j]
                if li <= lj and (codes[j] >> (lj - li)) == codes[i]:
                    pytest.fail(f"code {i} is a prefix of {j}")

    def test_lengths_preserved(self):
        """Canonization keeps bitwidths => identical ratio (paper §3.2.3)."""
        rng = np.random.default_rng(13)
        freq = np.bincount(_random_codes(rng, 8000, 64), minlength=64)
        ld = hf.codeword_lengths(jnp.asarray(freq.astype(np.int32)))
        cb = hf.canonical_codebook(ld)
        np.testing.assert_array_equal(np.asarray(cb.lengths), np.asarray(ld))

    def test_packed_codebook_u32(self):
        freq = jnp.asarray(np.bincount(_random_codes(np.random.default_rng(5), 1000, 16),
                                       minlength=16).astype(np.int32))
        cb = hf.canonical_codebook(hf.codeword_lengths(freq))
        packed = np.asarray(hf.packed_codebook(cb, 32))
        assert ((packed >> 26) == np.asarray(cb.lengths)).all()
        assert ((packed & ((1 << 26) - 1)) == np.asarray(cb.codes)).all()
        assert hf.select_repr(int(cb.max_len)) == 32


class TestRoundtrip:
    @pytest.mark.parametrize("k,n,chunk", [(64, 3000, 256), (1024, 20000, 1024),
                                           (256, 777, 128)])
    def test_lut_roundtrip(self, k, n, chunk):
        rng = np.random.default_rng(n)
        codes = _random_codes(rng, n, k)
        freq = hf.histogram(jnp.asarray(codes), k)
        cb = hf.canonical_codebook(hf.codeword_lengths(freq))
        cw, bw = hf.encode(jnp.asarray(codes), cb)
        words, bits, *_ = hf.deflate(cw, bw, chunk)
        nc = words.shape[0]
        n_valid = np.minimum(chunk, np.maximum(n - np.arange(nc) * chunk, 0)).astype(np.int32)
        out = np.asarray(hf.inflate_lut(words, jnp.asarray(n_valid), cb))
        np.testing.assert_array_equal(out.reshape(-1)[:n], codes)

    def test_bitscan_roundtrip(self):
        rng = np.random.default_rng(99)
        codes = _random_codes(rng, 600, 32)
        freq = hf.histogram(jnp.asarray(codes), 32)
        cb = hf.canonical_codebook(hf.codeword_lengths(freq))
        cw, bw = hf.encode(jnp.asarray(codes), cb)
        words, bits, *_ = hf.deflate(cw, bw, 128)
        nc = words.shape[0]
        n_valid = np.minimum(128, np.maximum(600 - np.arange(nc) * 128, 0)).astype(np.int32)
        out = np.asarray(hf.inflate_bitscan(words, bits, jnp.asarray(n_valid), cb))
        np.testing.assert_array_equal(out.reshape(-1)[:600], codes)

    @given(st.integers(0, 2**31 - 1), st.integers(2, 64),
           st.sampled_from([64, 128, 256]))
    @settings(max_examples=25, deadline=None)
    def test_property_lossless(self, seed, k, chunk):
        """Huffman stage is bit-exact lossless for arbitrary symbol streams."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 2000))
        codes = rng.integers(0, k, n).astype(np.int32)
        freq = hf.histogram(jnp.asarray(codes), k)
        cb = hf.canonical_codebook(hf.codeword_lengths(freq))
        cw, bw = hf.encode(jnp.asarray(codes), cb)
        words, bits, *_ = hf.deflate(cw, bw, chunk)
        nc = words.shape[0]
        n_valid = np.minimum(chunk, np.maximum(n - np.arange(nc) * chunk, 0)).astype(np.int32)
        out = np.asarray(hf.inflate(words, bits, jnp.asarray(n_valid), cb,
                                    int(cb.max_len)))
        np.testing.assert_array_equal(out.reshape(-1)[:n], codes)

    def test_deflate_bits_accounting(self):
        """bits_used must equal the sum of encoded bitwidths per chunk."""
        rng = np.random.default_rng(3)
        codes = _random_codes(rng, 1000, 32)
        freq = hf.histogram(jnp.asarray(codes), 32)
        cb = hf.canonical_codebook(hf.codeword_lengths(freq))
        cw, bw = hf.encode(jnp.asarray(codes), cb)
        words, bits, *_ = hf.deflate(cw, bw, 256)
        bwn = np.asarray(bw)
        for c in range(words.shape[0]):
            seg = bwn[c * 256:(c + 1) * 256]
            assert int(bits[c]) == int(seg.sum())


# ---------------------------------------------------------------------------
# Gap-array two-phase decode (Rivera et al., arXiv 2201.09118)
# ---------------------------------------------------------------------------

def _skewed_codes(rng, n, k):
    """Exponentially-skewed stream -> deep tree (bitscan regime)."""
    p = 2.0 ** -np.arange(1, k + 1)
    p /= p.sum()
    return rng.choice(k, size=n, p=p).astype(np.int32)


def _encode_stream(codes, k, chunk, sub):
    freq = hf.histogram(jnp.asarray(codes), k)
    cb = hf.canonical_codebook(hf.codeword_lengths(freq))
    cw, bw = hf.encode(jnp.asarray(codes), cb)
    words, bits, gap_bits, gap_syms = hf.deflate(cw, bw, chunk, sub)
    nc = words.shape[0]
    n = codes.shape[0]
    n_valid = jnp.asarray(np.minimum(
        chunk, np.maximum(n - np.arange(nc) * chunk, 0)).astype(np.int32))
    return cb, words, bits, n_valid, gap_bits, gap_syms


class TestGapDecode:
    @given(st.integers(0, 2**31 - 1), st.sampled_from([128, 256, 512]),
           st.sampled_from([32, 64, 128]), st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_parity_vs_sequential(self, seed, chunk, sub, deep):
        """Gap decode (jax AND pallas-interpret) is bit-exact with the
        sequential reference across chunk/sub sizes and both the LUT and
        bitscan max-codeword-length regimes."""
        from repro.kernels.inflate import ops as inflate_ops
        sub = min(sub, chunk)
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 3000))
        k = 24 if deep else 64
        codes = (_skewed_codes(rng, n, k) if deep
                 else rng.integers(0, k, n).astype(np.int32))
        cb, words, bits, n_valid, gap_bits, _ = _encode_stream(
            codes, k, chunk, sub)
        ml = hf.bucket_max_len(max(1, int(cb.max_len)))
        table = hf.decode_table(cb.lengths, ml)
        seq = np.asarray(hf.inflate(words, bits, n_valid, cb, ml))
        gj = np.asarray(hf.inflate_gap(words, n_valid, gap_bits, table,
                                       sub, ml))
        gp = np.asarray(inflate_ops.inflate(
            words, bits, n_valid, table, ml, gaps=gap_bits,
            impl="pallas-interpret"))
        np.testing.assert_array_equal(gj, seq)
        np.testing.assert_array_equal(gp, seq)
        np.testing.assert_array_equal(gj.reshape(-1)[:n], codes)

    def test_gap_arrays_match_prefix_sums(self):
        """gap_bits samples the exclusive bit prefix-sum, gap_syms the
        exclusive valid-symbol count, at every sub boundary."""
        rng = np.random.default_rng(17)
        codes = _random_codes(rng, 1000, 64)
        cb, words, bits, n_valid, gap_bits, gap_syms = _encode_stream(
            codes, 64, 256, 64)
        cw, bw = hf.encode(jnp.asarray(codes), cb)
        bwn = np.asarray(bw)
        pad = words.shape[0] * 256 - bwn.shape[0]
        bwn = np.pad(bwn, (0, pad)).reshape(-1, 256)
        for c in range(words.shape[0]):
            offs = np.cumsum(bwn[c]) - bwn[c]
            np.testing.assert_array_equal(np.asarray(gap_bits)[c],
                                          offs[::64])
            valid = (bwn[c] > 0).astype(np.int64)
            vcnt = np.cumsum(valid) - valid
            np.testing.assert_array_equal(np.asarray(gap_syms)[c],
                                          vcnt[::64])

    def test_sub_size_must_divide_chunk(self):
        with pytest.raises(ValueError, match="divide"):
            hf.norm_sub_size(512, 100)
        assert hf.norm_sub_size(512, 64) == 64
        assert hf.norm_sub_size(32, 64) == 32    # clamped to the chunk

    def test_bucket_max_len(self):
        assert hf.bucket_max_len(1) == 8
        assert hf.bucket_max_len(8) == 8
        assert hf.bucket_max_len(9) == 12
        assert hf.bucket_max_len(13) == 16
        assert hf.bucket_max_len(17) == hf.MAXLEN

    def test_decode_table_cache_identity(self):
        """Same lengths array -> same cached table object; a fresh array
        (even equal-valued) builds its own entry."""
        freq = hf.histogram(jnp.asarray(_random_codes(
            np.random.default_rng(2), 500, 32)), 32)
        lengths = hf.codeword_lengths(freq)
        t1 = hf.decode_table(lengths, 8)
        t2 = hf.decode_table(lengths, 8)
        assert t1 is t2
        t3 = hf.decode_table(jnp.array(lengths), 8)
        assert t3 is not t1
        np.testing.assert_array_equal(np.asarray(t3.lut_sym),
                                      np.asarray(t1.lut_sym))
