"""Property-based hardening of the whole codec registry.

For EVERY id in `codecs.names()` (so a newly registered codec is covered
the day it lands), generated shapes/dtypes/error bounds must satisfy:

  * decode(encode(x)) stays within the codec's a-priori error bound
    (exact for lossless; scale/2 for the int family; header eb for cusz;
    zfp makes no a-priori claim and is bound-exempt);
  * pack -> unpack is an inverse: decoding the device form, the packed
    storage form, and the unpacked form are all bit-identical;
  * `stored_nbytes` is a pack-invariant, positive storage accounting;
  * the container header is faithful: codec id/dtype/shape match the
    source, and it survives the JSON manifest bridge byte-for-byte.

Runs under real `hypothesis` or the deterministic conftest shim
(offline containers) unchanged.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import codecs

# small fixed shape pool keeps the jit cache bounded across examples;
# last dims are multiples of 16 (the int8-block config used here)
SHAPES = ((4, 32), (3, 48), (96,), (2, 4, 32))
DTYPES = ("float32", "bfloat16")
BLOCK = 16


def _make(name: str, eb: float) -> codecs.Codec:
    """A configured instance per registry id; defaults for ids this file
    doesn't know (future codecs still get the full property sweep)."""
    if name == "cusz":
        return codecs.get("cusz", eb=eb, eb_mode="valrel", chunk_size=256,
                          outlier_frac=1.0)
    if name in ("cusz-i", "fz"):
        # the staged-pipeline codecs: same bound discipline as cusz
        # (full outlier capacity so the bound always holds)
        return codecs.get(name, eb=eb, eb_mode="valrel", chunk_size=256,
                          outlier_frac=1.0)
    if name == "int8-block":
        return codecs.get("int8-block", axis=-1, block=BLOCK)
    if name == "zfp":
        return codecs.get("zfp", rate_bits=14)
    return codecs.get(name)


def _data(shape, dtype: str, seed: int):
    """Smooth (Lorenzo-predictable) field with nonzero range."""
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal(shape, dtype=np.float64),
                  axis=-1).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


def _f32(a) -> np.ndarray:
    return np.asarray(jnp.asarray(a).astype(jnp.float32))


def _tolerance(name: str, cont, x32: np.ndarray, dtype: str):
    """A-priori per-element bound, or None when the codec claims none."""
    bf16_round = np.abs(x32).max() * 2.0 ** -7 if dtype == "bfloat16" else 0.0
    if name == "lossless":
        return bf16_round          # exact up to the storage dtype itself
    if name in ("int8", "int16"):
        scale = float(np.asarray(cont.payload["scale"]))
        return scale / 2 * 1.001 + bf16_round
    if name == "int8-block":
        scale = np.asarray(cont.payload["scale"])
        per_elem = np.repeat(scale, BLOCK, axis=-1) / 2
        return per_elem * 1.001 + bf16_round + 1e-12
    if name in ("cusz", "cusz-i", "fz"):
        return float(cont.header.param("eb")) * 1.001 + bf16_round + 1e-12
    return None                    # zfp / unknown: no a-priori bound


@pytest.mark.parametrize("name", codecs.names())
@given(st.sampled_from(SHAPES), st.sampled_from(DTYPES),
       st.floats(1e-4, 5e-3), st.integers(0, 10 ** 6))
@settings(max_examples=5, deadline=None)
def test_roundtrip_within_bound(name, shape, dtype, eb, seed):
    codec = _make(name, eb)
    x = _data(shape, dtype, seed)
    cont = codec.encode(x)
    assert codec.valid(cont)
    y = codecs.decode(cont) if name != "cusz" else codec.decode(cont)
    assert tuple(y.shape) == tuple(x.shape)
    assert y.dtype == x.dtype      # header dtype honored, bf16 included
    tol = _tolerance(name, cont, _f32(x), dtype)
    if tol is not None:
        err = np.abs(_f32(x) - _f32(y))
        assert (err <= tol).all(), float(np.max(err - tol))


@pytest.mark.parametrize("name", codecs.names())
@given(st.sampled_from(SHAPES), st.sampled_from(DTYPES),
       st.integers(0, 10 ** 6))
@settings(max_examples=4, deadline=None)
def test_pack_unpack_inverse_and_storage(name, shape, dtype, seed):
    codec = _make(name, 1e-3)
    x = _data(shape, dtype, seed)
    cont = codec.encode(x)
    packed = codec.pack(cont)
    assert packed.header.param("packed")
    assert codec.pack(packed) is packed            # pack is idempotent
    unpacked = codec.unpack(packed)
    assert not unpacked.header.param("packed")
    ys = [np.asarray(codec.decode(c).astype(jnp.float32))
          for c in (cont, packed, unpacked)]
    np.testing.assert_array_equal(ys[0], ys[1])
    np.testing.assert_array_equal(ys[0], ys[2])
    # storage accounting: positive and invariant under pack
    n = codec.stored_nbytes(cont)
    assert n > 0 and n == codec.stored_nbytes(packed)
    # packed payload must be host arrays (npz-writable)
    for v in packed.payload.values():
        assert isinstance(v, np.ndarray) or np.isscalar(np.asarray(v)[()])


@pytest.mark.parametrize("name", codecs.names())
@given(st.sampled_from(SHAPES), st.sampled_from(DTYPES),
       st.integers(0, 10 ** 6))
@settings(max_examples=4, deadline=None)
def test_container_header_fidelity(name, shape, dtype, seed):
    codec = _make(name, 1e-3)
    x = _data(shape, dtype, seed)
    cont = codec.encode(x)
    h = cont.header
    assert h.codec == codec.name == name
    assert h.version == codec.version
    assert h.dtype == np.dtype(jnp.asarray(x).dtype).name
    assert h.shape == tuple(x.shape)
    # JSON manifest bridge: header and payload survive to_arrays /
    # from_arrays plus a real json round-trip, and decode bit-identically
    hdr_json, fields = codecs.to_arrays(codec.pack(cont))
    rebuilt = codecs.from_arrays(json.loads(json.dumps(hdr_json)), fields)
    assert rebuilt.header == codec.pack(cont).header
    a = np.asarray(codecs.decode(rebuilt).astype(jnp.float32))
    b = np.asarray(codec.decode(cont).astype(jnp.float32))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", codecs.names())
@given(st.sampled_from(SHAPES), st.sampled_from(DTYPES),
       st.integers(0, 10 ** 6))
@settings(max_examples=4, deadline=None)
def test_checksum_roundtrips_and_byte_flip_always_detected(name, shape,
                                                          dtype, seed):
    """Integrity property for every registered codec: pack stamps a
    payload crc32 that (a) verifies on the untouched container, (b)
    survives the JSON manifest bridge, (c) catches any single flipped
    payload byte, and (d) never leaks into the unpacked device header
    (which is a jit cache key)."""
    from repro.dist import chaos
    codec = _make(name, 1e-3)
    packed = codec.pack(codec.encode(_data(shape, dtype, seed)))
    assert packed.header.param("checksum") is not None
    assert codecs.verify_container(packed)
    codecs.check_container(packed)               # no raise
    hdr_json, fields = codecs.to_arrays(packed)
    rebuilt = codecs.from_arrays(json.loads(json.dumps(hdr_json)), fields)
    assert codecs.verify_container(rebuilt)
    bad = chaos.corrupt_container(packed, seed=seed)
    assert not codecs.verify_container(bad)
    with pytest.raises(codecs.ChecksumError):
        codecs.check_container(bad)
    with pytest.raises(codecs.ChecksumError):
        codecs.decode(bad, verify=True)
    assert codec.unpack(packed).header.param("checksum") is None


def test_every_registered_codec_has_default_instance():
    """`codecs.get(name)` must work kwarg-free for every id — the
    checkpoint loader relies on it to decode any manifest."""
    for name in codecs.names():
        codec = codecs.get(name)
        assert codec.version >= 1 and isinstance(codec.name, str)
