"""Kernel-dispatch subsystem tests: policy resolution (env var, context,
config, explicit arg), pallas(interpret) vs reference parity across
1D/2D/3D blocks, odd (padded) shapes and both block tables, and full
compressor roundtrips under a forced-pallas policy (bit-exact with the
reference pipeline on CPU)."""
import dataclasses
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
import pytest

from repro.core import compressor as C, dualquant as dq, gradient as G, \
    huffman as hf, kvcache as KV, weights as W
from repro.io import checkpoint as CK
from repro.kernels import dispatch
from repro.kernels.deflate import ops as deflate_ops
from repro.kernels.encode import ops as encode_ops
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.inflate import ops as inflate_ops
from repro.kernels.lorenzo import ops as lorenzo_ops


# ---------------------------------------------------------------------------
# Policy resolution
# ---------------------------------------------------------------------------

class TestPolicyResolution:
    def test_registry_covers_pipeline(self):
        reg = dispatch.registered()
        for stage in dispatch.PIPELINE_STAGES:
            assert stage in reg, stage
        # gap-array two-phase decode gave inflate a real pallas impl
        assert reg["inflate"] == ("jax", "pallas")

    def test_auto_is_reference_on_cpu(self):
        assert jax.default_backend() == "cpu"
        assert dispatch.resolve("lorenzo.dualquant") == \
            dispatch.Resolved("jax", False)

    def test_forced_pallas_interprets_on_cpu(self):
        r = dispatch.resolve("histogram", impl="pallas")
        assert r == dispatch.Resolved("pallas", True)

    def test_pallas_interpret_choice(self):
        r = dispatch.resolve("deflate", impl="pallas-interpret")
        assert r == dispatch.Resolved("pallas", True)

    def test_explicit_pallas_on_jax_only_raises(self):
        # the jax-only protocol outlived inflate's graduation to a real
        # pallas impl; exercise it on a synthetic registration
        dispatch.register("testonly.seq", impls=("jax",),
                          jax_only_reason="synthetic: protocol test")
        try:
            # an explicit per-call request must not silently measure the
            # reference path; the error carries the declared reason
            with pytest.raises(NotImplementedError, match="synthetic"):
                dispatch.resolve("testonly.seq", impl="pallas")
        finally:
            dispatch._REGISTRY.pop("testonly.seq", None)
            dispatch._JAX_ONLY_REASON.pop("testonly.seq", None)

    def test_ambient_pallas_on_jax_only_falls_back(self):
        # forwarded policy/config impls keep the documented fallback so a
        # forced pipeline never crashes on a jax-only stage
        dispatch.register("testonly.seq", impls=("jax",),
                          jax_only_reason="synthetic: protocol test")
        try:
            with dispatch.kernel_policy("pallas"):
                assert dispatch.resolve("testonly.seq") == \
                    dispatch.Resolved("jax", False)
            assert dispatch.resolve("testonly.seq", "pallas",
                                    explicit=False) == \
                dispatch.Resolved("jax", False)
        finally:
            dispatch._REGISTRY.pop("testonly.seq", None)
            dispatch._JAX_ONLY_REASON.pop("testonly.seq", None)

    def test_jax_only_reason_recorded(self):
        dispatch.register("testonly.seq", impls=("jax",),
                          jax_only_reason="synthetic: protocol test")
        try:
            assert "synthetic" in dispatch.jax_only_reason("testonly.seq")
        finally:
            dispatch._REGISTRY.pop("testonly.seq", None)
            dispatch._JAX_ONLY_REASON.pop("testonly.seq", None)
        assert dispatch.jax_only_reason("histogram") is None
        assert dispatch.jax_only_reason("inflate") is None   # graduated

    def test_explicit_pallas_inflate_without_gaps_raises(self):
        # the pallas inflate IS the gap decoder: explicitly requesting it
        # on a gap-less (format v1) stream must raise, not silently
        # measure the sequential reference
        words = jnp.zeros((1, 64), jnp.uint32)
        table = hf.decode_table(
            hf.codeword_lengths(jnp.asarray([5, 5], jnp.int32)), 8)
        with pytest.raises(NotImplementedError, match="gap"):
            inflate_ops.inflate(words, jnp.zeros((1,), jnp.int32),
                                jnp.zeros((1,), jnp.int32), table, 8,
                                impl="pallas-interpret")

    def test_env_var_policy(self, monkeypatch):
        monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
        assert dispatch.resolve("encode") == dispatch.Resolved("pallas", True)

    def test_context_overrides_env(self, monkeypatch):
        monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
        with dispatch.kernel_policy("jax"):
            assert dispatch.resolve("encode") == \
                dispatch.Resolved("jax", False)
        assert dispatch.resolve("encode") == dispatch.Resolved("pallas", True)

    def test_explicit_arg_wins_over_context(self):
        with dispatch.kernel_policy("pallas-interpret"):
            assert dispatch.resolve("histogram", impl="jax") == \
                dispatch.Resolved("jax", False)

    def test_per_kernel_override_and_prefix(self):
        with dispatch.kernel_policy(
                "jax", overrides={"histogram": "pallas-interpret",
                                  "lorenzo": "pallas-interpret"}):
            assert dispatch.resolve("histogram").impl == "pallas"
            assert dispatch.resolve("lorenzo.dualquant").impl == "pallas"
            assert dispatch.resolve("lorenzo.reverse").impl == "pallas"
            assert dispatch.resolve("deflate").impl == "jax"

    def test_pipeline_policy_from_config_default(self):
        pp = dispatch.pipeline_policy("pallas-interpret")
        for stage in ("dualquant", "reverse", "histogram", "encode",
                      "deflate", "inflate"):
            assert getattr(pp, stage) == dispatch.Resolved("pallas", True)

    def test_ambient_beats_config_default(self):
        with dispatch.kernel_policy("jax"):
            pp = dispatch.pipeline_policy("pallas-interpret")
        assert pp.dualquant == dispatch.Resolved("jax", False)

    def test_invalid_impl_rejected(self):
        with pytest.raises(ValueError):
            dispatch.resolve("histogram", impl="cuda")
        with pytest.raises(KeyError):
            dispatch.resolve("not-a-kernel")
        with pytest.raises(ValueError):
            dispatch.KernelPolicy.make("jax", {"histogram": "wat"})


# ---------------------------------------------------------------------------
# Parity: pallas(interpret) == reference, odd shapes, both block tables
# ---------------------------------------------------------------------------

ODD_CASES = [
    # (shape, use_tpu_blocks) — shapes chosen NOT to divide the blocks so
    # the edge-replicate padding path is exercised
    ((1000,), False),
    ((5000,), True),
    ((37, 53), False),
    ((65, 130), True),
    ((11, 13, 17), False),
    ((9, 17, 130), True),
]


def _field(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.cumsum(rng.standard_normal(shape), axis=-1)
                       .astype(np.float32))


class TestParity:
    @pytest.mark.parametrize("shape,tpu", ODD_CASES)
    def test_dualquant_and_reverse(self, shape, tpu):
        table = dq.TPU_BLOCKS if tpu else dq.DEFAULT_BLOCKS
        block = table[len(shape)]
        xb = dq.block_split(dq.pad_to_blocks(_field(shape), block), block)
        ck, dk = lorenzo_ops.dualquant_blocks(xb, 1e-3, 1024,
                                              impl="pallas-interpret")
        cr, dr = lorenzo_ops.dualquant_blocks(xb, 1e-3, 1024, impl="jax")
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
        rk = lorenzo_ops.reverse_blocks(dk, 1e-3, impl="pallas-interpret")
        rr = lorenzo_ops.reverse_blocks(dr, 1e-3, impl="jax")
        np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))

    @pytest.mark.parametrize("n", [100, 4096, 10001])
    def test_histogram(self, n):
        rng = np.random.default_rng(n)
        codes = jnp.asarray(rng.integers(0, 512, n).astype(np.int32))
        hk = hist_ops.histogram(codes, 512, impl="pallas-interpret")
        hr = hist_ops.histogram(codes, 512, impl="jax")
        np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))

    @pytest.mark.parametrize("n,k", [(777, 64), (3000, 1024)])
    def test_encode_and_deflate(self, n, k):
        rng = np.random.default_rng(n + k)
        codes = jnp.asarray(rng.integers(0, k, n).astype(np.int32))
        cb = hf.canonical_codebook(hf.codeword_lengths(
            hf.histogram(codes, k)))
        ck, bk = encode_ops.encode(codes, cb, impl="pallas-interpret")
        cr, br = encode_ops.encode(codes, cb, impl="jax")
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
        wk, ik, gbk, gsk = deflate_ops.deflate(ck, bk, 512,
                                               impl="pallas-interpret")
        wr, ir, gbr, gsr = deflate_ops.deflate(cr, br, 512, impl="jax")
        np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(gbk), np.asarray(gbr))
        np.testing.assert_array_equal(np.asarray(gsk), np.asarray(gsr))

    def test_fused_matches_unfused_reference(self):
        """The fused kernels-op output == the two-dispatch reference form
        the compressor used before the dispatch refactor."""
        x = _field((37, 53), seed=9)
        block = dq.DEFAULT_BLOCKS[2]
        xb = dq.block_split(dq.pad_to_blocks(x, block), block)
        cf, df = lorenzo_ops.dualquant_blocks(xb, 1e-3, 1024, impl="jax")
        du = dq.blocked_delta(x, 1e-3, block)
        cu, _ = dq.postquant_codes(du, 1024)
        np.testing.assert_array_equal(np.asarray(df), np.asarray(du))
        np.testing.assert_array_equal(np.asarray(cf), np.asarray(cu))


# ---------------------------------------------------------------------------
# Full-pipeline roundtrips under forced policy
# ---------------------------------------------------------------------------

ROUNDTRIP_SHAPES = [(2000,), (49, 61), (9, 13, 21)]


class TestForcedPallasRoundtrip:
    @pytest.mark.parametrize("shape", ROUNDTRIP_SHAPES)
    def test_bitexact_vs_reference(self, shape):
        f = _field(shape, seed=len(shape))
        base = C.CompressorConfig(eb=1e-3, eb_mode="valrel", chunk_size=512,
                                  kernel_impl="jax")
        forced = dataclasses.replace(base, kernel_impl="pallas-interpret")
        blob_r, eb_r = C.compress(f, base)
        blob_p, eb_p = C.compress(f, forced)
        assert eb_r == eb_p
        for a, b in zip(blob_r, blob_p):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        rec_r = C.decompress(blob_r, base, eb_r, shape)
        rec_p = C.decompress(blob_p, forced, eb_p, shape)
        np.testing.assert_array_equal(np.asarray(rec_r), np.asarray(rec_p))

    def test_context_policy_roundtrip_bound_held(self):
        from repro.core import metrics as M
        f = _field((63, 70), seed=7)
        cfg = C.CompressorConfig(eb=1e-3, eb_mode="valrel", chunk_size=512)
        with dispatch.kernel_policy("pallas-interpret"):
            recon, blob, eb, ratio = C.roundtrip(f, cfg)
        assert M.verify_error_bound(f, recon, eb)
        recon_ref, *_ = C.roundtrip(f, dataclasses.replace(
            cfg, kernel_impl="jax"))
        np.testing.assert_array_equal(np.asarray(recon),
                                      np.asarray(recon_ref))


# ---------------------------------------------------------------------------
# Vectorized pack/unpack
# ---------------------------------------------------------------------------

class TestPackUnpack:
    def test_many_chunk_roundtrip(self):
        f = _field((40000,), seed=3)
        cfg = C.CompressorConfig(eb=1e-3, eb_mode="valrel", chunk_size=512)
        blob, eb = C.compress(f, cfg)
        assert blob.words.shape[0] > 10        # many chunks: vectorized path
        d = C.pack_blob(blob)
        blob2 = C.unpack_blob(d)
        # unused outlier slots use different (equally out-of-range, both
        # scatter-dropped) fill values on the two sides; compare the
        # meaningful prefix + every dense field exactly
        n_out = int(blob.n_outliers)
        for fld in ("words", "bits_used", "n_valid", "lengths",
                    "n_outliers", "max_len", "gap_bits", "gap_syms"):
            np.testing.assert_array_equal(
                np.asarray(getattr(blob, fld)),
                np.asarray(getattr(blob2, fld)), err_msg=fld)
        np.testing.assert_array_equal(np.asarray(blob.out_idx[:n_out]),
                                      np.asarray(blob2.out_idx[:n_out]))
        np.testing.assert_array_equal(np.asarray(blob.out_val[:n_out]),
                                      np.asarray(blob2.out_val[:n_out]))
        rec = C.decompress(blob2, cfg, eb, tuple(f.shape))
        rec0 = C.decompress(blob, cfg, eb, tuple(f.shape))
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(rec0))

    def test_packed_words_match_used_words(self):
        f = _field((3000,), seed=4)
        cfg = C.CompressorConfig(eb=1e-3, eb_mode="valrel", chunk_size=256)
        blob, _ = C.compress(f, cfg)
        d = C.pack_blob(blob)
        bits = np.asarray(blob.bits_used, np.int64)
        words = np.asarray(blob.words)
        manual = np.concatenate([words[c, : (bits[c] + 31) // 32]
                                 for c in range(words.shape[0])])
        np.testing.assert_array_equal(d["words_packed"], manual)


# ---------------------------------------------------------------------------
# resolve_eb: one fused reduction, one transfer
# ---------------------------------------------------------------------------

class TestResolveEb:
    def test_values_unchanged(self):
        f = _field((500,), seed=5)
        cfg = C.CompressorConfig(eb=1e-3, eb_mode="valrel")
        eb = C.resolve_eb(cfg, f)
        rng = float(np.max(np.asarray(f)) - np.min(np.asarray(f)))
        assert eb == pytest.approx(1e-3 * rng, rel=1e-6)
        assert C.resolve_eb(C.CompressorConfig(eb=0.5, eb_mode="abs"), f) \
            == 0.5

    def test_domain_guard_still_raises(self):
        f = jnp.asarray(np.array([0.0, 3.0e7], np.float32))
        with pytest.raises(ValueError):
            C.resolve_eb(C.CompressorConfig(eb=1e-3, eb_mode="abs"), f)


# ---------------------------------------------------------------------------
# Consumers thread the policy through CompressorConfig
# ---------------------------------------------------------------------------

class TestConsumers:
    def test_gradient_blob_roundtrip_forced_policy(self):
        g = _field((40, 130), seed=11) * 1e-3
        cfg = C.CompressorConfig(eb=1e-5, eb_mode="valrel", chunk_size=512,
                                 outlier_frac=1.0,
                                 kernel_impl="pallas-interpret")
        packed, eb = G.cusz_compress_gradient(g, cfg)
        out = G.cusz_decompress_gradient(packed, eb, g.shape, cfg)
        from repro.core import metrics as M
        assert M.verify_error_bound(g, out, eb)

    def test_kv_offload_roundtrip(self):
        x = _field((4, 256, 8), seed=12).astype(jnp.float32)
        cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel", chunk_size=512,
                                 outlier_frac=1.0, kernel_impl="jax")
        packed, eb = KV.kv_offload_pack(x, cfg)
        out = KV.kv_offload_restore(packed, eb, x.shape, cfg,
                                    dtype=jnp.float32)
        assert float(jnp.max(jnp.abs(out - x))) <= eb * (1 + 1e-4) + 1e-9

    def test_checkpoint_kernel_impl_roundtrip(self):
        rng = np.random.default_rng(13)
        tree = {"w": np.cumsum(rng.standard_normal((64, 128)), axis=-1)
                .astype(np.float32),
                "b": rng.standard_normal((8,)).astype(np.float32)}
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 1, tree, mode="cusz", eb_valrel=1e-4,
                               kernel_impl="pallas-interpret")
            out, step = CK.load_checkpoint(
                d, jax.tree.map(jnp.asarray, tree),
                kernel_impl="pallas-interpret")
        assert step == 1
        np.testing.assert_allclose(np.asarray(out["b"]), tree["b"],
                                   rtol=0, atol=0)
        mx = float(np.max(tree["w"]) - np.min(tree["w"]))
        np.testing.assert_allclose(np.asarray(out["w"]), tree["w"],
                                   atol=1.1e-4 * mx)

    def test_weights_codec_config_carries_policy(self):
        cfg = W.checkpoint_codec_config(1e-5, kernel_impl="jax")
        assert cfg.kernel_impl == "jax"
        assert cfg.eb_mode == "valrel" and cfg.use_tpu_blocks
