"""Suite-wide pytest config.

1. Registers the ``slow`` marker used by the multi-device SPMD test.
2. Installs a deterministic fallback shim for ``hypothesis`` when the
   real package is unavailable (offline CI containers): the property
   tests then run their example-based paths against a fixed, per-test
   seeded stream instead of being collection errors.  With the real
   package installed the shim never activates.
"""
from __future__ import annotations

import sys

# guard fixtures (recompile_guard, host_sync_sanitizer, ...) live next to
# the linter so the waiver allowlist and the runtime allowlist stay one
# artifact; `tools` resolves via pythonpath = ["src", "."] in pyproject
pytest_plugins = ("tools.lint.pytest_plugin",)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (multi-device subprocess runs)")


# ---------------------------------------------------------------------------
# hypothesis fallback shim
# ---------------------------------------------------------------------------

def _install_hypothesis_shim():
    import functools
    import inspect
    import random
    import types
    import zlib

    class Strategy:
        """Deterministic value source.  ``example(rng, i)`` returns a
        boundary value for i == 0 and a pseudo-random draw otherwise."""

        def __init__(self, boundary, draw):
            self._boundary = boundary
            self._draw = draw

        def example(self, rng, i):
            return self._boundary() if i == 0 else self._draw(rng)

    def integers(min_value=None, max_value=None):
        lo = -2**63 if min_value is None else int(min_value)
        hi = 2**63 - 1 if max_value is None else int(max_value)
        return Strategy(lambda: lo, lambda rng: rng.randint(lo, hi))

    def floats(min_value=None, max_value=None, **_kw):
        lo = 0.0 if min_value is None else float(min_value)
        hi = 1.0 if max_value is None else float(max_value)
        return Strategy(lambda: lo, lambda rng: rng.uniform(lo, hi))

    def sampled_from(elements):
        elems = list(elements)
        return Strategy(lambda: elems[0],
                        lambda rng: elems[rng.randrange(len(elems))])

    def booleans():
        return sampled_from([False, True])

    def just(value):
        return Strategy(lambda: value, lambda rng: value)

    def settings(*_args, **kwargs):
        def deco(fn):
            fn._shim_max_examples = kwargs.get("max_examples", 10)
            return fn
        return deco

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    def given(*strats):
        def deco(fn):
            n_examples = getattr(fn, "_shim_max_examples", 10)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep = params[:len(params) - len(strats)]
            # the trailing params are strategy-bound; fill them by NAME so
            # pytest passing fixtures/parametrize args as kwargs still works
            strat_names = [p.name for p in params[len(params) - len(strats):]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n_examples):
                    vals = tuple(s.example(rng, i) for s in strats)
                    try:
                        fn(*args, **kwargs,
                           **dict(zip(strat_names, vals)))
                    except Exception as e:
                        raise AssertionError(
                            f"hypothesis-shim falsifying example "
                            f"#{i}: {vals!r}") from e

            # hide the strategy-bound params from pytest's fixture
            # resolution (like real hypothesis does)
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco

    def assume(condition):
        return bool(condition)

    hyp = types.ModuleType("hypothesis")
    hyp.__shim__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [("integers", integers), ("floats", floats),
                      ("sampled_from", sampled_from), ("booleans", booleans),
                      ("just", just)]:
        setattr(st_mod, name, obj)
    hyp.strategies = st_mod
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow="too_slow", data_too_large="data_too_large",
        filter_too_much="filter_too_much")
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
