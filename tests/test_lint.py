"""Golden-file tests for the repro-lint rule engine (tools/lint).

Each rule has a must-flag and a must-pass fixture under
``tests/lint_fixtures/``; the suite also pins waiver-pragma semantics,
JSON-report stability, the CLI exit-code contract, and — as the in-repo
gate — that ``src/`` itself lints clean.
"""
from __future__ import annotations

import json
import os

from tools.lint import lint_paths, waived_spans
from tools.lint.__main__ import main as lint_main

HERE = os.path.dirname(os.path.abspath(__file__))
FIX = os.path.join(HERE, "lint_fixtures")
REPO = os.path.dirname(HERE)


def _fx(*parts):
    return os.path.join(FIX, *parts)


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# Per-rule golden files
# ---------------------------------------------------------------------------

class TestR1HostSync:
    def test_flags_every_sync_form(self):
        rep = lint_paths([_fx("r1_flag.py")], rules=["R1"])
        msgs = [f.message for f in rep.unwaived]
        assert len(msgs) == 6
        assert sum("jit-reachable" in m for m in msgs) == 3
        assert any(".item()" in m for m in msgs)
        assert any("numpy.asarray" in m for m in msgs)
        assert any(".block_until_ready()" in m for m in msgs)
        assert any("float()" in m for m in msgs)

    def test_clean_and_waived_code_passes(self):
        rep = lint_paths([_fx("r1_pass.py")], rules=["R1"])
        assert rep.unwaived == []
        # the intentional syncs are reported but waived, with reasons
        waived = [f for f in rep.findings if f.waived]
        assert len(waived) == 2
        assert all(f.waiver_reason for f in waived)


class TestR2JitCache:
    def test_flags_per_call_jit(self):
        rep = lint_paths([_fx("r2_flag.py")], rules=["R2"])
        assert len(rep.unwaived) == 1
        assert "hot_loop" in rep.unwaived[0].message

    def test_accepts_all_cache_idioms(self):
        rep = lint_paths([_fx("r2_pass.py")], rules=["R2"])
        assert rep.unwaived == []


class TestR3CodecRegistry:
    def test_flags_incomplete_codecs(self):
        rep = lint_paths([_fx("codecs", "r3_flag.py")], rules=["R3"])
        msgs = [f.message for f in rep.unwaived]
        assert any("does not define `decode`" in m for m in msgs)
        assert sum("sharded-encode surface" in m for m in msgs) == 2
        assert any("header param `table`" in m for m in msgs)

    def test_flags_incomplete_stages(self):
        rep = lint_paths([_fx("codecs", "r3_flag.py")], rules=["R3"])
        msgs = [f.message for f in rep.unwaived]
        assert any("predictor stage `noreconstruct`" in m
                   and "does not define `reconstruct`" in m for m in msgs)
        assert any("encoder stage `nokernels`" in m
                   and "`kernels` tuple" in m for m in msgs)

    def test_full_surface_or_optout_passes(self):
        rep = lint_paths([_fx("codecs", "r3_pass.py")], rules=["R3"])
        assert rep.unwaived == []


class TestR4KernelDispatch:
    def test_flags_unregistered_pallas_and_missing_reason(self):
        rep = lint_paths([_fx("kernels")], rules=["R4"])
        msgs = [f.message for f in rep.unwaived]
        assert any("flagop" in m and "unreachable" in m for m in msgs)
        assert any("rawonly_flag" in m and "jax_only_reason" in m
                   for m in msgs)
        assert not any("passop" in m or "rawonly_pass" in m for m in msgs)

    def test_flags_dangling_stage_kernel_decl(self):
        rep = lint_paths([_fx("kernels")], rules=["R4"])
        msgs = [f.message for f in rep.unwaived]
        assert any("stage `dangling`" in m and "ghostop.forward" in m
                   for m in msgs)
        assert not any("stage `resolves`" in m for m in msgs)


class TestR5TracerBranch:
    def test_flags_branches_on_tracers(self):
        rep = lint_paths([_fx("r5_flag.py")], rules=["R5"])
        kinds = sorted("while" if "`while`" in f.message else "if"
                       for f in rep.unwaived)
        assert kinds == ["if", "while"]

    def test_static_and_metadata_branches_pass(self):
        rep = lint_paths([_fx("r5_pass.py")], rules=["R5"])
        assert rep.unwaived == []


# ---------------------------------------------------------------------------
# Waiver semantics + runtime bridge
# ---------------------------------------------------------------------------

class TestWaivers:
    def test_waiver_category_must_match(self, tmp_path):
        src = ("import jax\n"
               "def f(x):\n"
               "    # repro-lint: allow[jit-cache] wrong category\n"
               "    return jax.device_get(x)\n")
        p = tmp_path / "wrongcat.py"
        p.write_text(src)
        rep = lint_paths([str(p)], rules=["R1"])
        assert len(rep.unwaived) == 1       # pragma does not cover R1

    def test_unknown_category_is_itself_flagged(self, tmp_path):
        p = tmp_path / "badcat.py"
        p.write_text("x = 1  # repro-lint: allow[made-up] huh\n")
        rep = lint_paths([str(p)])
        assert any(f.rule == "waiver-error" for f in rep.findings)

    def test_waived_spans_bridge(self):
        spans = waived_spans(FIX, category="host-sync")
        key = os.path.abspath(_fx("r1_pass.py"))
        assert key in spans
        lines = {ln for (lo, hi, _r) in spans[key]
                 for ln in range(lo, hi + 1)}
        assert 14 in lines                  # jax.device_get statement
        assert 16 in lines                  # int(stats) statement


# ---------------------------------------------------------------------------
# Report + CLI
# ---------------------------------------------------------------------------

class TestReportAndCli:
    def test_json_report_is_stable(self, tmp_path):
        a = lint_paths([FIX]).to_json()
        b = lint_paths([FIX]).to_json()
        assert a == b
        assert a["version"] == 1
        assert a["counts"]["total"] == len(a["findings"])
        assert a["counts"]["unwaived"] + a["counts"]["waived"] \
            == a["counts"]["total"]
        # findings sorted by (path, line, rule)
        keys = [(f["path"], f["line"], f["rule"]) for f in a["findings"]]
        assert keys == sorted(keys)

    def test_cli_exit_codes_and_json_artifact(self, tmp_path, capsys):
        out = tmp_path / "report.json"
        rc = lint_main([_fx("r2_flag.py"), "--json", str(out)])
        assert rc == 1
        data = json.loads(out.read_text())
        assert data["counts"]["unwaived"] == 1
        capsys.readouterr()
        rc = lint_main([_fx("r2_pass.py")])
        assert rc == 0

    def test_rule_filter(self):
        rep = lint_paths([_fx("r1_flag.py")], rules=["R5"])
        assert rep.findings == []           # r1 fixture has no R5 issues
        assert rep.rules == ["R5-tracer-branch"]


# ---------------------------------------------------------------------------
# The in-repo gate: src/ lints clean (same command CI runs)
# ---------------------------------------------------------------------------

class TestRepoIsClean:
    def test_src_has_zero_unwaived_findings(self):
        rep = lint_paths([os.path.join(REPO, "src")])
        assert [str(f) for f in rep.unwaived] == []
        # and the waivers that justify it all carry reasons
        assert all(f.waiver_reason and f.waiver_reason.strip()
                   for f in rep.findings if f.waived)

    def test_all_five_rules_ran(self):
        rep = lint_paths([os.path.join(REPO, "src")])
        assert len(rep.rules) >= 5
