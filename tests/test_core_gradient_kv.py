"""Gradient-compression and KV-cache compression tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import gradient as G, kvcache as KV


class TestGradCompression:
    @pytest.mark.parametrize("mode,tol_bits", [("int16", 16), ("int8", 8)])
    def test_psum_mean_error_bounded(self, mode, tol_bits):
        rng = np.random.default_rng(0)
        npods = 2
        g = rng.standard_normal((npods, 64, 32)).astype(np.float32) * 0.01
        out = G.compressed_psum_mean({"w": jnp.asarray(g)}, mode, npods)["w"]
        ref = g.mean(axis=0)
        qmax = 2 ** (tol_bits - 1) - 1
        scale = np.abs(g).max() / (qmax // npods)
        # mean of per-pod quantization errors each <= scale/2
        assert np.abs(np.asarray(out) - ref).max() <= scale / 2 + 1e-12

    def test_none_mode_exact(self):
        rng = np.random.default_rng(1)
        g = rng.standard_normal((2, 16)).astype(np.float32)
        out = G.compressed_psum_mean(jnp.asarray(g), "none", 2)
        np.testing.assert_allclose(np.asarray(out), g.mean(0), rtol=1e-6)

    def test_no_overflow_in_narrow_sum(self):
        """Adversarial: all pods at +amax must not overflow the narrow sum."""
        npods = 4
        g = jnp.ones((npods, 128), jnp.float32) * 3.0
        out = G.compressed_psum_mean(g, "int8", npods)
        np.testing.assert_allclose(np.asarray(out), 3.0, rtol=0.05)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_quantize_roundtrip_bound(self, seed):
        rng = np.random.default_rng(seed)
        g = rng.standard_normal(256).astype(np.float32) * 10 ** rng.uniform(-4, 2)
        q, scale = G.quantize_tensor(jnp.asarray(g), "int8")
        rec = np.asarray(G.dequantize_tensor(q, scale))
        eb = float(G.error_bound_of(jnp.asarray(g), "int8"))
        assert np.abs(rec - g).max() <= eb * 2 * (1 + 1e-5) + 1e-20


class TestKVCache:
    def test_quantize_dequantize_bound(self):
        rng = np.random.default_rng(2)
        k = rng.standard_normal((2, 4, 512, 16)).astype(np.float32)
        qkv = KV.kv_quantize(jnp.asarray(k), seq_axis=2)
        rec = np.asarray(KV.kv_dequantize(qkv, seq_axis=2, dtype=jnp.float32))
        eb = np.asarray(KV.error_bound(qkv))
        # per-block bound: broadcast eb over its SEQ_BLOCK
        eb_full = np.repeat(eb, KV.SEQ_BLOCK, axis=2)
        assert (np.abs(rec - k) <= eb_full * 2 + 1e-12).all()
        assert qkv.q.dtype == jnp.int8

    def test_update_block_preserves_old_tokens(self):
        rng = np.random.default_rng(3)
        cache = rng.standard_normal((1, 256, 8)).astype(np.float32) * 0.1
        qkv = KV.kv_quantize(jnp.asarray(cache), seq_axis=1)
        before = np.asarray(KV.kv_dequantize(qkv, 1, jnp.float32))
        big = jnp.ones((1, 1, 8), jnp.float32) * 5.0      # widens the scale
        qkv2 = KV.kv_update_block(qkv, big, pos=7, seq_axis=1)
        after = np.asarray(KV.kv_dequantize(qkv2, 1, jnp.float32))
        # written slot correct
        np.testing.assert_allclose(after[0, 7], 5.0, atol=0.05)
        # other tokens in the widened block survive within the new bound
        new_eb = float(np.asarray(KV.error_bound(qkv2))[0, 0].max())
        mask = np.ones(256, bool); mask[7] = False
        assert np.abs(after[0, mask] - before[0, mask]).max() <= 2 * new_eb + 1e-6
        # blocks other than block 0 untouched
        np.testing.assert_array_equal(after[0, 128:], before[0, 128:])

    def test_memory_footprint_4x(self):
        k = jnp.zeros((2, 4, 1024, 64), jnp.bfloat16)
        qkv = KV.kv_quantize(k.astype(jnp.float32), seq_axis=2)
        raw = k.size * 2
        comp = qkv.q.size * 1 + qkv.scale.size * 4
        assert raw / comp > 1.9
