"""Gradient-compression and KV-cache compression tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import gradient as G, kvcache as KV


class TestGradCompression:
    @pytest.mark.parametrize("mode,tol_bits", [("int16", 16), ("int8", 8)])
    def test_psum_mean_error_bounded(self, mode, tol_bits):
        rng = np.random.default_rng(0)
        npods = 2
        g = rng.standard_normal((npods, 64, 32)).astype(np.float32) * 0.01
        out = G.compressed_psum_mean({"w": jnp.asarray(g)}, mode, npods)["w"]
        ref = g.mean(axis=0)
        qmax = 2 ** (tol_bits - 1) - 1
        scale = np.abs(g).max() / (qmax // npods)
        # mean of per-pod quantization errors each <= scale/2
        assert np.abs(np.asarray(out) - ref).max() <= scale / 2 + 1e-12

    def test_none_mode_exact(self):
        rng = np.random.default_rng(1)
        g = rng.standard_normal((2, 16)).astype(np.float32)
        out = G.compressed_psum_mean(jnp.asarray(g), "none", 2)
        np.testing.assert_allclose(np.asarray(out), g.mean(0), rtol=1e-6)

    def test_no_overflow_in_narrow_sum(self):
        """Adversarial: all pods at +amax must not overflow the narrow sum."""
        npods = 4
        g = jnp.ones((npods, 128), jnp.float32) * 3.0
        out = G.compressed_psum_mean(g, "int8", npods)
        np.testing.assert_allclose(np.asarray(out), 3.0, rtol=0.05)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_quantize_roundtrip_bound(self, seed):
        rng = np.random.default_rng(seed)
        g = rng.standard_normal(256).astype(np.float32) * 10 ** rng.uniform(-4, 2)
        q, scale = G.quantize_tensor(jnp.asarray(g), "int8")
        rec = np.asarray(G.dequantize_tensor(q, scale))
        eb = float(G.error_bound_of(jnp.asarray(g), "int8"))
        assert np.abs(rec - g).max() <= eb * 2 * (1 + 1e-5) + 1e-20


class TestKVCache:
    def test_quantize_dequantize_bound(self):
        rng = np.random.default_rng(2)
        k = rng.standard_normal((2, 4, 512, 16)).astype(np.float32)
        qkv = KV.kv_quantize(jnp.asarray(k), seq_axis=2)
        rec = np.asarray(KV.kv_dequantize(qkv, seq_axis=2, dtype=jnp.float32))
        eb = np.asarray(KV.error_bound(qkv))
        # per-block bound: broadcast eb over its SEQ_BLOCK
        eb_full = np.repeat(eb, KV.SEQ_BLOCK, axis=2)
        assert (np.abs(rec - k) <= eb_full * 2 + 1e-12).all()
        assert qkv.q.dtype == jnp.int8

    def test_update_block_preserves_old_tokens(self):
        rng = np.random.default_rng(3)
        cache = rng.standard_normal((1, 256, 8)).astype(np.float32) * 0.1
        qkv = KV.kv_quantize(jnp.asarray(cache), seq_axis=1)
        before = np.asarray(KV.kv_dequantize(qkv, 1, jnp.float32))
        big = jnp.ones((1, 1, 8), jnp.float32) * 5.0      # widens the scale
        qkv2 = KV.kv_update_block(qkv, big, pos=7, seq_axis=1)
        after = np.asarray(KV.kv_dequantize(qkv2, 1, jnp.float32))
        # written slot correct
        np.testing.assert_allclose(after[0, 7], 5.0, atol=0.05)
        # other tokens in the widened block survive within the new bound
        new_eb = float(np.asarray(KV.error_bound(qkv2))[0, 0].max())
        mask = np.ones(256, bool); mask[7] = False
        assert np.abs(after[0, mask] - before[0, mask]).max() <= 2 * new_eb + 1e-6
        # blocks other than block 0 untouched
        np.testing.assert_array_equal(after[0, 128:], before[0, 128:])

    def test_memory_footprint_4x(self):
        k = jnp.zeros((2, 4, 1024, 64), jnp.bfloat16)
        qkv = KV.kv_quantize(k.astype(jnp.float32), seq_axis=2)
        raw = k.size * 2
        comp = qkv.q.size * 1 + qkv.scale.size * 4
        assert raw / comp > 1.9

    def test_update_widens_per_coordinate_not_globally(self):
        """Regression (ISSUE satellite): kv_update_block used to widen
        the block scale by the *global* amax of the new token, so one
        coordinate's large value requantized (and destroyed) every other
        coordinate's already-written tokens.  Widening is per scale
        coordinate: an untouched coordinate keeps its tight scale and its
        tokens survive bit-exactly."""
        cache = np.zeros((1, 256, 2), np.float32)
        cache[0, :8, 0] = np.linspace(1e-3, 2e-3, 8)   # tiny coord 0
        cache[0, :8, 1] = np.linspace(0.5, 1.0, 8)     # large coord 1
        qkv = KV.kv_quantize(jnp.asarray(cache), seq_axis=1)
        before = np.asarray(KV.kv_dequantize(qkv, 1, jnp.float32))
        new = jnp.asarray([[[1e-3, 100.0]]], jnp.float32)  # huge coord 1
        qkv2 = KV.kv_update_block(qkv, new, pos=8, seq_axis=1)
        after = np.asarray(KV.kv_dequantize(qkv2, 1, jnp.float32))
        # coord 0's scale must not have widened -> its tokens unchanged
        np.testing.assert_array_equal(after[0, :8, 0], before[0, :8, 0])
        assert float(np.asarray(qkv2.scale)[0, 0, 0]) == \
            float(np.asarray(qkv.scale)[0, 0, 0])
        # the written slot round-trips within its own (widened) bound
        eb1 = float(np.asarray(qkv2.scale)[0, 0, 1]) / 2
        assert abs(after[0, 8, 1] - 100.0) <= eb1 + 1e-6
        assert abs(after[0, 8, 0] - 1e-3) <= \
            float(np.asarray(qkv2.scale)[0, 0, 0]) / 2 + 1e-9

    def test_zero_extension_blocks_stay_at_floor_until_written(self):
        """The all-zero s_max extension quantizes to the 1e-30 scale
        floor; writing the first real token into a zero block sets that
        coordinate's scale from the token and the old zeros requantize
        to exact zeros (no garbage from the degenerate old scale)."""
        cache = np.zeros((1, 256, 4), np.float32)
        cache[0, :100] = np.random.default_rng(0).standard_normal((100, 4))
        qkv = KV.kv_quantize(jnp.asarray(cache), seq_axis=1)
        # block 1 (positions 128..255) is all zeros -> floor scale
        assert (np.asarray(qkv.scale)[0, 1] == 1e-30).all()
        new = jnp.full((1, 1, 4), 3.0, jnp.float32)
        qkv2 = KV.kv_update_block(qkv, new, pos=130, seq_axis=1)
        after = np.asarray(KV.kv_dequantize(qkv2, 1, jnp.float32))
        np.testing.assert_allclose(after[0, 130], 3.0, atol=3.0 / 254 + 1e-6)
        # the rest of the zero block stays exactly zero
        mask = np.ones(256, bool); mask[130] = False
        np.testing.assert_array_equal(after[0, 128:][mask[128:]], 0.0)
        # and a zero token into a zero block keeps the floor (no NaN/Inf)
        qkv3 = KV.kv_update_block(qkv, jnp.zeros((1, 1, 4), jnp.float32),
                                  pos=200, seq_axis=1)
        assert np.isfinite(np.asarray(qkv3.scale)).all()
        assert (np.asarray(KV.kv_dequantize(qkv3, 1, jnp.float32)) ==
                np.asarray(KV.kv_dequantize(qkv, 1, jnp.float32))).all()

    def test_misaligned_prompt_tail_block_survives_decode_writes(self):
        """A prompt tail that doesn't align to SEQ_BLOCK shares its block
        with the zero extension; decode writes into that partial block
        must keep the prompt tokens within their quantization bound."""
        plen = 100                       # partial block 0..127
        cache = np.zeros((1, 256, 4), np.float32)
        vals = np.random.default_rng(1).standard_normal((plen, 4))
        cache[0, :plen] = vals
        qkv = KV.kv_quantize(jnp.asarray(cache), seq_axis=1)
        before = np.asarray(KV.kv_dequantize(qkv, 1, jnp.float32))
        # write decode tokens at plen..plen+3 (same block as the tail)
        for i in range(4):
            tok = jnp.asarray(
                np.random.default_rng(2 + i).standard_normal((1, 1, 4))
                .astype(np.float32))
            qkv = KV.kv_update_block(qkv, tok, pos=plen + i, seq_axis=1)
        after = np.asarray(KV.kv_dequantize(qkv, 1, jnp.float32))
        eb = np.asarray(KV.error_bound(qkv))[0, 0]     # block 0, per coord
        # prompt tokens in the partial block: still within 2x the final
        # (possibly widened) per-coordinate bound
        err = np.abs(after[0, :plen] - before[0, :plen])
        assert (err <= 2 * eb[None, :] + 1e-9).all()
