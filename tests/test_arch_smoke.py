"""Per-architecture smoke tests (deliverable f): reduced same-family
configs, one forward + one train-style grad step + one decode step on CPU,
asserting output shapes and no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M

ARCH_IDS = sorted(configs.ARCHS)


def _inputs(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    extra = {}
    if cfg.n_prepend_embeds:
        extra["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prepend_embeds, cfg.d_model))
            .astype(np.float32))
    if cfg.add_frame_embeds:
        extra["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)).astype(np.float32) * 0.02)
    return toks, (extra or None)


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = configs.reduced(arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks, extra = _inputs(cfg)
        logits, _ = M.forward(params, cfg, toks, extra)
        S_total = toks.shape[1] + cfg.n_prepend_embeds
        assert logits.shape == (2, S_total, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_train_grad_step(self, arch):
        cfg = configs.reduced(arch)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        toks, extra = _inputs(cfg, seed=1)

        def loss_fn(p):
            logits, _ = M.forward(p, cfg, toks, extra)
            lp = jax.nn.log_softmax(logits[:, cfg.n_prepend_embeds:-1])
            tgt = toks[:, 1:]
            return -jnp.mean(jnp.take_along_axis(lp, tgt[..., None], -1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss)) and float(loss) > 0
        leaves = jax.tree.leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
        assert any(float(jnp.abs(g).max()) > 0 for g in leaves)

    def test_decode_step(self, arch):
        cfg = configs.reduced(arch)
        params = M.init_params(jax.random.PRNGKey(2), cfg)
        toks, extra = _inputs(cfg, seed=2)
        caches = M.init_caches(cfg, 2, 64)
        logits, caches2 = M.decode_step(params, cfg, toks[:, :1], caches,
                                        jnp.int32(0))
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        # a second step must consume the updated caches
        logits2, _ = M.decode_step(params, cfg, toks[:, 1:2], caches2,
                                   jnp.int32(1))
        assert bool(jnp.all(jnp.isfinite(logits2)))


class TestDecodeMatchesForward:
    """Step-by-step decode must agree with teacher-forced forward (tests
    cache correctness incl. rope offsets, conv tails, SSD state handoff)."""

    @pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-1.3b",
                                      "deepseek-v2-236b",
                                      "jamba-1.5-large-398b"])
    def test_agreement(self, arch):
        cfg = configs.reduced(arch)
        params = M.init_params(jax.random.PRNGKey(3), cfg)
        B, S = 1, 12
        toks, extra = _inputs(cfg, B=B, S=S, seed=3)
        full, _ = M.forward(params, cfg, toks, extra,
                            compute_dtype=jnp.float32)
        caches = M.init_caches(cfg, B, 32, dtype=jnp.float32)
        outs = []
        for t in range(S):
            lg, caches = M.decode_step(params, cfg, toks[:, t:t + 1], caches,
                                       jnp.int32(t), compute_dtype=jnp.float32)
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=2e-2, atol=2e-2)

    def test_compressed_kv_close(self):
        """int8 KV cache decode stays close to the fp cache decode."""
        cfg = configs.reduced("qwen2.5-3b")
        params = M.init_params(jax.random.PRNGKey(4), cfg)
        toks, _ = _inputs(cfg, B=1, S=8, seed=4)
        cf = M.init_caches(cfg, 1, 128, dtype=jnp.float32)
        cq = M.init_caches(cfg, 1, 128, compressed_kv=True)
        for t in range(8):
            lf, cf = M.decode_step(params, cfg, toks[:, t:t + 1], cf,
                                   jnp.int32(t), compute_dtype=jnp.float32)
            lq, cq = M.decode_step(params, cfg, toks[:, t:t + 1], cq,
                                   jnp.int32(t), compute_dtype=jnp.float32,
                                   compressed_kv=True)
        pf = jax.nn.softmax(lf[0, 0]); pq = jax.nn.softmax(lq[0, 0])
        assert float(jnp.abs(pf - pq).max()) < 0.05
