"""Continuous-batching scheduler: compile-once guarantee, token
identity against the engine, preemption-by-eviction, and the
continuous-vs-static decode-step win.

All runs use compute_dtype=float32 so greedy token streams are exactly
reproducible across the engine path (whole-batch decode), the vmapped
per-slot batch step, and preempt/resume cycles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serve import engine as E
from repro.serve import scheduler as S

ARCH = "qwen2.5-3b"


@pytest.fixture(scope="module")
def setup():
    cfg = configs.reduced(ARCH, n_periods=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    scfg = E.ServeConfig(s_max=256, compressed_kv=True,
                         compute_dtype=jnp.float32)
    return cfg, params, scfg


def _requests(n, rng, plen_lo=5, plen_hi=14, new_lo=3, new_hi=7,
              arrivals=None):
    return [S.Request(
        rid=i,
        prompt=rng.integers(1, 100,
                            size=int(rng.integers(plen_lo, plen_hi))
                            ).astype(np.int32),
        max_new=int(rng.integers(new_lo, new_hi)),
        arrival=0 if arrivals is None else arrivals[i]) for i in range(n)]


class TestCompileOnce:
    def test_batch_step_compiles_exactly_once_across_churn(
            self, setup, recompile_guard):
        """Admission, retirement and ragged per-slot positions churn the
        batch composition every few steps; the vmapped step must stay
        one executable (buffer writes, never shape changes)."""
        cfg, params, _ = setup
        # distinct s_max: a fresh (cfg, scfg, max_batch) jit-cache key
        scfg = E.ServeConfig(s_max=384, compressed_kv=True,
                             compute_dtype=jnp.float32)
        key = (cfg, scfg, 2)
        S.BATCH_STEP_TRACES.pop(key, None)
        S.get_batch_step.cache_clear()
        rng = np.random.default_rng(0)
        reqs = _requests(5, rng, arrivals=[0, 0, 1, 3, 4])
        schedcfg = S.SchedulerConfig(max_batch=2, pool_pages=12)
        with recompile_guard(max_compiles=1,
                             match=r"^batch_step$") as log:
            fin, sched = S.run_continuous(params, cfg, scfg, schedcfg,
                                          reqs)
        assert log.compiles == ["batch_step"]
        assert S.BATCH_STEP_TRACES[key] == 1
        assert len(fin) == 5
        # second run at the same config: zero additional compiles
        with recompile_guard(max_compiles=0,
                             match=r"^batch_step$"):
            S.run_continuous(params, cfg, scfg, schedcfg, reqs)
        assert S.BATCH_STEP_TRACES[key] == 1


class TestTokenIdentity:
    def test_single_request_matches_engine_generate(self, setup):
        cfg, params, scfg = setup
        rng = np.random.default_rng(1)
        prompt = rng.integers(1, 100, size=9).astype(np.int32)
        n_new = 5
        ref = np.asarray(E.generate(
            params, cfg, jnp.asarray(prompt)[None, :], n_new,
            scfg))[0].tolist()
        fin, _ = S.run_continuous(
            params, cfg, scfg,
            S.SchedulerConfig(max_batch=2, pool_pages=8),
            [S.Request(rid=0, prompt=prompt, max_new=n_new)])
        assert fin[0]["tokens"] == ref

    def test_continuous_equals_static_tokens(self, setup):
        cfg, params, scfg = setup
        rng = np.random.default_rng(2)
        reqs = _requests(4, rng, arrivals=[0, 0, 2, 3])
        schedcfg = S.SchedulerConfig(max_batch=2, pool_pages=12)
        fin_c, _ = S.run_continuous(params, cfg, scfg, schedcfg, reqs)
        fin_s, _ = S.run_static(params, cfg, scfg, schedcfg, reqs)
        assert fin_c.keys() == fin_s.keys()
        for rid in fin_c:
            assert fin_c[rid]["tokens"] == fin_s[rid]["tokens"], rid

    def test_hybrid_arch_state_sidecar(self, setup):
        """Jamba-style hybrid: the Mamba recurrent state (no seq axis)
        rides the per-sequence sidecar, not the pool; tokens must still
        match the engine exactly through admit -> decode -> retire."""
        cfg = configs.reduced("jamba-1.5-large-398b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        scfg = E.ServeConfig(s_max=256, compressed_kv=True,
                             compute_dtype=jnp.float32)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, 100, size=n).astype(np.int32)
                   for n in (6, 9)]
        refs = [np.asarray(E.generate(
            params, cfg, jnp.asarray(p)[None, :], 3, scfg))[0].tolist()
            for p in prompts]
        fin, _ = S.run_continuous(
            params, cfg, scfg,
            S.SchedulerConfig(max_batch=2, pool_pages=8),
            [S.Request(rid=i, prompt=p, max_new=3)
             for i, p in enumerate(prompts)])
        for i, ref in enumerate(refs):
            assert fin[i]["tokens"] == ref, i


class TestPreemption:
    def test_tiny_pool_preempts_and_stays_token_identical(self, setup):
        """3 live sequences on a 2-page pool: someone must be preempted
        (flush -> evict -> requeue -> restore) and, with the bit-exact
        int8-block eviction codec, every token stream must equal the
        unconstrained-pool run."""
        cfg, params, scfg = setup
        rng = np.random.default_rng(4)
        reqs = _requests(3, rng, plen_lo=6, plen_hi=12, new_lo=5,
                         new_hi=8)
        tiny = S.SchedulerConfig(max_batch=3, pool_pages=2,
                                 evict_codec="int8-block")
        fin, sched = S.run_continuous(params, cfg, scfg, tiny, reqs)
        assert sched.preemptions > 0
        assert sched.pool.stats()["evicted_pages"] > 0
        assert sched.pool.stats()["restored_pages"] > 0
        big = S.SchedulerConfig(max_batch=3, pool_pages=16,
                                evict_codec="int8-block")
        fin_big, sched_big = S.run_continuous(params, cfg, scfg, big,
                                              reqs)
        assert sched_big.preemptions == 0
        for rid in fin:
            assert fin[rid]["tokens"] == fin_big[rid]["tokens"], rid

    def test_pool_too_small_raises(self, setup):
        cfg, params, scfg = setup
        rng = np.random.default_rng(5)
        # prompt needs 2 pages (>SEQ_BLOCK tokens) but the pool has 1
        prompt = rng.integers(1, 100, size=150).astype(np.int32)
        with pytest.raises(RuntimeError, match="pool too small"):
            S.run_continuous(
                params, cfg, scfg,
                S.SchedulerConfig(max_batch=1, pool_pages=1,
                                  preempt=False),
                [S.Request(rid=0, prompt=prompt, max_new=2)])


class TestContinuousBeatsStatic:
    def test_fewer_decode_steps_than_wave_admission(self, setup):
        """Mixed generation lengths: wave admission holds finished slots
        hostage until the slowest member retires; continuous refills
        them.  Same tokens out, strictly fewer decode steps."""
        cfg, params, scfg = setup
        rng = np.random.default_rng(6)
        reqs = [S.Request(rid=0, prompt=rng.integers(1, 100, size=8)
                          .astype(np.int32), max_new=8),
                S.Request(rid=1, prompt=rng.integers(1, 100, size=6)
                          .astype(np.int32), max_new=2),
                S.Request(rid=2, prompt=rng.integers(1, 100, size=7)
                          .astype(np.int32), max_new=2),
                S.Request(rid=3, prompt=rng.integers(1, 100, size=9)
                          .astype(np.int32), max_new=2)]
        schedcfg = S.SchedulerConfig(max_batch=2, pool_pages=12)
        fin_c, sc = S.run_continuous(params, cfg, scfg, schedcfg, reqs)
        fin_s, ss = S.run_static(params, cfg, scfg, schedcfg, reqs)
        assert sum(len(f["tokens"]) for f in fin_c.values()) == \
            sum(len(f["tokens"]) for f in fin_s.values())
        assert sc.n_steps < ss.n_steps, (sc.n_steps, ss.n_steps)


class TestLifecycleAccounting:
    def test_pool_drains_and_eos_retires(self, setup):
        cfg, params, scfg = setup
        rng = np.random.default_rng(7)
        reqs = _requests(3, rng)
        fin, sched = S.run_continuous(
            params, cfg, scfg,
            S.SchedulerConfig(max_batch=2, pool_pages=8), reqs)
        assert sched.pool.used_pages == 0          # everything released
        assert sched.pool.stats()["sequences"] == 0
        assert not sched.states and not sched._suspended
        for r in reqs:
            assert len(fin[r.rid]["tokens"]) == r.max_new

    def test_eos_cuts_generation_short(self, setup):
        cfg, params, scfg = setup
        rng = np.random.default_rng(8)
        prompt = rng.integers(1, 100, size=8).astype(np.int32)
        ref = np.asarray(E.generate(
            params, cfg, jnp.asarray(prompt)[None, :], 6,
            scfg))[0].tolist()
        eos = ref[2]                    # force EOS at the 3rd token
        fin, _ = S.run_continuous(
            params, cfg, scfg,
            S.SchedulerConfig(max_batch=1, pool_pages=8, eos_id=eos),
            [S.Request(rid=0, prompt=prompt, max_new=6)])
        assert fin[0]["tokens"] == ref[:3]

    def test_requires_compressed_kv(self, setup):
        cfg, params, _ = setup
        with pytest.raises(ValueError, match="compressed_kv"):
            S.ContinuousScheduler(
                params, cfg,
                E.ServeConfig(s_max=256, compressed_kv=False),
                S.SchedulerConfig())
