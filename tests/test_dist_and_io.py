"""Distribution substrate tests: sharding rules, checkpoint I/O,
fault-tolerance primitives, data pipeline determinism."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data import pipeline
from repro.dist import fault, sharding as SH
from repro.io import checkpoint as CK
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optim import adamw


class TestShardingRules:
    def test_param_specs_cover_all_archs(self):
        mesh = make_host_mesh()
        for name in configs.ARCHS:
            shapes = M.param_shapes(configs.get(name))
            specs = SH.param_specs(shapes, mesh)
            n = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
            assert n == len(jax.tree.leaves(shapes))

    def test_divisibility_fallback(self):
        """granite kv=1 (MQA): wk head dim must fall back to replicated."""
        mesh = jax.sharding.AbstractMesh((1, 2), ("data", "model"))
        shapes = M.param_shapes(configs.get("granite-34b"))
        specs = SH.param_specs(shapes, mesh)
        wk_spec = specs["layers"][0]["attn"]["wk"]
        # kv=1 not shardable over model (trailing Nones are trimmed)
        assert len(wk_spec) < 3 or wk_spec[2] is None
        wq_spec = specs["layers"][0]["attn"]["wq"]
        assert wq_spec[2] == "model"         # 48 q heads shard fine

    def test_opt_state_specs_follow_params(self):
        mesh = jax.sharding.AbstractMesh((1, 2), ("data", "model"))
        cfg = configs.reduced("qwen3-4b")
        shapes = M.param_shapes(cfg)
        ocfg = adamw.AdamWConfig(quantized_moments=False)
        oshapes = jax.eval_shape(lambda p: adamw.init(p, ocfg), shapes)
        ospecs = SH.param_specs(oshapes, mesh)
        pspecs = SH.param_specs(shapes, mesh)
        assert ospecs.m["layers"][0]["mlp"]["w_up"] == \
            pspecs["layers"][0]["mlp"]["w_up"]


class TestQuantizedMoments:
    def test_adamw_quantized_close_to_fp32(self):
        cfg = adamw.AdamWConfig(lr=1e-2)
        cfg_q = adamw.AdamWConfig(lr=1e-2, quantized_moments=True)
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.standard_normal((8, 128)).astype(np.float32))}
        s, sq = adamw.init(params, cfg), adamw.init(params, cfg_q)
        p, pq = params, params
        for i in range(5):
            g = {"w": jnp.asarray(rng.standard_normal((8, 128)).astype(np.float32))}
            p, s = adamw.update(g, s, p, cfg)
            pq, sq = adamw.update(g, sq, pq, cfg_q)
        d = float(jnp.abs(p["w"] - pq["w"]).max())
        assert d < 5e-3, d

    def test_quantized_state_bytes(self):
        params = {"w": jnp.zeros((256, 1024), jnp.float32)}
        sq = adamw.init(params, adamw.AdamWConfig(quantized_moments=True))
        m = sq.m["w"]
        assert m.q.dtype == jnp.int8 and m.q.shape == (256, 1024)
        assert m.scale.shape == (256, 8)


class TestCheckpoint:
    def _state(self):
        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        return (params, adamw.init(params, adamw.AdamWConfig()))

    def test_lossless_roundtrip_exact(self):
        state = self._state()
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 3, state)       # default policy: lossless
            out, step = CK.load_checkpoint(d, state)
            assert step == 3
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_cusz_roundtrip_bounded(self):
        state = self._state()
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 0, state,
                               policy=CK.CheckpointPolicy(codec="cusz",
                                                          eb_valrel=1e-5))
            out, _ = CK.load_checkpoint(d, state)
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
                a, b = np.asarray(a), np.asarray(b)
                if a.dtype == np.float32 and a.size >= CK.CUSZ_MIN_SIZE:
                    rng = a.max() - a.min()
                    if rng > 0:
                        assert np.abs(a - b).max() <= 1.05e-5 * rng + 1e-12

    def test_policy_rules_route_leaves_to_codecs(self):
        """Per-leaf codec selection from one config: a substring rule
        sends the `opt` subtree through int8 while params stay cusz and
        ineligible leaves (small / int) fall back to lossless."""
        import json
        rng = np.random.default_rng(0)
        tree = {
            "w": jnp.asarray(np.cumsum(rng.standard_normal((64, 128)),
                                       axis=-1).astype(np.float32)),
            "bias": jnp.asarray(rng.standard_normal(8).astype(np.float32)),
            "step": jnp.asarray(np.int32(7)),
            "opt": {"m": jnp.asarray(
                rng.standard_normal((64, 128)).astype(np.float32))},
        }
        pol = CK.CheckpointPolicy(codec="cusz", eb_valrel=1e-4,
                                  rules=(("opt", "int8"),))
        with tempfile.TemporaryDirectory() as d:
            final = CK.save_checkpoint(d, 0, tree, policy=pol)
            man = json.load(open(os.path.join(final, "manifest.json")))
            assert man["tensors"]["w"]["codec"] == "cusz"
            assert man["tensors"]["opt::m"]["codec"] == "int8"
            assert man["tensors"]["bias"]["codec"] == "lossless"  # too small
            assert man["tensors"]["step"]["codec"] == "lossless"  # not float
            assert man["format"] == CK.MANIFEST_FORMAT
            for e in man["tensors"].values():      # self-describing headers
                for sh in e["shards"]:
                    assert sh["header"]["codec"] == e["codec"]
                    assert "dtype" in sh["header"] and "shape" in sh["header"]
            out, _ = CK.load_checkpoint(d, tree)
        np.testing.assert_array_equal(np.asarray(out["step"]),
                                      np.asarray(tree["step"]))
        np.testing.assert_array_equal(np.asarray(out["bias"]),
                                      np.asarray(tree["bias"]))
        w, w2 = np.asarray(tree["w"]), np.asarray(out["w"])
        assert np.abs(w - w2).max() <= 1.05e-4 * (w.max() - w.min())
        m, m2 = np.asarray(tree["opt"]["m"]), np.asarray(out["opt"]["m"])
        assert np.abs(m - m2).max() <= np.abs(m).max() / 127.0 * 0.51

    def test_latest_step_and_overwrite(self):
        state = self._state()
        with tempfile.TemporaryDirectory() as d:
            assert CK.latest_step(d) is None
            CK.save_checkpoint(d, 1, state)
            CK.save_checkpoint(d, 7, state)
            assert CK.latest_step(d) == 7


class TestFault:
    def test_straggler_detector(self):
        det = fault.StragglerDetector(threshold=2.0, warmup=2)
        flags = [det.observe(i, 0.1) for i in range(10)]
        assert not any(flags)
        assert det.observe(10, 0.5)          # 5x EMA -> flagged
        assert det.observe(11, 0.1) is False # recovers

    def test_nan_guard(self):
        assert fault.loss_is_bad(jnp.float32(np.nan))
        assert fault.loss_is_bad(jnp.float32(np.inf))
        assert not fault.loss_is_bad(jnp.float32(3.0))


class TestPipeline:
    def test_deterministic(self):
        a = pipeline.host_batch(1000, 4, 64, step=7, seed=3)
        b = pipeline.host_batch(1000, 4, 64, step=7, seed=3)
        np.testing.assert_array_equal(a, b)
        c = pipeline.host_batch(1000, 4, 64, step=8, seed=3)
        assert (a != c).any()

    def test_learnable_structure(self):
        toks = pipeline.host_batch(500, 8, 256, step=0, seed=0, noise=0.2)
        table = pipeline._bigram_table(500, 0)
        follow = (toks[:, 1:] == table[toks[:, :-1]]).mean()
        assert 0.7 < follow < 0.9            # ~1-noise


class TestCostModel:
    def test_terms_positive_and_shapes(self):
        from repro.perf import costmodel as CM
        for arch in ("qwen3-32b", "deepseek-v2-236b", "mamba2-1.3b",
                     "jamba-1.5-large-398b"):
            for shape in ("train_4k", "prefill_32k", "decode_32k"):
                c = CM.cell_cost(arch, shape, multi_pod=False, microbatches=4)
                assert c.flops > 0 and c.hbm_bytes > 0 and c.coll_bytes >= 0

    def test_int8_pod_sync_cheaper(self):
        from repro.perf import costmodel as CM
        a = CM.cell_cost("qwen3-32b", "train_4k", True, 8, "none")
        b = CM.cell_cost("qwen3-32b", "train_4k", True, 8, "int8")
        assert b.breakdown["coll_pod"] < a.breakdown["coll_pod"] / 3.5
