"""Prefill->decode KV handoff tests: the wire-slab codec layer, the
engine phase split (prefill / encode_handoff / reshard_caches /
decode_tokens), the cached jitted serve step, and the MLA compressed-KV
contract.

The 8-fake-device mesh-to-mesh version of the handoff lives in
``test_multidevice_spmd.py`` (subprocess); these tests cover the same
machinery single-device, including the property sweep over codec id x
prefill length x slab split."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import codecs, configs
from repro.core import kvcache as KVC
from repro.dist import context as dist_ctx
from repro.models import model as M
from repro.serve import engine as E


def _cache(plen: int, s_max: int = 512, seed: int = 0,
           shape=(1, 2, None, 2, 8)):
    """A synthetic prefill cache buffer: smooth values for the first
    `plen` positions, the all-zero s_max extension after (exactly what
    `prefill` hands to the codec)."""
    rng = np.random.default_rng(seed)
    full = list(shape)
    full[2] = s_max
    x = np.zeros(tuple(full), np.float32)
    live = np.cumsum(rng.standard_normal(tuple(full[:2] + [plen]
                                               + full[3:])), axis=2)
    x[:, :, :plen] = live / max(1.0, np.abs(live).max())
    return jnp.asarray(x)


class TestWireSlabs:
    @given(st.sampled_from(("int8-block", "cusz")),
           st.sampled_from((1, 7, 128, 200, 509, 512)),
           st.sampled_from((1, 2, 4)))
    @settings(max_examples=8, deadline=None)
    def test_property_roundtrip_wire_smaller_and_bounded(self, wire, plen,
                                                         nslabs):
        """ISSUE satellite: hypothesis over codec id x prefill length x
        mesh split — wire bytes < raw bf16 bytes and bound-held
        reconstruction for every combination."""
        x = _cache(plen)
        parts = KVC.kv_wire_encode(x, 2, wire=wire, nslabs=nslabs,
                                   source_dtype=jnp.float32)
        assert len(parts) == nslabs
        raw_bf16 = x.size * 2
        assert KVC.kv_wire_nbytes(parts) < raw_bf16, (wire, plen, nslabs)
        back = np.asarray(KVC.kv_wire_restore(parts, 2, dtype=jnp.float32))
        assert back.shape == x.shape
        if wire == "int8-block":
            scale = np.concatenate(
                [np.asarray(p.payload["scale"]) for p in parts], axis=2)
            tol = np.repeat(scale / 2, KVC.SEQ_BLOCK, axis=2) * 1.001 + 1e-12
        else:
            tol = max(float(p.header.param("eb")) for p in parts) \
                * 1.001 + 1e-12
        assert (np.abs(back - np.asarray(x)) <= tol).all()

    def test_int8_block_slabs_match_whole_tensor_quantize(self):
        """Slab boundaries are SEQ_BLOCK-aligned, so per-slab encoding is
        bit-identical to whole-tensor kv_quantize — the adopt path
        reproduces the single-mesh QuantKV exactly."""
        x = _cache(plen=100)
        ref = KVC.kv_quantize(x, seq_axis=2)
        for nslabs in (1, 2):
            parts = KVC.kv_wire_encode(x, 2, wire="int8-block",
                                       nslabs=nslabs)
            got = KVC.kv_wire_adopt(parts, 2)
            np.testing.assert_array_equal(np.asarray(got.q),
                                          np.asarray(ref.q))
            np.testing.assert_array_equal(np.asarray(got.scale),
                                          np.asarray(ref.scale))

    def test_quantkv_source_never_leaves_payload_space(self):
        """Encoding an already-quantized cache over the int8-block wire
        re-slices q/scale; adopt returns the identical payload."""
        qkv = KVC.kv_quantize(_cache(plen=256), seq_axis=2)
        parts = KVC.kv_wire_encode(qkv, 2, wire="int8-block")
        for p in parts:
            assert p.payload["q"].dtype == np.int8
        got = KVC.kv_wire_adopt(parts, 2)
        np.testing.assert_array_equal(np.asarray(got.q), np.asarray(qkv.q))
        np.testing.assert_array_equal(np.asarray(got.scale),
                                      np.asarray(qkv.scale))

    def test_adopt_rejects_non_blockwise_wire(self):
        parts = KVC.kv_wire_encode(_cache(64), 2, wire="cusz", nslabs=1)
        with pytest.raises(ValueError, match="adopt"):
            KVC.kv_wire_adopt(parts, 2)

    def test_cusz_slabs_flattened_not_padded(self):
        """The chunked codec sees [tokens, features], so tiny head/dim
        axes don't blow up Lorenzo-block padding; the logical slab shape
        rides in the header."""
        x = _cache(plen=256)
        parts = KVC.kv_wire_encode(x, 2, wire="cusz", nslabs=2)
        for p in parts:
            assert len(p.header.shape) == 2
            assert KVC.kv_slab_shape(p) == (1, 2, 256, 2, 8)


class TestEnginePhases:
    def _setup(self, compressed=True, arch="qwen2.5-3b"):
        cfg = configs.reduced(arch, n_periods=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12))
                             .astype(np.int32))
        scfg = E.ServeConfig(s_max=256, compressed_kv=compressed,
                             compute_dtype=jnp.float32)
        return cfg, params, prompt, scfg

    def test_disaggregated_matches_single_mesh(self):
        """prefill -> Containers -> reshard -> decode produces the exact
        single-mesh compressed token stream (int8-block adopt path)."""
        cfg, params, prompt, scfg = self._setup()
        ref = np.asarray(E.generate(params, cfg, prompt, 6, scfg))
        last, caches, plen = E.prefill(params, cfg, prompt, scfg)
        h = E.encode_handoff(caches, cfg, scfg, plen=plen)
        assert h.wire == "int8-block"
        assert E.LAST_HANDOFF_STATS["wire_bytes"] \
            < E.LAST_HANDOFF_STATS["raw_bf16_bytes"]
        caches2 = E.reshard_caches(h, cfg, scfg)
        assert E.LAST_RESHARD_STATS["adopted_quantkv"] == 2  # k and v
        assert E.LAST_RESHARD_STATS["decoded"] == 0          # no f32 trip
        toks = np.asarray(E.decode_tokens(params, cfg, scfg, last,
                                          caches2, plen, 6))
        np.testing.assert_array_equal(toks, ref)

    def test_cusz_wire_leg(self):
        """Host-offload leg: cusz containers cross, decode side
        re-quantizes; tokens mostly agree with the adopt path (lossy)."""
        cfg, params, prompt, scfg = self._setup()
        ref = np.asarray(E.generate(params, cfg, prompt, 6, scfg))
        last, caches, plen = E.prefill(params, cfg, prompt, scfg)
        h = E.encode_handoff(caches, cfg, scfg, wire="cusz", plen=plen)
        assert E.LAST_HANDOFF_STATS["wire_bytes"] \
            < E.LAST_HANDOFF_STATS["raw_bf16_bytes"]
        caches2 = E.reshard_caches(h, cfg, scfg)
        assert E.LAST_RESHARD_STATS["adopted_quantkv"] == 0
        toks = np.asarray(E.decode_tokens(params, cfg, scfg, last,
                                          caches2, plen, 6))
        assert (toks == ref).mean() > 0.5

    def test_reshard_hook_arms_wire(self):
        """use_kv_reshard_compress selects the handoff wire ambiently."""
        cfg, params, prompt, scfg = self._setup()
        _, caches, plen = E.prefill(params, cfg, prompt, scfg)
        with dist_ctx.use_kv_reshard_compress("cusz"):
            h = E.encode_handoff(caches, cfg, scfg, plen=plen)
        assert h.wire == "cusz"
        with dist_ctx.use_kv_reshard_compress(True):
            h = E.encode_handoff(caches, cfg, scfg, plen=plen)
        assert h.wire == "int8-block"
        # an explicit disarm means raw bytes, not a lossy fall-through
        with dist_ctx.use_kv_reshard_compress("cusz"):
            with dist_ctx.use_kv_reshard_compress(False):
                h = E.encode_handoff(caches, cfg, scfg, plen=plen)
        assert h.wire == "lossless"
        assert E.encode_handoff(caches, cfg, scfg, plen=plen).wire \
            == "int8-block"
        assert h.plen == plen

    def test_reshard_hook_validates_at_arm_time(self):
        with pytest.raises(ValueError):
            with dist_ctx.use_kv_reshard_compress("zfp"):
                pass
        with pytest.raises(ValueError):
            with dist_ctx.use_kv_reshard_compress("no-such-codec"):
                pass

    def test_hybrid_state_crosses_as_containers(self):
        """Mamba/SSD state ships lossless and reassembles exactly."""
        cfg, params, prompt, scfg = self._setup(arch="jamba-1.5-large-398b")
        ref = np.asarray(E.generate(params, cfg, prompt, 5, scfg))
        last, caches, plen = E.prefill(params, cfg, prompt, scfg)
        h = E.encode_handoff(caches, cfg, scfg, plen=plen)
        assert "state" in h.kinds and "kv" in h.kinds
        caches2 = E.reshard_caches(h, cfg, scfg)
        toks = np.asarray(E.decode_tokens(params, cfg, scfg, last,
                                          caches2, plen, 5))
        np.testing.assert_array_equal(toks, ref)


class TestServeStepCache:
    def test_generate_reuses_compiled_step(self):
        """Regression (ISSUE satellite): `generate` used to call
        jax.jit(make_serve_step(...)) per invocation, discarding the
        compiled step; now one trace serves repeated calls."""
        cfg = configs.reduced("qwen3-4b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        # unique scfg (distinct s_max) so no earlier test shares the key
        scfg = E.ServeConfig(s_max=384, compressed_kv=True)
        key = (cfg, scfg)
        E.STEP_TRACES.pop(key, None)
        a = np.asarray(E.generate(params, cfg, prompt, 4, scfg))
        assert E.STEP_TRACES[key] == 1
        b = np.asarray(E.generate(params, cfg, prompt, 4, scfg))
        assert E.STEP_TRACES[key] == 1          # no retrace on call 2
        np.testing.assert_array_equal(a, b)
        assert E.get_serve_step(cfg, scfg) is E.get_serve_step(cfg, scfg)


class TestMLACompressedKV:
    def test_mla_prefill_honors_compressed_kv(self):
        """Regression (ISSUE satellite): the MLA branch of prefill used
        to silently ignore scfg.compressed_kv; the latent cache now goes
        through the same block codec and decode consumes QuantKV."""
        cfg = configs.reduced("deepseek-v2-236b", n_periods=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jnp.zeros((2, 8), jnp.int32)
        scfg = E.ServeConfig(s_max=256, compressed_kv=True)
        _, caches, _ = E.prefill(params, cfg, prompt, scfg)
        assert isinstance(caches.entries[0], KVC.QuantKV)
        assert caches.entries[0].q.dtype == jnp.int8
        # and the compressed decode tracks the uncompressed one
        a = np.asarray(E.generate(params, cfg, prompt, 6,
                                  E.ServeConfig(s_max=256)))
        b = np.asarray(E.generate(params, cfg, prompt, 6, scfg))
        assert (a == b).mean() > 0.6

    def test_mla_init_caches_compressed_shape(self):
        cfg = configs.reduced("deepseek-v2-236b", n_periods=1)
        caches = M.init_caches(cfg, batch=2, s_max=256, compressed_kv=True)
        qkv = caches.entries[0]
        assert isinstance(qkv, KVC.QuantKV)
        R = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
        assert qkv.q.shape == (cfg.n_periods, 2, 256, R)
        assert qkv.scale.shape == (cfg.n_periods, 2,
                                   256 // KVC.SEQ_BLOCK, R)
