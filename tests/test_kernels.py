"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes/configs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dualquant as dq
from repro.kernels.lorenzo import ops as lorenzo_ops
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.deflate import ops as deflate_ops
from repro.kernels.encode import ops as encode_ops
from repro.core import huffman as hf


BLOCK_CASES = [
    # (data shape, block)
    ((1024,), (256,)),
    ((8192,), (4096,)),
    ((64, 64), (16, 16)),
    ((128, 256), (64, 128)),
    ((16, 16, 16), (8, 8, 8)),
    ((8, 32, 128), (8, 16, 128)),
]


def _blocked(shape, block, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    x = (np.cumsum(rng.standard_normal(shape), axis=-1) * scale).astype(np.float32)
    return dq.block_split(dq.pad_to_blocks(jnp.asarray(x), block), block)


class TestLorenzoKernel:
    @pytest.mark.parametrize("shape,block", BLOCK_CASES)
    @pytest.mark.parametrize("eb", [1e-2, 1e-3])
    def test_dualquant_matches_ref(self, shape, block, eb):
        xb = _blocked(shape, block, seed=hash((shape, block)) % 2**31)
        ck, dk = lorenzo_ops.dualquant_blocks(xb, eb, 1024, impl="pallas")
        cr, dr = lorenzo_ops.dualquant_blocks(xb, eb, 1024, impl="jax")
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))

    @pytest.mark.parametrize("shape,block", BLOCK_CASES)
    def test_reverse_matches_ref(self, shape, block):
        rng = np.random.default_rng(0)
        nb = tuple(-(-s // b) for s, b in zip(shape, block))
        delta = jnp.asarray(rng.integers(-500, 500, nb + block).astype(np.int32))
        rk = lorenzo_ops.reverse_blocks(delta, 1e-3, impl="pallas")
        rr = lorenzo_ops.reverse_blocks(delta, 1e-3, impl="jax")
        np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), rtol=0, atol=0)

    @pytest.mark.parametrize("nbins", [256, 1024])
    def test_fused_roundtrip_error_bound(self, nbins):
        """Kernel forward + kernel reverse obeys the paper's bound."""
        eb = 1e-3
        xb = _blocked((64, 128), (16, 16), seed=3, scale=0.1)
        codes, delta = lorenzo_ops.dualquant_blocks(xb, eb, nbins, impl="pallas")
        recon = lorenzo_ops.reverse_blocks(delta, eb, impl="pallas")
        err = np.abs(np.asarray(recon) - np.asarray(xb))
        assert err.max() <= eb * (1 + 1e-4) + 1e-7


class TestHistogramKernel:
    @pytest.mark.parametrize("n,nbins", [(1000, 256), (4096, 1024),
                                         (10000, 1024), (333, 128)])
    def test_matches_ref(self, n, nbins):
        rng = np.random.default_rng(n)
        codes = jnp.asarray(rng.integers(0, nbins, n).astype(np.int32))
        hk = hist_ops.histogram(codes, nbins, impl="pallas")
        hr = hist_ops.histogram(codes, nbins, impl="jax")
        np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))
        assert int(np.asarray(hk).sum()) == n

    def test_skewed_distribution(self):
        rng = np.random.default_rng(1)
        codes = jnp.asarray(np.clip(rng.normal(512, 3, 8192), 0, 1023).astype(np.int32))
        hk = hist_ops.histogram(codes, 1024, impl="pallas")
        hr = hist_ops.histogram(codes, 1024, impl="jax")
        np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))


class TestEncodeKernel:
    @pytest.mark.parametrize("n,k", [(100, 64), (4096, 1024), (513, 256)])
    def test_matches_ref(self, n, k):
        """One-hot-MXU codebook gather == reference gather, bit-exact
        (incl. full-width uint32 codewords through the int32 bitcast)."""
        rng = np.random.default_rng(n * 7 + k)
        p = 1.0 / np.arange(1, k + 1) ** 1.2
        codes = jnp.asarray(rng.choice(k, n, p=p / p.sum()).astype(np.int32))
        cb = hf.canonical_codebook(hf.codeword_lengths(hf.histogram(codes, k)))
        ck, bk = encode_ops.encode(codes, cb, impl="pallas")
        cr, br = encode_ops.encode(codes, cb, impl="jax")
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
        assert ck.dtype == jnp.uint32 and bk.dtype == jnp.int32


class TestDeflateKernel:
    @pytest.mark.parametrize("n,k,chunk", [(1000, 64, 512), (4096, 256, 512),
                                           (700, 1024, 512)])
    def test_matches_ref_bitstream(self, n, k, chunk):
        rng = np.random.default_rng(n + k)
        p = 1.0 / np.arange(1, k + 1) ** 1.5
        codes = jnp.asarray(rng.choice(k, n, p=p / p.sum()).astype(np.int32))
        cb = hf.canonical_codebook(hf.codeword_lengths(hf.histogram(codes, k)))
        cw, bw = hf.encode(codes, cb)
        wk, bk, gbk, gsk = deflate_ops.deflate(cw, bw, chunk, impl="pallas")
        wr, br, gbr, gsr = deflate_ops.deflate(cw, bw, chunk, impl="jax")
        np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
        np.testing.assert_array_equal(np.asarray(gbk), np.asarray(gbr))
        np.testing.assert_array_equal(np.asarray(gsk), np.asarray(gsr))

    def test_kernel_stream_decodes(self):
        """Kernel-produced bitstream must inflate back to the input."""
        rng = np.random.default_rng(5)
        n, k, chunk = 2000, 128, 512
        codes = rng.integers(0, k, n).astype(np.int32)
        cb = hf.canonical_codebook(hf.codeword_lengths(
            hf.histogram(jnp.asarray(codes), k)))
        cw, bw = hf.encode(jnp.asarray(codes), cb)
        words, bits, gap_bits, _ = deflate_ops.deflate(cw, bw, chunk,
                                                       impl="pallas")
        nc = words.shape[0]
        n_valid = np.minimum(chunk, np.maximum(n - np.arange(nc) * chunk, 0)
                             ).astype(np.int32)
        out = np.asarray(hf.inflate(words, bits, jnp.asarray(n_valid), cb,
                                    int(cb.max_len)))
        np.testing.assert_array_equal(out.reshape(-1)[:n], codes)


class TestInflateKernel:
    @pytest.mark.parametrize("n,k,chunk,sub", [(2000, 128, 512, 64),
                                               (700, 1024, 256, 32),
                                               (4096, 64, 512, 128)])
    def test_gap_kernel_matches_sequential(self, n, k, chunk, sub):
        """Pallas gap-array inflate == sequential reference, bit-exact;
        the decoded stream equals the original codes."""
        from repro.kernels.inflate import ops as inflate_ops
        rng = np.random.default_rng(n + k)
        codes = rng.integers(0, k, n).astype(np.int32)
        cb = hf.canonical_codebook(hf.codeword_lengths(
            hf.histogram(jnp.asarray(codes), k)))
        cw, bw = hf.encode(jnp.asarray(codes), cb)
        words, bits, gap_bits, _ = deflate_ops.deflate(
            cw, bw, chunk, sub, impl="pallas")
        nv = jnp.asarray(np.minimum(
            chunk, np.maximum(n - np.arange(words.shape[0]) * chunk, 0)
        ).astype(np.int32))
        ml = hf.bucket_max_len(max(1, int(cb.max_len)))
        table = hf.decode_table(cb.lengths, ml)
        seq = np.asarray(hf.inflate(words, bits, nv, cb, ml))
        out = np.asarray(inflate_ops.inflate(
            words, bits, nv, table, ml, gaps=gap_bits,
            impl="pallas-interpret"))
        np.testing.assert_array_equal(out, seq)
        np.testing.assert_array_equal(out.reshape(-1)[:n], codes)
