"""cuZFP-like baseline tests."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import zfp_like as Z, metrics as M
from repro.data import scidata


class TestZfpLike:
    def test_negabinary_exact(self):
        rng = np.random.default_rng(0)
        i = jnp.asarray(rng.integers(-2**30, 2**30, 4096).astype(np.int32))
        out = Z._inv_negabinary(Z._negabinary(i))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(i))

    def test_lift_near_inverse(self):
        """ZFP's fwd/inv lifting loses only low bits (|err| small vs 2^30
        fixed-point magnitudes)."""
        rng = np.random.default_rng(1)
        v = jnp.asarray(rng.integers(-2**27, 2**27, (64, 4)).astype(np.int32))
        err = np.abs(np.asarray(Z._inv_lift(Z._fwd_lift(v, 1), 1)) -
                     np.asarray(v))
        assert err.max() <= 8

    @pytest.mark.parametrize("name,shape", [
        ("cesm", None), ("hurricane", None), ("nyx", None)])
    def test_rate_monotone_psnr(self, name, shape):
        f = jnp.asarray(scidata.all_fields(small=True)[name])
        psnrs = []
        for rate in (6, 10, 14):
            rec, _ = Z.compress_decompress(f, rate)
            psnrs.append(float(M.psnr(f, rec)))
        assert psnrs[0] < psnrs[1] < psnrs[2]

    def test_4d_field(self):
        f = jnp.asarray(scidata.qmcpack_like((6, 24, 24, 24)))
        rec, br = Z.compress_decompress(f, 12)
        assert rec.shape == f.shape
        assert float(M.psnr(f, rec)) > 40
