"""Dual-quantization unit + property tests (paper §3.1, Algorithm 2)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dualquant as dq


class TestLorenzo:
    @pytest.mark.parametrize("shape,axes", [((64,), (0,)), ((16, 24), (0, 1)),
                                            ((8, 10, 12), (0, 1, 2))])
    def test_delta_reconstruct_inverse(self, shape, axes):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-1000, 1000, shape).astype(np.int32))
        d = dq.lorenzo_delta(x, axes)
        r = dq.lorenzo_reconstruct(d, axes)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(x))

    def test_2d_delta_matches_paper_formula(self):
        """δ[a,b] = d[a,b] − d[a−1,b] − d[a,b−1] + d[a−1,b−1] (paper Fig 1)."""
        rng = np.random.default_rng(1)
        x = rng.integers(-50, 50, (9, 11)).astype(np.int32)
        d = np.asarray(dq.lorenzo_delta(jnp.asarray(x), (0, 1)))
        xp = np.pad(x, ((1, 0), (1, 0)))
        expect = xp[1:, 1:] - xp[:-1, 1:] - xp[1:, :-1] + xp[:-1, :-1]
        np.testing.assert_array_equal(d, expect)

    def test_zero_padding_layer(self):
        """First row/col predict from the implicit zero layer (paper §3.1.1:
        outer layer falls back to lower-order Lorenzo)."""
        x = jnp.asarray([[5, 7], [9, 13]], dtype=jnp.int32)
        d = np.asarray(dq.lorenzo_delta(x, (0, 1)))
        assert d[0, 0] == 5           # predicted 0
        assert d[0, 1] == 2           # 1D fallback: 7-5
        assert d[1, 0] == 4           # 1D fallback: 9-5
        assert d[1, 1] == 13 - 9 - 7 + 5


class TestBlocking:
    @pytest.mark.parametrize("shape,block", [((100,), (32,)), ((33, 21), (16, 16)),
                                             ((9, 17, 11), (8, 8, 8))])
    def test_split_merge_roundtrip(self, shape, block):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
        xp = dq.pad_to_blocks(x, block)
        m = dq.block_merge(dq.block_split(xp, block), block)
        np.testing.assert_array_equal(np.asarray(m), np.asarray(xp))

    def test_blocks_are_independent(self):
        """Changing one block must not change another block's deltas."""
        rng = np.random.default_rng(3)
        x = rng.standard_normal((32, 32)).astype(np.float32)
        d1 = np.asarray(dq.blocked_delta(jnp.asarray(x), 1e-3, (16, 16)))
        x2 = x.copy(); x2[:16, :16] += 100.0
        d2 = np.asarray(dq.blocked_delta(jnp.asarray(x2), 1e-3, (16, 16)))
        np.testing.assert_array_equal(d1[0, 1], d2[0, 1])
        np.testing.assert_array_equal(d1[1, 1], d2[1, 1])


class TestPrequant:
    @given(st.floats(min_value=1e-4, max_value=10.0),
           st.integers(min_value=-2**20, max_value=2**20))
    @settings(max_examples=50, deadline=None)
    def test_prequant_error_bounded(self, eb, seed):
        rng = np.random.default_rng(abs(seed))
        d = rng.uniform(-100, 100, 64).astype(np.float32)
        dqv = dq.prequant(jnp.asarray(d), eb)
        rec = np.asarray(dq.dequant(dqv, eb))
        # |d − d°·2eb| ≤ eb up to fp32 representability (DESIGN.md §8)
        slack = 4 * np.finfo(np.float32).eps * np.abs(d).max()
        assert np.all(np.abs(d - rec) <= eb * (1 + 1e-5) + slack)


class TestOutliers:
    def test_extract_scatter_roundtrip(self):
        rng = np.random.default_rng(4)
        delta = jnp.asarray(rng.integers(-10_000, 10_000, 500).astype(np.int32))
        codes, in_cap = dq.postquant_codes(delta, 1024)
        idx, val, n = dq.extract_outliers(delta, in_cap, capacity=500)
        rec = dq.codes_to_delta(codes, 1024)
        rec = dq.scatter_outliers(rec, idx, val)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(delta))
        assert int(n) == int(np.sum(~np.asarray(in_cap)))

    def test_code_zero_reserved_for_outlier(self):
        delta = jnp.asarray([0, -511, 511, -512, 512, 100000], dtype=jnp.int32)
        codes, in_cap = dq.postquant_codes(delta, 1024)
        c = np.asarray(codes); m = np.asarray(in_cap)
        assert m.tolist() == [True, True, True, False, False, False]
        assert (c[~m] == 0).all() and (c[m] > 0).all()
