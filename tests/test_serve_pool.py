"""Paged compressed-KV pool: allocator properties, page math, eviction.

The tentpole invariants:

* allocator — under random admit/grow/evict/restore/release traces, no
  device page id is ever live twice, the free list is conserved
  (``free + used == n_pages``), and occupancy accounting is exact.
* page math — `kv_page_slice`/`kv_page_concat` are inverse payload-space
  ops, and a slot assembled from pages is BIT-identical to the
  whole-tensor int8-block path (the PR-5 zero-requantize trick at page
  granularity).
* eviction — evict->restore through "int8-block" is bit-exact; through
  "cusz"/"lossless" it holds the stacked error bound (codec bound +
  requantize scale/2).
"""
from __future__ import annotations

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import kvcache as KVC
from repro.serve.pool import PagedKVPool, PoolExhausted

SEQ_AXIS = 2


def _quantkv(key, n_blocks: int, heads: int = 2, dim: int = 4):
    x = jax.random.normal(key, (1, 1, n_blocks * KVC.SEQ_BLOCK, heads, dim),
                          jnp.float32)
    return KVC.kv_quantize(x, SEQ_AXIS)


@pytest.fixture(scope="module")
def page_slab():
    """One reusable page slab (content is irrelevant to the allocator)."""
    return KVC.kv_page_slice(_quantkv(jax.random.PRNGKey(0), 1),
                             SEQ_AXIS, 0)


# ---------------------------------------------------------------------------
# allocator property test: random traces keep the accounting exact
# ---------------------------------------------------------------------------

def _check_invariants(pool: PagedKVPool):
    pids = [p.pid for t in pool._tables.values() for p in t if p.resident]
    assert len(pids) == len(set(pids)), f"double-allocated page: {pids}"
    assert pool.free_pages + pool.used_pages == pool.n_pages
    assert len(pids) == pool.used_pages
    assert not (set(pids) & set(pool._free)), "page both free and live"
    assert set(pids) | set(pool._free) <= set(range(pool.n_pages))
    assert pool.occupancy == pool.used_pages / pool.n_pages
    st_ = pool.stats()
    assert st_["used"] == pool.used_pages and st_["free"] == pool.free_pages
    assert (st_["host_bytes"] > 0) == (st_["host_pages"] > 0)


@settings(max_examples=12)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=9))
def test_allocator_random_trace_invariants(page_slab, seed, n_pages):
    rng = random.Random(seed)
    pool = PagedKVPool(n_pages, evict_codec="int8-block",
                       source_dtype=jnp.float32)
    next_sid = 0
    for _ in range(60):
        op = rng.choice(["admit", "grow", "evict", "restore", "release"])
        sids = pool.sequences()
        try:
            if op == "admit":
                sid = next_sid
                next_sid += 1
                pool.register(sid)
                for _ in range(rng.randint(1, 3)):
                    pool.append_page(sid, (page_slab,))
            elif op == "grow" and sids:
                pool.append_page(rng.choice(sids), (page_slab,))
            elif op == "evict" and sids:
                sid = rng.choice(sids)
                if pool.n_pages_of(sid):
                    pool.evict_page(sid,
                                    rng.randrange(pool.n_pages_of(sid)))
            elif op == "restore" and sids:
                sid = rng.choice(sids)
                if pool.n_pages_of(sid):
                    pool.restore_page(sid,
                                      rng.randrange(pool.n_pages_of(sid)))
            elif op == "release" and sids:
                pool.release(rng.choice(sids))
        except PoolExhausted:
            # a partially admitted sequence stays registered; its pages
            # so far must still satisfy every invariant
            pass
        _check_invariants(pool)
    # drain: releasing everything returns the pool to fully free
    for sid in pool.sequences():
        pool.release(sid)
    assert pool.used_pages == 0
    assert sorted(pool._free) == list(range(pool.n_pages))
    assert pool.stats()["host_bytes"] == 0


def test_exhaustion_raises_and_recovers(page_slab):
    pool = PagedKVPool(2, evict_codec="int8-block",
                       source_dtype=jnp.float32)
    pool.register("a")
    pool.append_page("a", (page_slab,))
    pool.append_page("a", (page_slab,))
    pool.register("b")
    with pytest.raises(PoolExhausted):
        pool.append_page("b", (page_slab,))
    # eviction frees a device page; the retry succeeds
    assert pool.evict_page("a", 0)
    pool.append_page("b", (page_slab,))
    assert pool.used_pages == 2 and pool.stats()["host_pages"] == 1


def test_evict_cold_prefers_least_recently_touched(page_slab):
    pool = PagedKVPool(4, evict_codec="int8-block",
                       source_dtype=jnp.float32)
    for sid in ("old", "hot"):
        pool.register(sid)
        pool.append_page(sid, (page_slab,))
        pool.append_page(sid, (page_slab,))
    pool.touch("hot")
    freed = pool.evict_cold(2, exclude=())
    assert freed == 2
    assert pool.n_resident("old") == 0       # cold sequence went first
    assert pool.n_resident("hot") == 2


# ---------------------------------------------------------------------------
# page math: slice/concat inverse + bit-identity of page-wise transport
# ---------------------------------------------------------------------------

def test_page_slice_concat_roundtrip_bitwise():
    qkv = _quantkv(jax.random.PRNGKey(1), 4)
    n = KVC.kv_page_count(qkv.q.shape[SEQ_AXIS])
    assert n == 4
    pages = [KVC.kv_page_slice(qkv, SEQ_AXIS, i) for i in range(n)]
    for p in pages:
        assert p.q.shape[SEQ_AXIS] == KVC.SEQ_BLOCK
        assert p.scale.shape[SEQ_AXIS] == 1
    back = KVC.kv_page_concat(pages, SEQ_AXIS)
    assert np.array_equal(np.asarray(back.q), np.asarray(qkv.q))
    assert np.array_equal(np.asarray(back.scale), np.asarray(qkv.scale))


def test_page_count():
    assert KVC.kv_page_count(0) == 0
    assert KVC.kv_page_count(1) == 1
    assert KVC.kv_page_count(KVC.SEQ_BLOCK) == 1
    assert KVC.kv_page_count(KVC.SEQ_BLOCK + 1) == 2


def test_adopted_slot_bit_identical_to_whole_tensor_path():
    """Pages written into a batched decode slot must reproduce the
    whole-tensor quantize path bit for bit — including the
    zero/SCALE_FLOOR extension past the written pages (what `prefill`
    puts there), so decode from an adopted slot is the PR-5 path."""
    from repro.serve.scheduler import _adopt_slot

    n_blocks, s_blocks = 2, 4            # 2 written pages in a 4-page slot
    qkv = _quantkv(jax.random.PRNGKey(2), n_blocks)
    pages = [KVC.kv_page_slice(qkv, SEQ_AXIS, i) for i in range(n_blocks)]

    # reference: whole padded buffer through kv_quantize (prefill's path)
    full = KVC.kv_dequantize(qkv, SEQ_AXIS, jnp.float32)
    pad = jnp.zeros(full.shape[:2]
                    + ((s_blocks - n_blocks) * KVC.SEQ_BLOCK,)
                    + full.shape[3:], full.dtype)
    ref = KVC.kv_quantize(jnp.concatenate([full, pad], axis=SEQ_AXIS),
                          SEQ_AXIS)

    buf = KVC.QuantKV(
        jnp.ones((1, 3, s_blocks * KVC.SEQ_BLOCK) + qkv.q.shape[3:],
                 jnp.int8),              # poisoned: adoption must reset
        jnp.full((1, 3, s_blocks) + qkv.scale.shape[3:], 7.0, jnp.float32))
    slot = 1
    out = _adopt_slot(buf, pages, slot, SEQ_AXIS)
    assert np.array_equal(np.asarray(out.q[:, slot]),
                          np.asarray(ref.q[:, 0]))
    assert np.array_equal(np.asarray(out.scale[:, slot]),
                          np.asarray(ref.scale[:, 0]))
    # other slots untouched
    assert np.all(np.asarray(out.q[:, 0]) == 1)
    assert np.all(np.asarray(out.scale[:, 2]) == 7.0)


# ---------------------------------------------------------------------------
# evict -> restore error bounds per codec
# ---------------------------------------------------------------------------

def _evict_restore(codec: str):
    qkv = _quantkv(jax.random.PRNGKey(3), 2)
    pages = [KVC.kv_page_slice(qkv, SEQ_AXIS, i) for i in range(2)]
    pool = PagedKVPool(2, evict_codec=codec, source_dtype=jnp.float32)
    pool.register("s")
    for p in pages:
        pool.append_page("s", (p,))
    assert pool.evict_sequence("s") == 2
    assert pool.used_pages == 0 and pool.stats()["host_bytes"] > 0
    assert pool.ensure_resident("s") == 2
    return pages, [c[0] for c in pool.read_pages("s")]


def test_evict_restore_int8_block_bit_exact():
    pages, restored = _evict_restore("int8-block")
    for orig, back in zip(pages, restored):
        assert np.array_equal(np.asarray(back.q), np.asarray(orig.q))
        assert np.array_equal(np.asarray(back.scale),
                              np.asarray(orig.scale))


@pytest.mark.parametrize("codec", ["cusz", "lossless"])
def test_evict_restore_lossy_holds_error_bound(codec):
    pages, restored = _evict_restore(codec)
    for orig, back in zip(pages, restored):
        a = np.asarray(KVC.kv_dequantize(orig, SEQ_AXIS, jnp.float32))
        b = np.asarray(KVC.kv_dequantize(back, SEQ_AXIS, jnp.float32))
        # restore re-quantizes: its own bound is scale_new/2 per element
        requant = np.broadcast_to(
            np.asarray(back.scale).repeat(KVC.SEQ_BLOCK, SEQ_AXIS) / 2,
            a.shape)
        if codec == "cusz":
            # default wire cfg: valrel eb on the dequantized slab
            eb = KVC.CUSZ_WIRE_CFG["eb"] * (a.max() - a.min())
        else:
            eb = 0.0
        assert np.all(np.abs(a - b) <= requant + eb + 1e-6), codec


def test_bad_evict_codec_rejected_at_construction():
    with pytest.raises(Exception):
        PagedKVPool(2, evict_codec="no-such-codec")


def test_evict_codec_resolves_from_context_hook():
    from repro.dist import context as dist_ctx

    with dist_ctx.use_kv_evict_codec("lossless"):
        assert PagedKVPool(2).evict_codec == "lossless"
        # explicit arg still wins over the armed hook
        assert PagedKVPool(2, evict_codec="int8-block"
                           ).evict_codec == "int8-block"
    assert PagedKVPool(2).evict_codec == "cusz"   # default past the scope
