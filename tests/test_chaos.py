"""Chaos-tested resilience layer: fault injection (`dist.chaos`),
straggler mitigation (`dist.fault.MitigationPolicy`), container
checksums, checkpoint quarantine/rollback, and async-writer retry.

Every injected failure here is deterministic (seeded schedule), so these
are reproducible tests of the recovery paths, not flaky chaos runs."""
import glob
import json
import os
import tempfile
import time
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro import codecs
from repro.dist import chaos, fault
from repro.io import checkpoint as CK
from repro.io.async_writer import AsyncWriter


# ---------------------------------------------------------------------------
# chaos config + monkey
# ---------------------------------------------------------------------------

class TestChaosSpec:
    def test_from_spec_full_grammar(self):
        cfg = chaos.from_spec(
            "straggler:host=3,delay=0.25,start=2,stop=9;"
            "writer:failures=2,kind=partial;nan:steps=7+8;corrupt:shards=1",
            seed=5, nhosts=8)
        assert cfg.straggler_host == 3 and cfg.straggler_delay_s == 0.25
        assert (cfg.straggler_start, cfg.straggler_stop) == (2, 9)
        assert cfg.writer_failures == 2 and cfg.writer_fault == "partial"
        assert cfg.nan_steps == (7, 8)
        assert cfg.corrupt_shards == 1
        assert cfg.seed == 5 and cfg.nhosts == 8

    def test_from_spec_defaults_and_unknown_group(self):
        cfg = chaos.from_spec("writer:", nhosts=2)
        assert cfg.writer_failures == 1 and cfg.writer_fault == "raise"
        with pytest.raises(ValueError, match="unknown chaos group"):
            chaos.from_spec("gremlin:count=3")

    def test_use_chaos_none_is_noop(self):
        with chaos.use_chaos(None) as monkey:
            assert monkey is None
            assert chaos.current() is None

    def test_current_tracks_context(self):
        cfg = chaos.ChaosConfig(nhosts=4)
        assert chaos.current() is None
        with chaos.use_chaos(cfg) as monkey:
            assert chaos.current() is monkey
        assert chaos.current() is None


class TestChaosMonkey:
    def test_straggler_simulation_contract(self):
        """dur[h] = compute*share*n + delay*share*n on the straggler:
        shrinking the straggler's share genuinely shrinks its duration."""
        cfg = chaos.ChaosConfig(nhosts=4, straggler_host=1,
                                straggler_delay_s=0.4)
        m = chaos.ChaosMonkey(cfg)
        durs = m.host_step_times(0, 0.1)
        np.testing.assert_allclose(durs, [0.1, 0.5, 0.1, 0.1])
        half = np.array([1.25, 0.25, 1.25, 1.25]) / 4.0
        durs2 = m.host_step_times(0, 0.1, shares=half)
        assert durs2[1] == pytest.approx((0.1 + 0.4) * 0.25 / 4 * 4)
        assert durs2[1] < durs[1]

    def test_straggler_window(self):
        cfg = chaos.ChaosConfig(nhosts=2, straggler_host=0,
                                straggler_delay_s=1.0,
                                straggler_start=3, straggler_stop=5)
        m = chaos.ChaosMonkey(cfg)
        assert [m.straggler_active(s) for s in range(6)] == \
            [False, False, False, True, True, False]

    def test_inject_step_sleeps_the_modeled_extra(self):
        cfg = chaos.ChaosConfig(nhosts=2, straggler_host=0,
                                straggler_delay_s=0.05)
        m = chaos.ChaosMonkey(cfg)
        t0 = time.perf_counter()
        total, durs = m.inject_step(0, 0.0)
        wall = time.perf_counter() - t0
        assert total == pytest.approx(float(durs.max()))
        assert wall >= 0.04                      # the sleep is real
        assert m.events and m.events[0]["kind"] == "straggler-delay"

    def test_nan_burst_schedule(self):
        m = chaos.ChaosMonkey(chaos.ChaosConfig(nan_steps=(2, 5)))
        assert [m.nan_burst(s) for s in range(6)] == \
            [False, False, True, False, False, True]
        assert sum(e["kind"] == "nan-burst" for e in m.events) == 2

    def test_pre_write_raises_exactly_n_transient_errors(self):
        m = chaos.ChaosMonkey(chaos.ChaosConfig(writer_failures=2))
        for _ in range(2):
            with pytest.raises(chaos.TransientWriteError):
                m.pre_write("/tmp/x")
        m.pre_write("/tmp/x")                    # budget exhausted
        assert isinstance(chaos.TransientWriteError("x"), OSError)

    def test_post_write_partial_truncates(self, tmp_path):
        p = str(tmp_path / "f.bin")
        with open(p, "wb") as f:
            f.write(bytes(1000))
        m = chaos.ChaosMonkey(chaos.ChaosConfig(writer_failures=1,
                                                writer_fault="partial"))
        m.post_write(p)
        assert os.path.getsize(p) == 600
        m.post_write(p)                          # budget exhausted
        assert os.path.getsize(p) == 600

    def test_post_write_corrupt_flips_one_byte(self, tmp_path):
        p = str(tmp_path / "f.bin")
        payload = bytes(range(256)) * 8
        with open(p, "wb") as f:
            f.write(payload)
        m = chaos.ChaosMonkey(chaos.ChaosConfig(corrupt_shards=1, seed=3))
        m.post_write(p)
        got = open(p, "rb").read()
        assert len(got) == len(payload)
        diff = [i for i in range(len(payload)) if got[i] != payload[i]]
        assert len(diff) == 1 and diff[0] >= len(payload) // 2
        m.post_write(p)                          # budget exhausted
        assert open(p, "rb").read() == got


# ---------------------------------------------------------------------------
# container checksums + corruption helpers
# ---------------------------------------------------------------------------

class TestContainerChecksums:
    def _packed(self, name="lossless"):
        codec = codecs.get(name)
        x = jnp.asarray(np.linspace(-2, 7, 96, dtype=np.float32)
                        .reshape(3, 32))
        return codec, codec.pack(codec.encode(x))

    def test_pack_stamps_and_verifies(self):
        _, c = self._packed()
        assert c.header.param("checksum") is not None
        assert codecs.verify_container(c)
        codecs.check_container(c)                # no raise

    def test_corrupt_container_always_detected(self):
        codec, c = self._packed()
        bad = chaos.corrupt_container(c, seed=11)
        assert not codecs.verify_container(bad)
        with pytest.raises(codecs.ChecksumError, match="checksum"):
            codecs.check_container(bad)
        with pytest.raises(codecs.ChecksumError):
            codecs.decode(bad, verify=True)

    def test_unpack_drops_checksum_from_device_header(self):
        """The checksum covers stored bytes only: the unpacked (device)
        header — a jit cache key — must not vary with it."""
        codec, c = self._packed("cusz")
        u = codec.unpack(c)
        assert u.header.param("checksum", None) is None

    def test_unchecksummed_container_passes(self):
        codec = codecs.get("lossless")
        c = codec.encode(jnp.ones((4, 4)))       # device form: no checksum
        assert codecs.verify_container(c)
        codecs.check_container(c)


# ---------------------------------------------------------------------------
# straggler detection fixes (satellite: reset/decay)
# ---------------------------------------------------------------------------

class TestStragglerDetectorPerHost:
    def test_penalty_decays_on_clean_steps(self):
        d = fault.StragglerDetector(warmup=2, penalty_decay=0.5)
        for s in range(2):
            d.observe(s, 0.1)
        assert d.observe(2, 1.0)                 # flagged
        assert d.penalty == 1.0
        d.observe(3, 0.1)
        d.observe(4, 0.1)
        assert d.penalty == pytest.approx(0.25)  # decayed, not cumulative
        assert d.n_flagged == 1                  # telemetry stays monotone

    def test_reset_host_clears_only_that_host(self):
        d = fault.StragglerDetector(warmup=1)
        d.observe(0, 0.1, host=0)
        d.observe(0, 0.1, host=1)
        d.observe(1, 1.0, host=0)
        assert d.host(0).n_flagged == 1
        d.reset(host=0)
        assert d.host(0).n_observed == 0         # fresh child
        assert d.host(1).n_observed == 1         # untouched

    def test_reset_all_clears_children(self):
        d = fault.StragglerDetector(warmup=1)
        d.observe(0, 0.1, host=3)
        d.reset()
        assert d._hosts == {} and d.n_observed == 0


# ---------------------------------------------------------------------------
# mitigation policy
# ---------------------------------------------------------------------------

def _drive(policy, monkey, steps, compute=0.1, start=0):
    """Feed modeled per-host durations (no real sleeping) and return the
    per-step cluster step time ratio vs the fault-free compute."""
    ratios = []
    for s in range(start, start + steps):
        durs = monkey.host_step_times(s, compute, policy.shares)
        policy.observe(s, durs)
        ratios.append(float(np.max(durs)) / compute)
    return ratios


class TestMitigationPolicy:
    def test_rebalance_recovers_step_time(self):
        """Acceptance: a 5x straggler is rebalanced to within ~1.2x of
        the fault-free step time, and stays there (no limit cycle)."""
        monkey = chaos.ChaosMonkey(chaos.ChaosConfig(
            nhosts=8, straggler_host=3, straggler_delay_s=0.4))
        policy = fault.MitigationPolicy(8)
        ratios = _drive(policy, monkey, 12)
        assert ratios[0] == pytest.approx(5.0)   # fault is real pre-mitigation
        assert max(ratios[-4:]) <= 1.25, ratios
        assert any(e["kind"] == "rebalance" for e in policy.events)
        assert not policy.excluded
        # shares stay a simplex and the straggler genuinely lost work
        assert policy.shares.sum() == pytest.approx(1.0)
        assert policy.shares[3] < 1.0 / 8

    def test_slow_since_step0_is_caught(self):
        """The relative (cross-host median) flag: a host slow from its
        very first step has a poisoned self-baseline and can only be
        caught by comparison against its peers."""
        monkey = chaos.ChaosMonkey(chaos.ChaosConfig(
            nhosts=4, straggler_host=0, straggler_delay_s=0.5))
        policy = fault.MitigationPolicy(4)
        ratios = _drive(policy, monkey, 10)
        # capacity floor: 3 healthy hosts carry ~4/3 of uniform work, so
        # ~1.38x is the best possible here — assert we converge onto it
        # from the 6x fault, not the 1.2x an 8-host cluster can reach
        assert ratios[0] == pytest.approx(6.0)
        assert ratios[-1] <= 1.45, ratios

    def test_persistent_straggler_excluded(self):
        """A host so slow that rebalancing bottoms out at min_share gets
        excluded outright (share 0), and the cluster recovers fully."""
        monkey = chaos.ChaosMonkey(chaos.ChaosConfig(
            nhosts=4, straggler_host=2, straggler_delay_s=50.0))
        policy = fault.MitigationPolicy(4)
        ratios = _drive(policy, monkey, 20)
        assert 2 in policy.excluded
        assert policy.shares[2] == 0.0
        assert any(e["kind"] == "exclude-host" for e in policy.events)
        # remaining hosts take over: modeled time back to ~uniform work
        assert ratios[-1] <= 1.4, ratios

    def test_shares_restore_after_straggler_heals(self):
        monkey = chaos.ChaosMonkey(chaos.ChaosConfig(
            nhosts=8, straggler_host=3, straggler_delay_s=0.4,
            straggler_stop=12))
        policy = fault.MitigationPolicy(8)
        _drive(policy, monkey, 12)
        assert policy.shares[3] < 1.0 / 8        # mitigated while faulty
        _drive(policy, monkey, 25, start=12)     # healed: delay off
        np.testing.assert_allclose(policy.shares, 1.0 / 8)  # exact uniform
        assert any(e["kind"] == "host-recovered" for e in policy.events)

    def test_on_bad_loss_skips_and_logs(self):
        policy = fault.MitigationPolicy(2)
        assert not policy.on_bad_loss(0, 1.25)
        assert policy.on_bad_loss(1, float("nan"))
        assert policy.on_bad_loss(2, float("inf"))
        assert policy.n_skipped == 2
        skips = [e for e in policy.events if e["kind"] == "skip-step"]
        assert [e["step"] for e in skips] == [1, 2]

    def test_operator_reset_readmits_excluded_host(self):
        monkey = chaos.ChaosMonkey(chaos.ChaosConfig(
            nhosts=4, straggler_host=1, straggler_delay_s=50.0))
        policy = fault.MitigationPolicy(4)
        _drive(policy, monkey, 20)
        assert 1 in policy.excluded
        policy.reset(1)
        assert 1 not in policy.excluded
        assert policy.shares[1] > 0
        assert policy.shares.sum() == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# checkpoint quarantine + rollback
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(np.cumsum(rng.standard_normal((32, 64)),
                                       axis=-1).astype(np.float32)),
            "step": jnp.asarray(np.int32(seed))}


def _save_steps(d, steps, nshards=2):
    for s in steps:
        CK.save_checkpoint(d, s, _tree(seed=s),
                           policy=CK.CheckpointPolicy(codec="lossless"),
                           nshards=nshards)


def _shards(d, step):
    return sorted(glob.glob(os.path.join(
        d, f"step_{step:08d}", "shard_*.npz")))


class TestCheckpointQuarantine:
    def test_corrupted_latest_falls_back_to_last_good(self):
        with tempfile.TemporaryDirectory() as d:
            _save_steps(d, [10, 20, 30])
            chaos.corrupt_file(_shards(d, 30)[0])
            restored, step = CK.load_checkpoint(d, _tree())
            assert step == 20
            np.testing.assert_array_equal(np.asarray(restored["step"]), 20)
            # structured quarantine report rides in the restore stats
            reports = CK.LAST_RESTORE_STATS["quarantine"]
            assert len(reports) == 1 and reports[0]["step"] == 30
            assert reports[0]["error_type"]
            # the bad step is marked on disk and skipped from now on
            assert os.path.exists(os.path.join(
                d, "step_00000030", CK._QUARANTINE_MARK))
            assert CK.available_steps(d) == [10, 20]
            assert CK.latest_step(d) == 20

    def test_two_corrupt_steps_fall_back_twice(self):
        with tempfile.TemporaryDirectory() as d:
            _save_steps(d, [10, 20, 30])
            chaos.corrupt_file(_shards(d, 30)[0])
            chaos.corrupt_file(_shards(d, 20)[1], seed=1)
            _, step = CK.load_checkpoint(d, _tree())
            assert step == 10
            assert [r["step"] for r in
                    CK.LAST_RESTORE_STATS["quarantine"]] == [30, 20]

    def test_quarantine_false_raises_immediately(self):
        with tempfile.TemporaryDirectory() as d:
            _save_steps(d, [10, 20])
            chaos.corrupt_file(_shards(d, 20)[0])
            with pytest.raises(CK.CheckpointCorruptionError) as ei:
                CK.load_checkpoint(d, _tree(), quarantine=False)
            assert ei.value.reports[0]["step"] == 20
            # nothing was marked: the operator opted out of fallback
            assert CK.available_steps(d) == [10, 20]

    def test_all_steps_corrupt_raises_with_full_report(self):
        with tempfile.TemporaryDirectory() as d:
            _save_steps(d, [10, 20])
            chaos.corrupt_file(_shards(d, 10)[0])
            chaos.corrupt_file(_shards(d, 20)[0], seed=1)
            with pytest.raises(CK.CheckpointCorruptionError) as ei:
                CK.load_checkpoint(d, _tree())
            assert sorted(r["step"] for r in ei.value.reports) == [10, 20]

    def test_explicit_step_falls_back_below_it(self):
        with tempfile.TemporaryDirectory() as d:
            _save_steps(d, [10, 20, 30])
            chaos.corrupt_file(_shards(d, 20)[0])
            _, step = CK.load_checkpoint(d, _tree(), step=20)
            assert step == 10                    # never forward to 30

    def test_format_gate_errors_still_propagate(self):
        """A wrong-format manifest is an operator error, not corruption:
        it must raise the actionable ValueError, not quarantine."""
        with tempfile.TemporaryDirectory() as d:
            sd = os.path.join(d, "step_00000000")
            os.makedirs(sd)
            with open(os.path.join(sd, "manifest.json"), "w") as f:
                json.dump({"step": 0, "format": 1, "tensors": {}}, f)
            with pytest.raises(ValueError, match="predates"):
                CK.load_checkpoint(d, {})


class TestWriterChaos:
    def test_transient_write_fault_retried_to_success(self):
        """chaos 'raise' faults are OSError-classed, so the AsyncWriter
        retry loop absorbs them and the checkpoint still lands."""
        cfg = chaos.ChaosConfig(writer_failures=1)
        with tempfile.TemporaryDirectory() as d, chaos.use_chaos(cfg):
            with AsyncWriter(max_pending=1, retries=2,
                             backoff_s=0.001) as w:
                CK.save_checkpoint(d, 0, _tree(), writer=w)
                w.wait()
                assert w.n_retries == 1
            restored, step = CK.load_checkpoint(d, _tree())
            assert step == 0

    def test_transient_fault_without_retries_surfaces(self):
        cfg = chaos.ChaosConfig(writer_failures=1)
        with tempfile.TemporaryDirectory() as d, chaos.use_chaos(cfg):
            w = AsyncWriter(max_pending=1, retries=0)
            CK.save_checkpoint(d, 0, _tree(), writer=w)
            with pytest.raises(chaos.TransientWriteError):
                w.wait()
            w.close()
            assert CK.latest_step(d) is None     # tmp dir never promoted

    def test_partial_write_quarantined_at_restore(self):
        """A silently-truncated shard passes the save, then trips the
        integrity check at restore and falls back to the prior step."""
        with tempfile.TemporaryDirectory() as d:
            _save_steps(d, [10], nshards=2)
            cfg = chaos.ChaosConfig(writer_failures=1,
                                    writer_fault="partial")
            with chaos.use_chaos(cfg) as monkey:
                _save_steps(d, [20], nshards=2)
                assert any(e["kind"] == "partial-write"
                           for e in monkey.events)
            assert CK.latest_step(d) == 20       # damage is silent...
            _, step = CK.load_checkpoint(d, _tree())
            assert step == 10                    # ...until restore catches it
            assert CK.LAST_RESTORE_STATS["quarantine"][0]["step"] == 20


# ---------------------------------------------------------------------------
# async writer: retry/backoff, wait(timeout), close-time error surfacing
# ---------------------------------------------------------------------------

class TestAsyncWriterResilience:
    def test_close_reraises_error_from_final_task(self):
        """Regression: an error landing after the last submit/wait used
        to be swallowed by close() — the lost-checkpoint bug."""
        w = AsyncWriter()
        w.submit(lambda: (_ for _ in ()).throw(IOError("last write died")))
        with pytest.raises(IOError, match="last write died"):
            w.close()

    def test_retries_transient_then_succeeds(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise OSError("transient")

        with AsyncWriter(retries=3, backoff_s=0.001) as w:
            w.submit(flaky)
            w.wait()
        assert calls["n"] == 3
        assert w.n_retries == 2

    def test_retry_budget_exhausted_surfaces_error(self):
        w = AsyncWriter(retries=1, backoff_s=0.001)
        w.submit(lambda: (_ for _ in ()).throw(OSError("always")))
        with pytest.raises(OSError, match="always"):
            w.wait()
        assert w.n_retries == 1
        w.close()

    def test_non_retryable_errors_never_retry(self):
        calls = {"n": 0}

        def bug():
            calls["n"] += 1
            raise ValueError("a bug, not a flaky disk")

        w = AsyncWriter(retries=5, backoff_s=0.001)
        w.submit(bug)
        with pytest.raises(ValueError):
            w.wait()
        assert calls["n"] == 1 and w.n_retries == 0
        w.close()

    def test_custom_retryable_predicate(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 2:
                raise KeyError("weird but declared retryable")

        with AsyncWriter(retries=2, backoff_s=0.001,
                         retryable=lambda e: isinstance(e, KeyError)) as w:
            w.submit(flaky)
            w.wait()
        assert calls["n"] == 2

    def test_wait_timeout(self):
        import threading
        release = threading.Event()
        w = AsyncWriter()
        w.submit(release.wait)
        with pytest.raises(TimeoutError, match="still pending"):
            w.wait(timeout=0.05)
        release.set()
        w.wait(timeout=5)                        # drains fine afterwards
        w.close()

    def test_exit_with_body_exception_warns_about_masked_error(self):
        with pytest.raises(RuntimeError, match="body failed"), \
                warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            with AsyncWriter() as w:
                w.submit(lambda: (_ for _ in ()).throw(IOError("w died")))
                w._q.join()                      # let the failure land
                raise RuntimeError("body failed")
        assert any("masked" in str(c.message) for c in caught)
        assert isinstance(w.pending_error, IOError)


# ---------------------------------------------------------------------------
# serve-path graceful degradation: unrepresentable slab ships lossless
# ---------------------------------------------------------------------------

class TestWireFallback:
    def test_cusz_overflow_slab_falls_back_to_lossless(self):
        from repro.core import kvcache as KVC
        rng = np.random.default_rng(0)
        # spiky data + tiny outlier budget: cusz cannot represent it
        x = jnp.asarray((rng.standard_normal((2, 256, 8))
                         * (1 + 100 * (rng.random((2, 256, 8)) > 0.99)))
                        .astype(np.float32))
        parts = KVC.kv_wire_encode(
            x, 1, wire="cusz", source_dtype=jnp.float32,
            wire_cfg={"eb": 1e-4, "outlier_frac": 0.001, "nbins": 16})
        names = {p.header.codec for p in parts}
        assert "lossless" in names, names
        back = KVC.kv_wire_restore(parts, 1, dtype=jnp.float32)
        lossless = [i for i, p in enumerate(parts)
                    if p.header.codec == "lossless"]
        if len(lossless) == len(parts):
            np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
        else:                                    # mixed: lossless slabs exact
            step = x.shape[1] // len(parts)
            i = lossless[0]
            np.testing.assert_array_equal(
                np.asarray(back[:, i * step:(i + 1) * step]),
                np.asarray(x[:, i * step:(i + 1) * step]))

    def test_healthy_slab_still_ships_compressed(self):
        from repro.core import kvcache as KVC
        rng = np.random.default_rng(1)
        x = jnp.asarray(np.cumsum(rng.standard_normal((2, 256, 8)), axis=1)
                        .astype(np.float32) / 50)
        parts = KVC.kv_wire_encode(
            x, 1, wire="cusz", source_dtype=jnp.float32,
            wire_cfg={"eb": 1e-3, "outlier_frac": 1.0})
        assert {p.header.codec for p in parts} == {"cusz"}


# ---------------------------------------------------------------------------
# launch.env: the shared runtime setup every entrypoint and CI job uses
# ---------------------------------------------------------------------------

class TestLaunchEnv:
    def test_env_overrides_is_pure_and_merges(self):
        from repro.launch import env as E
        base = {"XLA_FLAGS": "--xla_dump_to=/tmp/d "
                             "--xla_force_host_platform_device_count=2"}
        ov = E.env_overrides(E.RuntimeConfig(host_device_count=8,
                                             nan_debug=True,
                                             preallocate=False),
                             base_env=base)
        flags = ov["XLA_FLAGS"].split()
        # unmanaged flags survive; the managed one is replaced, not duped
        assert "--xla_dump_to=/tmp/d" in flags
        assert flags.count("--xla_force_host_platform_device_count=8") == 1
        assert "--xla_force_host_platform_device_count=2" not in flags
        assert ov["JAX_DEBUG_NANS"] == "1"
        assert ov["XLA_PYTHON_CLIENT_PREALLOCATE"] == "false"
        assert base["XLA_FLAGS"].startswith("--xla_dump_to")  # untouched

    def test_removed_async_flags_scrubbed_never_emitted(self):
        """XLA aborts the whole process on unknown flags, and the
        per-op --xla_gpu_enable_async_* family was removed upstream:
        setup must scrub stale copies and never emit its own."""
        from repro.launch import env as E
        base = {"XLA_FLAGS": "--xla_gpu_enable_async_all_gather=true"}
        ov = E.env_overrides(E.RuntimeConfig(), base_env=base)
        assert "async_all_gather" not in ov["XLA_FLAGS"]
        assert "--xla_gpu_enable_latency_hiding_scheduler=true" \
            in ov["XLA_FLAGS"].split()

    def test_no_change_yields_empty_override(self):
        from repro.launch import env as E
        cfg = E.RuntimeConfig(async_collectives=False)
        ov = E.env_overrides(cfg, base_env={"XLA_FLAGS": ""})
        assert ov == {}

    def test_from_args_round_trip(self):
        import argparse
        from repro.launch import env as E
        ap = argparse.ArgumentParser()
        E.add_arguments(ap)
        cfg = E.from_args(ap.parse_args(
            ["--host-devices", "8", "--nan-debug",
             "--no-async-collectives"]))
        assert cfg == E.RuntimeConfig(host_device_count=8, nan_debug=True,
                                      async_collectives=False)


# ---------------------------------------------------------------------------
# trainer integration: chaos armed end-to-end (small model, few steps)
# ---------------------------------------------------------------------------

class TestTrainerUnderChaos:
    def test_nan_burst_skipped_and_mitigation_wired(self):
        from repro import configs
        from repro.train.trainer import LoopConfig, Trainer
        from repro.train.train_step import TrainConfig

        cfg = configs.reduced("qwen2.5-3b", n_periods=1)
        policy = fault.MitigationPolicy(4)
        lcfg = LoopConfig(steps=6, batch=2, seq=16, mitigation=policy,
                          log_every=100)
        ccfg = chaos.ChaosConfig(nhosts=4, nan_steps=(3,),
                                 straggler_host=1, straggler_delay_s=0.01)
        with chaos.use_chaos(ccfg):
            hist = Trainer(cfg, TrainConfig(), lcfg).run()
        steps = [h["step"] for h in hist]
        assert 3 not in steps and len(steps) == 5   # NaN step skipped
        assert policy.n_skipped == 1
        # the straggler sim fed the policy real per-host durations
        assert policy.detector.host(1).n_observed > 0
