"""R4 must-flag: jax-only op without a declared reason."""
from .. import dispatch

KERNEL = dispatch.register("rawonly_flag", impls=("jax",))   # FLAG: no reason
