"""R4 must-pass: jax-only op declaring why no pallas impl exists."""
from .. import dispatch

KERNEL = dispatch.register(
    "rawonly_pass", impls=("jax",),
    jax_only_reason="decode is RAW-bound; see the gap-array roadmap item")
