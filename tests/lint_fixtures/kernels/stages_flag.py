"""R4 stage-kernels fixture: one stage resolving to a registered op,
one declaring a kernel nothing registers."""


def register_predictor(name, factory):
    pass


class ResolvesPredictor:
    kernels = ("passop",)                # registered by passop/ops.py

    def predict(self, data, cfg, eb, pp):
        pass

    def reconstruct(self, codes, payload, cfg, eb, shape, pp):
        pass


class DanglingPredictor:
    kernels = ("ghostop.forward",)       # FLAG: no ops.py registers this

    def predict(self, data, cfg, eb, pp):
        pass

    def reconstruct(self, codes, payload, cfg, eb, shape, pp):
        pass


register_predictor("resolves", ResolvesPredictor)
register_predictor("dangling", DanglingPredictor)
