"""R4 must-flag: ships kernel.py but registers no pallas impl."""
from .. import dispatch

KERNEL = dispatch.register("flagop", impls=("jax",))   # FLAG
