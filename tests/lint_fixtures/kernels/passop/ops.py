"""R4 must-pass: kernel.py present and pallas registered."""
from .. import dispatch

KERNEL = dispatch.register("passop", impls=("jax", "pallas"))
