"""R5 must-pass fixture: static-arg branches and metadata branches."""
import jax
import jax.numpy as jnp
from functools import partial


@partial(jax.jit, static_argnames=("impl", "nbins"))
def root(x, impl, nbins):
    if impl == "pallas":                    # static arg: fine
        y = x * 2
    else:
        y = x * 3
    if x.ndim == 2:                         # shape metadata: fine
        y = y.reshape(-1)
    while nbins > 1024:                     # static arg: fine
        nbins //= 2
    return jnp.sum(y) + nbins
