"""R1 must-pass fixture: clean device code plus waived intentional syncs."""
import jax
import jax.numpy as jnp


@jax.jit
def root(x):
    s = x.shape                             # static metadata: not a sync
    return jnp.sum(x) / s[0]


def boundary(x):
    # repro-lint: allow[host-sync] storage boundary, one readback per save
    host = jax.device_get(x)
    stats = jnp.max(x)
    n = int(stats)  # repro-lint: allow[host-sync] one scalar for the header
    return host, n


def untraced(n):
    return float(n) + int(n)                # plain python: no sync
