"""R2 must-pass fixture: every accepted caching shape."""
import functools

import jax

MODULE_LEVEL = jax.jit(lambda x: x + 1)     # module level: fine
_CACHE = {}


@functools.lru_cache(maxsize=None)
def get_step(cfg):
    return jax.jit(lambda x: x * cfg)       # memoized by lru_cache: fine


def dict_cached(key, fn):
    if key not in _CACHE:
        _CACHE[key] = jax.jit(fn)           # module-dict cache: fine
    return _CACHE[key]


class Runner:
    def __init__(self, fn):
        self.step = jax.jit(fn)             # once per object: fine


def waived(fn):
    # repro-lint: allow[jit-cache] one-shot lowering tool, nothing to cache
    return jax.jit(fn).lower(1.0).compile()
