"""R3 must-pass fixture: full surface, explicit opt-out, scalar params."""


def register(name, factory):
    pass


def make_header(name, version, x, **params):
    pass


class FullSurface:
    def encode(self, x, *, cfg=None):
        return make_header("full", 1, x, scale=0.5, bits=8,
                           kv_shape=(2, 3))      # tuples serialize as lists

    def decode(self, c, *, like=None):
        pass

    def shard_axis(self, shape, nshards):
        return 0

    def payload_axes(self, axis):
        return {"data": axis}


class OptedOut:
    shardable = False                            # explicit opt-out

    def encode(self, x, *, cfg=None):
        pass

    def decode(self, c, *, like=None):
        pass

    @staticmethod
    def make(**kw):
        return OptedOut()


register("full", lambda **kw: FullSurface(**kw))
register("opted", OptedOut.make)


def register_predictor(name, factory):
    pass


def register_encoder(name, factory):
    pass


class Predictor:
    """Abstract stage base: its raising stubs must not satisfy R3."""

    def predict(self, data, cfg, eb, pp):
        raise NotImplementedError

    def reconstruct(self, codes, payload, cfg, eb, shape, pp):
        raise NotImplementedError


class GoodPredictor(Predictor):
    kernels = ("some.kernel", "other.kernel")

    def predict(self, data, cfg, eb, pp):
        pass

    def reconstruct(self, codes, payload, cfg, eb, shape, pp):
        pass


class GoodEncoder:
    kernels = ()

    def encode(self, codes, cfg, pp):
        pass

    def decode(self, payload, aux, static_meta, cfg, pp):
        pass


register_predictor("good", GoodPredictor)
register_encoder("goodenc", GoodEncoder)
