"""R3 must-flag fixture: incomplete codec registrations."""


def register(name, factory):
    pass


def make_header(name, version, x, **params):
    pass


class NoDecode:
    def encode(self, x, *, cfg=None):
        return make_header("nodecode", 1, x,
                           table={"a": 1})   # FLAG: dict header param
    # FLAG: no decode


class NoShardSurface:
    def encode(self, x, *, cfg=None):
        pass

    def decode(self, c, *, like=None):
        pass
    # FLAG: no shard_axis/payload_axes and no shardable = False


register("nodecode", lambda **kw: NoDecode(**kw))
register("noshard", lambda **kw: NoShardSurface(**kw))
