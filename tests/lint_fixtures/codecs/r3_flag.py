"""R3 must-flag fixture: incomplete codec registrations."""


def register(name, factory):
    pass


def make_header(name, version, x, **params):
    pass


class NoDecode:
    def encode(self, x, *, cfg=None):
        return make_header("nodecode", 1, x,
                           table={"a": 1})   # FLAG: dict header param
    # FLAG: no decode


class NoShardSurface:
    def encode(self, x, *, cfg=None):
        pass

    def decode(self, c, *, like=None):
        pass
    # FLAG: no shard_axis/payload_axes and no shardable = False


register("nodecode", lambda **kw: NoDecode(**kw))
register("noshard", lambda **kw: NoShardSurface(**kw))


def register_predictor(name, factory):
    pass


def register_encoder(name, factory):
    pass


class NoReconstruct:
    kernels = ("some.kernel",)

    def predict(self, data, cfg, eb, pp):
        pass
    # FLAG: no reconstruct


class NoKernelsEncoder:
    # FLAG: no kernels tuple
    def encode(self, codes, cfg, pp):
        pass

    def decode(self, payload, aux, static_meta, cfg, pp):
        pass


register_predictor("noreconstruct", NoReconstruct)
register_encoder("nokernels", NoKernelsEncoder)
