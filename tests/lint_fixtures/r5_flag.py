"""R5 must-flag fixture: python branch on a traced value."""
import jax
import jax.numpy as jnp
from functools import partial


@partial(jax.jit, static_argnames=("mode",))
def root(x, mode):
    y = jnp.sum(x)
    if y > 0:                              # FLAG: branch on tracer
        return y
    while x:                               # FLAG: loop on tracer param
        break
    return -y
