"""R1 must-flag fixture: syncs in jit-reachable and host-path code."""
import jax
import jax.numpy as jnp
import numpy as np


def helper(x):
    # reachable from the jit root below -> every sync form flags
    v = jax.device_get(x)                  # FLAG: device_get (jit-reachable)
    w = np.asarray(x)                      # FLAG: np.asarray (jit-reachable)
    return v, w


@jax.jit
def root(x):
    y = jnp.sum(x)
    if False:
        return helper(y)
    return y.item()                        # FLAG: .item() (jit-reachable)


def host_path(x):
    a = jax.device_get(x)                  # FLAG: blocking sync (host path)
    x.block_until_ready()                  # FLAG: blocking sync (host path)
    b = jnp.max(jnp.abs(x))
    return a, float(b)                     # FLAG: float() on traced value
