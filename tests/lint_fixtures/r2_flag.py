"""R2 must-flag fixture: per-call jax.jit construction."""
import jax


def hot_loop(fn, xs):
    out = []
    for x in xs:
        step = jax.jit(fn)                 # FLAG: fresh jit every call
        out.append(step(x))
    return out
