"""Crash consistency, async-writer semantics, shard layout, and the
manifest v2 -> v3 format gate of the rewritten checkpoint subsystem."""
import json
import os
import tempfile
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import codecs
from repro.dist import context as dist_ctx
from repro.io import checkpoint as CK
from repro.io.async_writer import AsyncWriter


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(np.cumsum(rng.standard_normal((64, 128)),
                                   axis=-1).astype(np.float32)),
        "bias": jnp.asarray(rng.standard_normal(8).astype(np.float32)),
        "step": jnp.asarray(np.int32(7)),
        "opt": {"m": jnp.asarray(
            rng.standard_normal((64, 128)).astype(np.float32))},
        "bf": jnp.asarray(rng.standard_normal((32, 256)).astype(np.float32)
                          ).astype(jnp.bfloat16),
    }


POLICY = CK.CheckpointPolicy(codec="cusz", eb_valrel=1e-4,
                             rules=(("opt", "int8"),))


def _assert_trees_bitwise_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        x, y = np.asarray(la), np.asarray(lb)
        if x.dtype == jnp.bfloat16:
            x, y = x.view(np.uint16), y.view(np.uint16)
        np.testing.assert_array_equal(x, y)


class TestAsyncWriter:
    def test_runs_tasks_in_order_and_waits(self):
        out = []
        with AsyncWriter(max_pending=2) as w:
            for i in range(5):
                w.submit(out.append, i)
            w.wait()
            assert out == [0, 1, 2, 3, 4]

    def test_exception_reraised_at_wait(self):
        w = AsyncWriter()
        w.submit(lambda: (_ for _ in ()).throw(IOError("disk gone")))
        with pytest.raises(IOError, match="disk gone"):
            w.wait()
        w.wait()                  # error is consumed, writer still usable
        w.close()

    def test_exception_reraised_at_next_submit(self):
        w = AsyncWriter()
        w.submit(lambda: 1 / 0)
        w._q.join()               # let the failure land
        with pytest.raises(ZeroDivisionError):
            w.submit(print, "never runs")
        w.close()

    def test_first_error_wins(self):
        w = AsyncWriter()
        w.submit(lambda: (_ for _ in ()).throw(IOError("first")))
        w.submit(lambda: (_ for _ in ()).throw(ValueError("second")))
        with pytest.raises(IOError, match="first"):
            w.wait()
        w.close()

    def test_bounded_queue_applies_backpressure(self):
        """With max_pending=1, a submit while a task is running and one
        is queued must block until the running task finishes — the
        writer-fell-behind barrier the trainer relies on."""
        release = threading.Event()
        w = AsyncWriter(max_pending=1)
        w.submit(release.wait)            # running (blocks the worker)
        w.submit(lambda: None)            # fills the queue
        t0 = time.perf_counter()
        blocker = threading.Thread(
            target=lambda: w.submit(lambda: None))
        blocker.start()
        blocker.join(timeout=0.15)
        assert blocker.is_alive()         # still blocked on the full queue
        release.set()
        blocker.join(timeout=5)
        assert not blocker.is_alive()
        assert time.perf_counter() - t0 >= 0.15
        w.wait()
        w.close()

    def test_closed_writer_rejects_submits(self):
        w = AsyncWriter()
        w.close()
        with pytest.raises(RuntimeError, match="closed"):
            w.submit(lambda: None)


class TestCrashConsistency:
    def _failing_shard_writer(self, monkeypatch, fail_after: int):
        real = CK._write_shard
        calls = {"n": 0}

        def failing(path, arrays):
            calls["n"] += 1
            if calls["n"] > fail_after:
                raise IOError("injected: writer died mid-save")
            real(path, arrays)

        monkeypatch.setattr(CK, "_write_shard", failing)
        return calls

    def test_interrupted_save_never_shadows_previous_step(self, monkeypatch):
        """Kill the writer after shard 0 of a multi-shard save: the
        previous complete step must stay the restorable latest."""
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 0, tree, policy=POLICY, nshards=3)
            self._failing_shard_writer(monkeypatch, fail_after=1)
            with pytest.raises(IOError, match="injected"):
                CK.save_checkpoint(d, 1, _tree(seed=1), policy=POLICY,
                                   nshards=3)
            assert CK.latest_step(d) == 0          # tmp dir is invisible
            restored, step = CK.load_checkpoint(d, tree)
            assert step == 0
            np.testing.assert_array_equal(np.asarray(restored["step"]),
                                          np.asarray(tree["step"]))

    def test_async_failure_reraises_at_wait_and_prior_step_survives(
            self, monkeypatch):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 0, tree, policy=POLICY, nshards=2)
            self._failing_shard_writer(monkeypatch, fail_after=0)
            w = AsyncWriter()
            assert CK.save_checkpoint(d, 1, _tree(seed=1), policy=POLICY,
                                      nshards=2, writer=w) is w
            with pytest.raises(IOError, match="injected"):
                w.wait()
            assert CK.latest_step(d) == 0
            _, step = CK.load_checkpoint(d, tree)
            assert step == 0
            w.close()

    def test_legacy_background_failures_surface(self, monkeypatch):
        """The old fire-and-forget thread swallowed write exceptions and
        lost the checkpoint; background=True must now re-raise them at
        the module barrier."""
        self._failing_shard_writer(monkeypatch, fail_after=0)
        monkeypatch.setattr(CK, "_default_writer", None)  # fresh writer
        with tempfile.TemporaryDirectory() as d:
            ret = CK.save_checkpoint(d, 0, _tree(), background=True)
            assert isinstance(ret, AsyncWriter)
            with pytest.raises(IOError, match="injected"):
                CK.wait_for_writes()

    def test_crashed_tmp_dir_is_cleaned_on_retry(self, monkeypatch):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            self._failing_shard_writer(monkeypatch, fail_after=1)
            with pytest.raises(IOError):
                CK.save_checkpoint(d, 5, tree, policy=POLICY, nshards=3)
            assert os.path.isdir(os.path.join(d, ".tmp_step_00000005"))
            monkeypatch.undo()
            final = CK.save_checkpoint(d, 5, tree, policy=POLICY, nshards=3)
            assert CK.latest_step(d) == 5
            assert not os.path.isdir(os.path.join(d, ".tmp_step_00000005"))
            restored, _ = CK.load_checkpoint(d, tree)
            _assert_trees_bitwise_equal(
                restored, CK.load_checkpoint(os.path.dirname(final), tree)[0])


class TestShardedLayout:
    def test_sharded_save_matches_single_file_bit_for_bit(self):
        """Per codec policy: an nshards=4 save restores bit-identically
        to the nshards=1 single-file save of the same state."""
        tree = _tree()
        policies = (CK.CheckpointPolicy(codec="lossless"),
                    CK.CheckpointPolicy(codec="int8"),
                    POLICY)
        for pol in policies:
            with tempfile.TemporaryDirectory() as d1, \
                    tempfile.TemporaryDirectory() as d4:
                CK.save_checkpoint(d1, 0, tree, policy=pol, nshards=1)
                with AsyncWriter(max_pending=1) as w:
                    CK.save_checkpoint(d4, 0, tree, policy=pol, nshards=4,
                                       writer=w)
                    w.wait()
                a, _ = CK.load_checkpoint(d1, tree)
                b, _ = CK.load_checkpoint(d4, tree)
                _assert_trees_bitwise_equal(a, b)

    def test_manifest_v3_layout(self):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            final = CK.save_checkpoint(d, 0, tree, policy=POLICY, nshards=4)
            man = json.load(open(os.path.join(final, "manifest.json")))
            assert man["format"] == CK.MANIFEST_FORMAT
            assert man["nshards"] == 4
            for h in range(4):
                assert os.path.exists(
                    os.path.join(final, CK._SHARD_FMT.format(h)))
            # split-stable codecs split across all shards, cusz leaves
            # stay whole on one owner shard
            w = man["tensors"]["w"]
            assert w["codec"] == "cusz" and w["axis"] is None
            assert len(w["shards"]) == 1
            m = man["tensors"]["opt::m"]
            assert m["codec"] == "int8" and m["axis"] is not None
            assert [s["shard"] for s in m["shards"]] == [0, 1, 2, 3]
            # every shard header is self-describing
            for e in man["tensors"].values():
                for sh in e["shards"]:
                    assert sh["header"]["codec"] == e["codec"]

    def test_pinned_scale_makes_int8_split_stable(self):
        """The int8 per-tensor scale must be derived globally, not per
        slice — otherwise sharded and single-file saves diverge."""
        x = jnp.asarray(np.linspace(-3, 11, 64 * 32, dtype=np.float32
                                    ).reshape(64, 32))
        codec = codecs.get("int8")
        whole = codec.decode(codec.encode(x))
        axis = codec.shard_axis(x.shape, 4)
        parts = codec.encode_parts(x, axis, 4)
        merged = codecs.concat_containers(parts, axis,
                                          codec.payload_axes(axis))
        np.testing.assert_array_equal(np.asarray(whole),
                                      np.asarray(codec.decode(merged)))

    def test_elastic_restore_with_shardings_is_bitwise(self):
        tree = _tree()
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        shardings = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), tree)
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 0, tree, policy=POLICY, nshards=4)
            host, _ = CK.load_checkpoint(d, tree)
            wired, _ = CK.load_checkpoint(d, tree, shardings=shardings)
            _assert_trees_bitwise_equal(host, wired)
            stats = CK.LAST_RESTORE_STATS
            assert stats["saved_nshards"] == 4
            assert stats["wire_leaves"] > 0       # containers moved, not f32
            assert 0 < stats["wire_bytes"] < stats["raw_bytes"]

    def test_restore_wire_codec_leg(self):
        """Arming use_restore_compress moves raw leaves over the
        int8-block wire codec: lossy within scale/2, much smaller."""
        rng = np.random.default_rng(3)
        tree = {"w": jnp.asarray(rng.standard_normal((128, 256))
                                 .astype(np.float32))}
        with tempfile.TemporaryDirectory() as d:
            CK.save_checkpoint(d, 0, tree)        # lossless policy
            plain, _ = CK.load_checkpoint(d, tree)
            plain_bytes = CK.LAST_RESTORE_STATS["wire_bytes"]
            with dist_ctx.use_restore_compress("int8-block"):
                coded, _ = CK.load_checkpoint(d, tree)
            stats = CK.LAST_RESTORE_STATS
            assert stats["recoded_leaves"] == 1
            assert stats["wire_bytes"] < stats["raw_bytes"] / 3
            a = np.asarray(plain["w"])
            b = np.asarray(coded["w"])
            bound = np.abs(a).max() / 127.0 * 0.51
            assert np.abs(a - b).max() <= bound
            assert not np.array_equal(a, b)       # genuinely recoded
            assert plain_bytes == 0               # and off by default

    def test_invalid_restore_codec_rejected(self):
        with pytest.raises(ValueError, match="unknown compression codec"):
            with dist_ctx.use_restore_compress("no-such-codec"):
                pass
        # non-blockwise registry ids fail at arm time, not mid-restore
        with pytest.raises(ValueError, match="blockwise"):
            with dist_ctx.use_restore_compress("cusz"):
                pass


class TestManifestFormatGate:
    def _v2_checkpoint(self, d, key, value):
        sd = os.path.join(d, "step_00000003")
        os.makedirs(sd)
        codec = codecs.get("lossless")
        c = codec.pack(codec.encode(value))
        header, fields = codecs.to_arrays(c)
        arrays = {f"{key}::__c__::{f}": v for f, v in fields.items()}
        man = {"step": 3, "format": 2, "policy": "lossless",
               "tensors": {key: {"codec": "lossless", "version": 1,
                                 "header": header}}}
        np.savez(os.path.join(sd, "arrays.npz"), **arrays)
        with open(os.path.join(sd, "manifest.json"), "w") as f:
            json.dump(man, f)

    def test_v2_still_loads_behind_gate(self):
        v = np.arange(12, dtype=np.float32).reshape(3, 4)
        with tempfile.TemporaryDirectory() as d:
            self._v2_checkpoint(d, "x", v)
            out, step = CK.load_checkpoint(
                d, {"x": jnp.zeros((3, 4), jnp.float32)})
            assert step == 3
            np.testing.assert_array_equal(np.asarray(out["x"]), v)
            assert CK.LAST_RESTORE_STATS["format"] == 2

    def test_v1_rejected_with_actionable_error(self):
        with tempfile.TemporaryDirectory() as d:
            sd = os.path.join(d, "step_00000000")
            os.makedirs(sd)
            with open(os.path.join(sd, "manifest.json"), "w") as f:
                json.dump({"step": 0, "format": 1, "tensors": {}}, f)
            with pytest.raises(ValueError, match="predates"):
                CK.load_checkpoint(d, {})

    def test_future_format_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            sd = os.path.join(d, "step_00000000")
            os.makedirs(sd)
            with open(os.path.join(sd, "manifest.json"), "w") as f:
                json.dump({"step": 0, "format": 4, "tensors": {}}, f)
            with pytest.raises(ValueError, match="supports formats 2"):
                CK.load_checkpoint(d, {})

    def test_latest_step_ignores_tmp_dirs(self):
        with tempfile.TemporaryDirectory() as d:
            os.makedirs(os.path.join(d, ".tmp_step_00000009"))
            assert CK.latest_step(d) is None
            CK.save_checkpoint(d, 4, {"x": jnp.zeros(3)})
            assert CK.latest_step(d) == 4
