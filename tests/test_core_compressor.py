"""End-to-end compressor tests: the paper's defining guarantee + quality."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compressor as C, metrics as M, zfp_like as Z
from repro.data import scidata


FIELDS = scidata.all_fields(small=True)


class TestErrorBound:
    @pytest.mark.parametrize("name", list(FIELDS))
    def test_valrel_1em4_bound_held(self, name):
        """|d − d•| ≤ eb on every synthetic SDRBench-like field at the
        paper's headline setting valrel=1e-4 (Table 8)."""
        f = jnp.asarray(FIELDS[name])
        cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
        recon, blob, eb, ratio = C.roundtrip(f, cfg)
        assert int(blob.n_outliers) <= blob.out_idx.shape[0], "outlier overflow"
        assert M.verify_error_bound(f, recon, eb), name
        assert float(M.psnr(f, recon)) > 80.0       # paper Table 8: ~85 dB

    @given(st.integers(0, 2**31 - 1),
           st.sampled_from([1e-2, 1e-3, 1e-4]),
           st.sampled_from([(1000,), (37, 53), (11, 13, 17)]))
    @settings(max_examples=20, deadline=None)
    def test_property_bound_random_fields(self, seed, valrel, shape):
        rng = np.random.default_rng(seed)
        kind = seed % 3
        if kind == 0:
            f = rng.standard_normal(shape).astype(np.float32)
        elif kind == 1:
            f = np.cumsum(rng.standard_normal(shape), axis=-1).astype(np.float32)
        else:
            f = np.zeros(shape, np.float32)            # constant field
        cfg = C.CompressorConfig(eb=valrel, eb_mode="valrel",
                                 outlier_frac=1.0)     # never overflow
        recon, blob, eb, _ = C.roundtrip(jnp.asarray(f), cfg)
        assert M.verify_error_bound(f, recon, eb)

    def test_decompressed_prequant_identical(self):
        """d° reconstruction is exact integer arithmetic: re-compressing the
        reconstruction at the same eb is idempotent (paper §3.1.2)."""
        f = jnp.asarray(FIELDS["cesm"])
        cfg = C.CompressorConfig(eb=1e-3, eb_mode="abs")
        r1, _, eb, _ = C.roundtrip(f, cfg)
        r2, _, _, _ = C.roundtrip(r1, cfg)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=2.1e-3)


class TestQuality:
    def test_ratio_beats_zfp_like_at_equal_psnr(self):
        """Paper Table 5 headline: cuSZ reaches ~PSNR 85 dB at a much lower
        bitrate than the fixed-rate baseline."""
        f = jnp.asarray(FIELDS["hurricane"])
        cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
        recon, blob, eb, ratio = C.roundtrip(f, cfg)
        sz_psnr = float(M.psnr(f, recon))
        sz_rate = M.bitrate(f.size, C.compressed_bytes(blob, cfg.nbins))
        # find the baseline rate that reaches the same PSNR
        zr = None
        for rate in [4, 6, 8, 10, 12, 14, 16, 20]:
            rec, br = Z.compress_decompress(f, rate)
            if float(M.psnr(f, rec)) >= sz_psnr:
                zr = br
                break
        assert zr is not None
        assert sz_rate < zr, (sz_rate, zr)

    def test_zero_concentrated_field_high_ratio(self):
        """Table 9 fields (≈89% of points within eb of 0) compress hard."""
        f = jnp.asarray(FIELDS["hurricane_cloud"])
        cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
        recon, blob, eb, ratio = C.roundtrip(f, cfg)
        assert ratio > 10.0
        assert M.verify_error_bound(f, recon, eb)

    def test_tpu_blocks_do_not_break_bound(self):
        f = jnp.asarray(FIELDS["nyx"])
        cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel", use_tpu_blocks=True)
        recon, blob, eb, ratio = C.roundtrip(f, cfg)
        assert M.verify_error_bound(f, recon, eb)


class TestAccounting:
    def test_compressed_bytes_components(self):
        f = jnp.asarray(FIELDS["cesm"])
        cfg = C.CompressorConfig(eb=1e-3, eb_mode="abs", nbins=256)
        blob, eb = C.compress(f, cfg)
        total = C.compressed_bytes(blob, cfg.nbins)
        bits = np.asarray(blob.bits_used, dtype=np.int64)
        stream = int(np.sum((bits + 31) // 32) * 4)
        gaps = blob.gap_bits.size * 4 + blob.gap_syms.size * 2
        assert total == stream + int(blob.n_outliers) * 8 + 256 + gaps \
            + C.HEADER_BYTES

    def test_nbins_sweep_bound_held(self):
        f = jnp.asarray(FIELDS["hacc"])[:65536]
        for nbins in [128, 256, 512, 1024, 4096]:
            cfg = C.CompressorConfig(eb=1e-3, eb_mode="valrel", nbins=nbins)
            recon, blob, eb, _ = C.roundtrip(f, cfg)
            assert M.verify_error_bound(f, recon, eb), nbins
