"""Staged-pipeline regression suite (core.stages + core.compressor).

Four pillars:

  * golden bit-identity — re-encoding the committed cusz v2 fixture
    through the staged pipeline reproduces the stored container header
    and every payload array bit-for-bit (the refactor is format-neutral),
    and the stored fixture still decodes within its bound;
  * registry contract — stage ids resolve to singletons, unknown ids
    fail loudly, predictor/encoder payload key sets stay disjoint;
  * kernel parity — interp and bitshuffle jax references and Pallas
    (interpret) kernels agree bit-exactly, and both stage pipelines are
    impl-invariant end to end;
  * 8-fake-device elasticity — checkpoint save/restore over the two new
    codec ids ("cusz-i", "fz") across a mesh reshape, bitwise-stable
    between shardings (subprocess so the device-count flag stays local).
"""
from __future__ import annotations

import itertools
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.codecs.container import Container, Header
from repro.core import compressor as CZ
from repro.core import stages
from repro.kernels.bitshuffle import ops as bitshuffle_ops
from repro.kernels.interp import ops as interp_ops

HERE = os.path.dirname(os.path.abspath(__file__))
DATA = os.path.join(HERE, "data")

# the exact config the committed fixture was produced with
GOLDEN_CFG = CZ.CompressorConfig(eb=1e-3, eb_mode="abs", chunk_size=256,
                                 sub_size=64, outlier_frac=1.0)


def _golden():
    z = np.load(os.path.join(DATA, "cusz_v2_golden.npz"))
    hdr = json.load(open(os.path.join(DATA, "cusz_v2_golden_header.json")))
    return z, hdr


def _smooth(shape, seed=0):
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal(shape, dtype=np.float64),
                  axis=-1).astype(np.float32)
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# Golden-fixture bit-identity
# ---------------------------------------------------------------------------

class TestGoldenFixture:
    def test_reencode_is_bit_identical(self):
        """The staged lorenzo+huffman pipeline must reproduce the
        pre-refactor container byte-for-byte: same header JSON (checksum
        included), same packed payload arrays, same dtypes."""
        z, hdr = _golden()
        codec = codecs.get("cusz", cfg=GOLDEN_CFG)
        c = codec.pack(codec.encode(jnp.asarray(z["field"])))
        assert c.header.to_json() == hdr
        payload_keys = sorted(k for k in z.files if k != "field")
        assert sorted(c.payload) == payload_keys
        for k in payload_keys:
            got = np.asarray(c.payload[k])
            np.testing.assert_array_equal(got, z[k], err_msg=k)
            assert got.dtype == z[k].dtype, (k, got.dtype, z[k].dtype)

    def test_stored_fixture_decodes_within_bound(self):
        """Backward decode: the container as committed (not re-encoded)
        must decode via the registry within its recorded abs bound."""
        z, hdr = _golden()
        cont = Container(Header.from_json(hdr),
                         {k: z[k] for k in z.files if k != "field"})
        rec = np.asarray(codecs.decode(cont))
        eb = float(hdr["params"]["eb"])
        assert np.abs(rec - z["field"]).max() <= eb * 1.0001


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------

class TestStageRegistry:
    def test_registered_ids(self):
        assert {"lorenzo", "interp"} <= set(stages.predictor_names())
        assert {"huffman", "bitshuffle"} <= set(stages.encoder_names())

    def test_lookup_returns_singletons(self):
        for name in stages.predictor_names():
            p = stages.get_predictor(name)
            assert p is stages.get_predictor(name)   # jit-static identity
            assert p.name == name
        for name in stages.encoder_names():
            e = stages.get_encoder(name)
            assert e is stages.get_encoder(name)
            assert e.name == name

    def test_unknown_ids_fail_loudly(self):
        with pytest.raises(KeyError, match="unknown predictor"):
            stages.get_predictor("nope")
        with pytest.raises(KeyError, match="unknown encoder"):
            stages.get_encoder("nope")

    def test_payload_keys_disjoint_across_all_compositions(self):
        """The composed payload is a dict union, so every predictor's
        key set must be disjoint from every encoder's."""
        for pn, en in itertools.product(stages.predictor_names(),
                                        stages.encoder_names()):
            pk = set(stages.get_predictor(pn).payload_keys)
            ek = set(stages.get_encoder(en).payload_keys)
            assert not (pk & ek), (pn, en, pk & ek)


# ---------------------------------------------------------------------------
# Every predictor x encoder composition round-trips within bound
# ---------------------------------------------------------------------------

COMBOS = tuple(itertools.product(("lorenzo", "interp"),
                                 ("huffman", "bitshuffle")))


@pytest.mark.parametrize("predictor,encoder", COMBOS)
def test_composition_roundtrip_within_bound(predictor, encoder):
    cfg = CZ.CompressorConfig(eb=1e-3, eb_mode="abs", chunk_size=256,
                              sub_size=64, outlier_frac=1.0,
                              predictor=predictor, encoder=encoder)
    x = _smooth((24, 48), seed=3)
    pipe = CZ.StagedPipeline.from_cfg(cfg)
    payload, eb = pipe.compress(x, cfg)
    assert pipe.valid(payload)
    y = np.asarray(pipe.decompress(payload, cfg, eb, x.shape))
    assert np.abs(np.asarray(x) - y).max() <= eb * 1.0001
    # the storage boundary is an inverse: decode of unpack(pack) is
    # bit-identical to decode of the device payload
    restored = pipe.unpack(pipe.pack(payload), cfg, x.shape)
    y2 = np.asarray(pipe.decompress(restored, cfg, eb, x.shape))
    np.testing.assert_array_equal(y, y2)
    assert pipe.stored_nbytes(pipe.pack(payload)) > 0


@pytest.mark.parametrize("predictor,encoder",
                         (("interp", "huffman"), ("lorenzo", "bitshuffle")))
def test_composition_is_kernel_impl_invariant(predictor, encoder):
    """jax vs pallas-interpret produce bit-identical packed payloads."""
    x = _smooth((16, 48), seed=7)
    packs = []
    for impl in ("jax", "pallas-interpret"):
        cfg = CZ.CompressorConfig(eb=1e-3, eb_mode="abs", chunk_size=256,
                                  sub_size=64, outlier_frac=1.0,
                                  predictor=predictor, encoder=encoder,
                                  kernel_impl=impl)
        pipe = CZ.StagedPipeline.from_cfg(cfg)
        payload, _ = pipe.compress(x, cfg)
        packs.append(pipe.pack(payload))
    a, b = packs
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# Kernel-level parity (jax reference vs Pallas interpret)
# ---------------------------------------------------------------------------

class TestKernelParity:
    def test_interp_rows_parity_and_exact_inverse(self):
        rng = np.random.default_rng(11)
        pe = jnp.asarray(rng.integers(-(2 ** 20), 2 ** 20, (5, 19)), jnp.int32)
        odd = jnp.asarray(rng.integers(-(2 ** 20), 2 ** 20, (5, 16)),
                          jnp.int32)
        r_jax = interp_ops.residual_rows(pe, odd, impl="jax")
        r_pl = interp_ops.residual_rows(pe, odd, impl="pallas",
                                        interpret=True)
        np.testing.assert_array_equal(np.asarray(r_jax), np.asarray(r_pl))
        for impl, interp in (("jax", None), ("pallas", True)):
            back = interp_ops.odd_rows(pe, r_jax, impl=impl,
                                       interpret=interp)
            np.testing.assert_array_equal(np.asarray(back), np.asarray(odd))

    def test_bitshuffle_planes_parity_and_exact_inverse(self):
        nbins, chunk = 1024, 256
        rng = np.random.default_rng(13)
        codes2 = jnp.asarray(rng.integers(0, nbins, (3, chunk)), jnp.int32)
        p_jax = bitshuffle_ops.encode_planes(codes2, nbins, impl="jax")
        p_pl = bitshuffle_ops.encode_planes(codes2, nbins, impl="pallas",
                                            interpret=True)
        np.testing.assert_array_equal(np.asarray(p_jax), np.asarray(p_pl))
        for impl, interp in (("jax", None), ("pallas", True)):
            back = bitshuffle_ops.decode_planes(p_jax, nbins, impl=impl,
                                                interpret=interp)
            np.testing.assert_array_equal(np.asarray(back)[:, :chunk],
                                          np.asarray(codes2))


# ---------------------------------------------------------------------------
# 8-fake-device checkpoint elasticity over the new codec ids
# ---------------------------------------------------------------------------

STAGED_CKPT_SCRIPT = r"""
import json, os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.dist import sharding as SH
from repro.dist.context import use_mesh
from repro.io import checkpoint as CK
from repro.models import model as M

cfg = configs.reduced("qwen2.5-3b", n_periods=1)
params = M.init_params(jax.random.PRNGKey(0), cfg)
# smooth the leaves so the lossy policies genuinely code instead of
# falling back to lossless on random init
params = jax.tree_util.tree_map(
    lambda x: jnp.cumsum(x, axis=-1) / 8
    if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

# save from a (4, 2) mesh; restore onto a differently-shaped (2, 4) mesh
mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
params = jax.device_put(params, SH.param_shardings(params, mesh_a,
                                                   fsdp=True))
shard_b = SH.param_shardings(params, mesh_b, fsdp=True)

def bits(x):
    x = np.asarray(x)
    return x.view(np.uint16) if x.dtype == jnp.bfloat16 else x

for name in ("cusz-i", "fz"):
    # 1e-3: tight enough to code, loose enough that the interpolation
    # predictor's residuals stay in-bin on the small smoothed leaves
    pol = CK.CheckpointPolicy(codec=name, eb_valrel=1e-3)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        CK.save_checkpoint(d1, 0, params, policy=pol, nshards=1)
        CK.save_checkpoint(d2, 0, params, policy=pol, nshards=2)
        with use_mesh(mesh_b):
            a, _ = CK.load_checkpoint(d1, params, shardings=shard_b)
            b, _ = CK.load_checkpoint(d2, params, shardings=shard_b)
        stats = dict(CK.LAST_RESTORE_STATS)
        assert stats["saved_nshards"] == 2
        assert stats["wire_leaves"] > 0, stats
        assert stats["wire_bytes"] < stats["raw_bytes"], stats
        man = json.load(open(os.path.join(d2, "step_00000000",
                                          "manifest.json")))
        coded = [e["codec"] for e in man["tensors"].values()]
        assert name in coded, (name, sorted(set(coded)))
        for (pa, la), (pb, lb) in zip(
                jax.tree_util.tree_flatten_with_path(a)[0],
                jax.tree_util.tree_flatten_with_path(b)[0]):
            np.testing.assert_array_equal(bits(la), bits(lb),
                                          err_msg=str(pa))
        # restored leaves actually live on the new mesh's placement
        leaf = jax.tree_util.tree_leaves(b)[0]
        assert leaf.sharding.mesh.shape == mesh_b.shape
    print("policy", name, "elastic bitwise OK")
print("STAGED_CKPT_OK")
"""


def _run_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(HERE))


def test_eight_device_checkpoint_roundtrip_over_staged_codecs():
    r = _run_subprocess(STAGED_CKPT_SCRIPT)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "STAGED_CKPT_OK" in r.stdout
