"""Paper Table 3: codebook construction time vs #quantization bins.

Measures the device two-queue tree build + canonization for 128..8192
bins on a Hurricane-like field's quant codes (time complexity check:
O(k log k)-ish growth, §3.2.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C, dualquant as dq, huffman as hf
from repro.data import scidata
from .common import emit, timeit


def main() -> None:
    f = jnp.asarray(scidata.hurricane_like((25, 125, 125)))
    eb = 1e-4 * float(jnp.max(f) - jnp.min(f))
    delta = dq.blocked_delta(f, eb, (8, 8, 8))
    for nbins in (128, 256, 512, 1024, 2048, 4096, 8192):
        codes, _ = dq.postquant_codes(delta, nbins)
        hist = hf.histogram(codes, nbins)

        def build(h):
            lengths = hf.codeword_lengths(h)
            return hf.canonical_codebook(lengths).codes

        t = timeit(jax.jit(build), hist)
        emit(f"codebook_bins{nbins}", t, f"bins={nbins}")


if __name__ == "__main__":
    main()
