"""Fault-recovery wall time: what each resilience mechanism costs.

Per injected fault class, measures the recovery path end-to-end against
its fault-free baseline:

  * ``straggler_mitigation`` — modeled 8-host cluster with a 5x slow
    host: steps until `MitigationPolicy` brings the step time within
    1.25x of fault-free, plus the converged ratio;
  * ``writer_retry``        — a transient (OSError-class) shard-write
    failure absorbed by the AsyncWriter retry loop: committed-save wall
    time vs the clean save;
  * ``corrupt_fallback``    — restore with the newest step's shard
    corrupted: quarantine + fall back to the previous step vs a clean
    restore;
  * ``nan_skip``            — the skip-and-log guard's per-step cost.

Writes ``BENCH_fault.json`` records
``{fault, seconds, baseline_s, derived}`` (seconds = recovery-path wall
time).
"""
from __future__ import annotations

import glob
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.dist import chaos, fault
from repro.io import checkpoint as CK
from repro.io.async_writer import AsyncWriter
from .common import emit, write_json

JSON_NAME = "BENCH_fault.json"


def _tree(small: bool, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = 64 if small else 512
    return {"w": jnp.asarray(np.cumsum(rng.standard_normal((n, 1024)),
                                       axis=-1).astype(np.float32)),
            "step": jnp.asarray(np.int32(seed))}


def _straggler(records, small: bool) -> None:
    compute = 0.01
    monkey = chaos.ChaosMonkey(chaos.ChaosConfig(
        nhosts=8, straggler_host=3, straggler_delay_s=4 * compute))
    policy = fault.MitigationPolicy(8)
    steps = 8 if small else 30
    ratios, recovered_at = [], None
    for s in range(steps):
        durs = monkey.host_step_times(s, compute, policy.shares)
        policy.observe(s, durs)
        ratios.append(float(durs.max()) / compute)
        if recovered_at is None and ratios[-1] <= 1.25:
            recovered_at = s
    sec = ratios[-1] * compute
    derived = (f"steps_to_recover={recovered_at} "
               f"ratio {ratios[0]:.2f}->{ratios[-1]:.3f}")
    emit("fault_straggler_mitigation", sec, derived)
    records.append({"fault": "straggler_mitigation", "seconds": sec,
                    "baseline_s": compute, "derived": derived})


def _writer_retry(records, small: bool) -> None:
    tree = _tree(small)

    def committed_save(cfg):
        with tempfile.TemporaryDirectory() as d, chaos.use_chaos(cfg):
            t0 = time.perf_counter()
            with AsyncWriter(max_pending=1, retries=2,
                             backoff_s=0.005) as w:
                CK.save_checkpoint(d, 0, tree, writer=w)
                w.wait()
            dt = time.perf_counter() - t0
            assert CK.latest_step(d) == 0
            return dt, w.n_retries

    base, _ = committed_save(None)
    sec, n_retries = committed_save(chaos.ChaosConfig(writer_failures=1))
    derived = f"n_retries={n_retries} overhead={sec - base:+.4f}s"
    emit("fault_writer_retry", sec, derived)
    records.append({"fault": "writer_retry", "seconds": sec,
                    "baseline_s": base, "derived": derived})


def _corrupt_fallback(records, small: bool) -> None:
    with tempfile.TemporaryDirectory() as d:
        for s in (0, 1):
            CK.save_checkpoint(d, s, _tree(small, seed=s), nshards=2)
        t0 = time.perf_counter()
        _, step = CK.load_checkpoint(d, _tree(small))
        base = time.perf_counter() - t0
        assert step == 1
        chaos.corrupt_file(sorted(glob.glob(
            os.path.join(d, "step_00000001", "shard_*.npz")))[0])
        t0 = time.perf_counter()
        _, step = CK.load_checkpoint(d, _tree(small))
        sec = time.perf_counter() - t0
        assert step == 0
        nq = len(CK.LAST_RESTORE_STATS["quarantine"])
        derived = f"quarantined={nq} fell_back_to=step0"
        emit("fault_corrupt_fallback", sec, derived)
        records.append({"fault": "corrupt_fallback", "seconds": sec,
                        "baseline_s": base, "derived": derived})


def _nan_skip(records, small: bool) -> None:
    policy = fault.MitigationPolicy(8)
    iters = 200 if small else 2000
    t0 = time.perf_counter()
    for s in range(iters):
        policy.on_bad_loss(s, float("nan") if s % 10 == 0 else 1.0)
    sec = (time.perf_counter() - t0) / iters
    derived = f"skipped={policy.n_skipped}/{iters}"
    emit("fault_nan_skip", sec, derived)
    records.append({"fault": "nan_skip", "seconds": sec,
                    "baseline_s": 0.0, "derived": derived})


def main(small: bool = False, json_dir: str = ".") -> None:
    records: list = []
    _straggler(records, small)
    _writer_retry(records, small)
    _corrupt_fallback(records, small)
    _nan_skip(records, small)
    write_json(os.path.join(json_dir, JSON_NAME), records)
