"""Paper Tables 5 + 8 with a codec axis: compression ratio / bitrate /
PSNR per SDRBench-like field for every registered lossy codec
(cusz / int8 / zfp via `repro.codecs.get`), plus the paper's matched-PSNR
cuSZ-vs-cuZFP bitrate comparison.

Writes ``BENCH_quality.json`` records
``{field, codec, ratio, bitrate, psnr_db, bound_held}`` (bound_held is
null for codecs without an a-priori bound claim).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro import codecs
from repro.core import metrics as M
from repro.data import scidata
from .common import emit, write_json

# the codec axis: registry name -> configured instance.  cusz and
# cusz-i run at the SAME bound so their ratio rows are the paper's
# Lorenzo-vs-interpolation predictor comparison; fz runs at its wire
# operating point (outlier_frac=1.0: the bound always holds).
CODECS = (
    ("cusz", lambda: codecs.get("cusz", eb=1e-4, eb_mode="valrel")),
    # full outlier capacity: packed storage only pays for actual
    # outliers, and rough fields (qmcpack) overflow the default capacity
    # under interpolation
    ("cusz-i", lambda: codecs.get("cusz-i", eb=1e-4, eb_mode="valrel",
                                  outlier_frac=1.0)),
    ("fz", lambda: codecs.get("fz", eb=1e-4, eb_mode="valrel")),
    ("int8", lambda: codecs.get("int8")),
    ("zfp", lambda: codecs.get("zfp", rate_bits=12)),
)


def _fields(small: bool):
    if small:                         # CI smoke path: tiny fields
        return {
            "cesm": scidata.cesm_like((90, 180)),
            "hurricane": scidata.hurricane_like((10, 50, 50)),
            "nyx": scidata.nyx_like((32, 32, 32)),
        }
    return scidata.all_fields(small=True)   # the paper-table suite


def main(small: bool = False, json_dir: str = ".") -> None:
    fields = _fields(small)
    records = []
    for name, arr in fields.items():
        f = jnp.asarray(arr)
        results = {}
        for cname, make in CODECS:
            codec = make()
            c = codec.encode(f)
            recon = codecs.decode(c)
            nbytes = codec.stored_nbytes(c)
            ratio = f.nbytes / nbytes
            rate = M.bitrate(f.size, nbytes)
            psnr = float(M.psnr(f, recon))
            eb = c.header.param("eb")
            if eb is None and cname.startswith("int"):
                # int codecs: eb = scale/2, data-dependent (payload)
                eb = float(jnp.max(c.payload["scale"])) / 2.0
            bound = (bool(M.verify_error_bound(f, recon, float(eb)))
                     if eb is not None else None)
            results[cname] = dict(ratio=ratio, rate=rate, psnr=psnr)
            records.append({"field": name, "codec": cname,
                            "ratio": round(float(ratio), 3),
                            "bitrate": round(float(rate), 3),
                            "psnr_db": round(psnr, 2),
                            "bound_held": bound})
            emit(f"quality_{name}_{cname}", 0.0,
                 f"CR={ratio:.2f};bitrate={rate:.2f};PSNR={psnr:.1f}dB;"
                 f"bound_held={bound}")
        # paper comparison: fixed-rate baseline bitrate at >= cusz PSNR
        zr = None
        for r in (2, 4, 6, 8, 10, 12, 14, 16, 20, 24):
            zc = codecs.get("zfp", rate_bits=r)
            cont = zc.encode(f)
            if float(M.psnr(f, codecs.decode(cont))) >= results["cusz"]["psnr"]:
                zr = zc.achieved_bitrate(cont)
                break
        gain = (zr / results["cusz"]["rate"]) if zr else float("nan")
        emit(f"quality_{name}", 0.0,
             f"baseline_bitrate={zr};bitrate_gain={gain:.2f}x")
    write_json(os.path.join(json_dir, "BENCH_quality.json"), records)


if __name__ == "__main__":
    main()
