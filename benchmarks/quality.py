"""Paper Tables 5 + 8: compression ratio / bitrate / PSNR at valrel=1e-4
on the five SDRBench-like fields, vs the cuZFP-like fixed-rate baseline
at matched PSNR."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import compressor as C, metrics as M, zfp_like as Z
from repro.data import scidata
from .common import emit


def main() -> None:
    fields = scidata.all_fields(small=True)
    for name, arr in fields.items():
        f = jnp.asarray(arr)
        cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
        recon, blob, eb, ratio = C.roundtrip(f, cfg)
        psnr = float(M.psnr(f, recon))
        rate = M.bitrate(f.size, C.compressed_bytes(blob, cfg.nbins))
        bound = M.verify_error_bound(f, recon, eb)
        zr = None
        for r in (2, 4, 6, 8, 10, 12, 14, 16, 20, 24):
            rec, br = Z.compress_decompress(f, r)
            if float(M.psnr(f, rec)) >= psnr:
                zr = br
                break
        gain = (zr / rate) if zr else float("nan")
        emit(f"quality_{name}", 0.0,
             f"CR={ratio:.2f};bitrate={rate:.2f};PSNR={psnr:.1f}dB;"
             f"bound_held={bound};baseline_bitrate={zr};bitrate_gain={gain:.2f}x")


if __name__ == "__main__":
    main()
