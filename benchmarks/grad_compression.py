"""Beyond-paper: cuSZ-quantized gradient all-reduce — error and collective
byte savings per mode (the multi-pod dry-run's int8 all-reduce HLO is the
structural proof; this benchmark quantifies the numerics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gradient as G
from .common import emit, timeit


def main() -> None:
    rng = np.random.default_rng(0)
    npods = 2
    g = {f"w{i}": jnp.asarray(rng.standard_normal((npods, 512, 1024))
                              .astype(np.float32) * 10 ** rng.uniform(-4, 0))
         for i in range(4)}
    ref = jax.tree.map(lambda x: np.asarray(x).mean(0), g)
    n_elems = sum(x.size // npods for x in jax.tree.leaves(g))
    for mode, bytes_per in (("none", 4), ("int16", 2), ("int8", 1)):
        fn = jax.jit(lambda t: G.compressed_psum_mean(t, mode, npods))
        t = timeit(fn, g)
        out = fn(g)
        err = max(float(np.abs(np.asarray(o) - r).max() /
                        (np.abs(r).max() + 1e-30))
                  for o, r in zip(jax.tree.leaves(out), jax.tree.leaves(ref)))
        emit(f"gradsync_{mode}", t,
             f"collective_MB={n_elems * bytes_per / 1e6:.1f};"
             f"rel_err={err:.2e};reduction={4 / bytes_per:.0f}x")


if __name__ == "__main__":
    main()
