"""Paper Table 6: deflate/inflate throughput vs chunk size (2^6..2^16).

Reproduces the paper's finding that a moderate chunk count (~2e4
concurrent chunks on V100; the analogous sweet spot here) balances
parallelism against per-chunk overhead."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C, dualquant as dq, huffman as hf
from repro.data import scidata
from .common import emit, timeit


def main() -> None:
    f = jnp.asarray(scidata.hacc_like(1 << 21))
    cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
    eb = C.resolve_eb(cfg, f)
    delta = dq.blocked_delta(f, eb, (256,))
    codes, _ = dq.postquant_codes(delta, cfg.nbins)
    cb = hf.canonical_codebook(hf.codeword_lengths(hf.histogram(codes, cfg.nbins)))
    cw, bw = hf.encode(codes, cb)
    n = cw.shape[0]
    nbytes = f.size * 4
    ml = hf.bucket_max_len(max(1, int(cb.max_len)))
    table = hf.decode_table(cb.lengths, ml)
    for lg in range(6, 17):
        chunk = 1 << lg
        sub = C.CompressorConfig().sub_size if chunk >= C.CompressorConfig().sub_size else chunk
        defl = jax.jit(lambda c, b: hf.deflate(c, b, chunk, sub))
        t_d = timeit(defl, cw, bw)
        words, bits, gap_bits, _ = defl(cw, bw)
        nc = words.shape[0]
        n_valid = jnp.asarray(np.minimum(
            chunk, np.maximum(n - np.arange(nc) * chunk, 0)).astype(np.int32))
        infl = jax.jit(lambda w, v, g: hf.inflate_gap(w, v, g, table, sub, ml))
        t_i = timeit(infl, words, n_valid, gap_bits)
        emit(f"deflate_c{chunk}", t_d,
             f"GBps={nbytes / t_d / 1e9:.3f};threads={nc:.0f}")
        emit(f"inflate_c{chunk}", t_i,
             f"GBps={nbytes / t_i / 1e9:.3f};subchunks={nc * chunk // sub:.0f}")


if __name__ == "__main__":
    main()
