"""Checkpoint write-path wall time: sync vs async vs sharded, per codec.

For each checkpoint codec policy (lossless / cusz / int8) and each write
mode, measures:

  * ``blocked_s``  — time the step loop is stalled by the save call
                     (sync: the whole save; async: encode + submit only)
  * ``total_s``    — time until the step directory is durably committed
                     (async: includes the writer-thread drain)

so the async win is visible as blocked_s << total_s, and the sharded
win as smaller per-file writes.  Writes ``BENCH_checkpoint.json``
records ``{mode, codec, nshards, blocked_s, total_s, MBps, bytes}``.
"""
from __future__ import annotations

import glob
import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.io import checkpoint as CK
from repro.io.async_writer import AsyncWriter
from .common import emit, write_json

JSON_NAME = "BENCH_checkpoint.json"

CODECS = ("lossless", "cusz", "int8")
MODES = (("sync", 1), ("async", 1), ("sharded-sync", 4), ("sharded-async", 4))


def _state(small: bool):
    """A checkpoint-shaped tree: a few compressible (smooth) weight-like
    leaves plus small raw leaves (bias / step counter)."""
    rng = np.random.default_rng(0)
    n = 64 if small else 512
    tree = {"step": jnp.asarray(np.int32(7)),
            "bias": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
    for i in range(4):
        w = np.cumsum(rng.standard_normal((n, 1024)), axis=-1)
        tree[f"w{i}"] = jnp.asarray(w.astype(np.float32))
    return tree


def _policy(codec: str) -> CK.CheckpointPolicy:
    if codec == "cusz":
        return CK.CheckpointPolicy(codec="cusz", eb_valrel=1e-4)
    return CK.CheckpointPolicy(codec=codec)


def _dir_bytes(d: str) -> int:
    return sum(os.path.getsize(p) for p in glob.glob(os.path.join(d, "*")))


def main(small: bool = False, json_dir: str = ".") -> None:
    tree = _state(small)
    raw = sum(int(v.size) * v.dtype.itemsize for v in tree.values())
    records = []
    base = tempfile.mkdtemp(prefix="repro_bench_ckpt_")
    try:
        for codec in CODECS:
            policy = _policy(codec)
            for mode, nshards in MODES:
                d = os.path.join(base, f"{codec}_{mode}")
                os.makedirs(d, exist_ok=True)
                use_async = mode.endswith("async")
                writer = AsyncWriter(max_pending=1) if use_async else None
                # warmup save (jit compiles), then the timed one
                CK.save_checkpoint(d, 0, tree, policy=policy,
                                   nshards=nshards, writer=writer)
                if writer is not None:
                    writer.wait()
                t0 = time.perf_counter()
                CK.save_checkpoint(d, 1, tree, policy=policy,
                                   nshards=nshards, writer=writer)
                blocked = time.perf_counter() - t0
                if writer is not None:
                    writer.wait()
                total = time.perf_counter() - t0
                stored = _dir_bytes(os.path.join(d, "step_00000001"))
                rec = {"mode": mode, "codec": codec, "nshards": nshards,
                       "blocked_s": round(blocked, 6),
                       "total_s": round(total, 6),
                       "MBps": round(raw / total / 1e6, 2),
                       "bytes": stored}
                records.append(rec)
                emit(f"ckpt_{codec}_{mode}", total,
                     f"blocked_ms={blocked * 1e3:.2f};"
                     f"MBps={rec['MBps']};ratio={raw / max(1, stored):.2f}")
                if writer is not None:
                    writer.close()
    finally:
        shutil.rmtree(base, ignore_errors=True)
    write_json(os.path.join(json_dir, JSON_NAME), records)


if __name__ == "__main__":
    main()
