"""Prefill->decode KV handoff wire accounting + wall time (the serve
reshard leg of "compressed all-to-all beyond MoE").

For a real reduced-model prefill, measures what crossing the
prefill->decode mesh boundary costs per wire codec:

  * ``raw``        — the bf16 bytes the uncompressed reshard would ship
    (lossless containers; the baseline row).
  * ``int8-block`` — blockwise-quantized payloads.  From a compressed
    prefill this is a pure payload re-slice (``adopt`` path: the decode
    side takes the payload as its in-memory QuantKV with no f32 round
    trip); from a raw prefill it is quantize-on-the-wire (FZ-GPU-style
    throughput codec).
  * ``cusz``       — the full dual-quant + Huffman pipeline per slab
    (the host-offload/storage leg).
  * ``fz``         — Lorenzo + fused bitshuffle with zero-plane elision
    (the error-bounded throughput wire: no codebook on encode, no host
    prep on decode).

Writes ``BENCH_reshard.json`` records ``{wire, source, wire_bytes,
raw_bf16_bytes, ratio, encode_s, reshard_s, containers}``.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve.engine import (LAST_HANDOFF_STATS, ServeConfig,
                                encode_handoff, prefill, reshard_caches)
from .common import emit, write_json

JSON_NAME = "BENCH_reshard.json"

WIRES = ("lossless", "int8-block", "cusz", "fz")


def _sweep(cfg, params, prompt, scfg, source: str, records: list) -> None:
    _, caches, plen = prefill(params, cfg, prompt, scfg)
    jax.block_until_ready(jax.tree_util.tree_leaves(caches))
    for wire in WIRES:
        if source == "quantkv" and wire == "lossless":
            continue                     # raw baseline comes from the raw run
        t0 = time.perf_counter()
        h = encode_handoff(caches, cfg, scfg, wire=wire, plen=plen)
        t_enc = time.perf_counter() - t0
        stats = dict(LAST_HANDOFF_STATS)
        t1 = time.perf_counter()
        out = reshard_caches(h, cfg, scfg)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        t_rs = time.perf_counter() - t1
        name = "raw" if wire == "lossless" else wire
        rec = {"wire": name, "source": source,
               "wire_bytes": int(stats["wire_bytes"]),
               "raw_bf16_bytes": int(stats["raw_bf16_bytes"]),
               "ratio": round(stats["raw_bf16_bytes"]
                              / max(1, stats["wire_bytes"]), 3),
               "encode_s": round(t_enc, 4), "reshard_s": round(t_rs, 4),
               "containers": int(stats["containers"])}
        records.append(rec)
        emit(f"reshard_{source}_{name}", t_enc + t_rs,
             f"wire={rec['wire_bytes']}B ratio={rec['ratio']}")


def main(small: bool = False, json_dir: str = ".") -> None:
    records: list = []
    cfg = configs.reduced("qwen2.5-3b", n_periods=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, plen = (2, 24) if small else (4, 96)
    s_max = 256 if small else 1024
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, plen))
                         .astype(np.int32))
    # raw (uncompressed bf16) prefill: the wire codecs quantize on the wire
    _sweep(cfg, params, prompt,
           ServeConfig(s_max=s_max, compressed_kv=False), "raw", records)
    # compressed prefill: int8-block is a pure payload adopt (no f32)
    _sweep(cfg, params, prompt,
           ServeConfig(s_max=s_max, compressed_kv=True), "quantkv", records)
    write_json(os.path.join(json_dir, JSON_NAME), records)


if __name__ == "__main__":
    main()
