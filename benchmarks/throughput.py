"""Paper Table 7: per-stage throughput breakdown of the full pipeline
(predict-quant, histogram, codebook, encode, deflate; decoding: inflate,
reversed predict-quant).  CPU numbers — relative structure mirrors the
paper's breakdown; absolute TPU projections live in the roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C, dualquant as dq, huffman as hf
from repro.data import scidata
from .common import emit, timeit


def main() -> None:
    fields = {
        "hacc": scidata.hacc_like(1 << 21),
        "cesm": scidata.cesm_like((450, 900)),
        "hurricane": scidata.hurricane_like((25, 250, 250)),
        "nyx": scidata.nyx_like((96, 96, 96)),
        "qmcpack": scidata.qmcpack_like((12, 36, 36, 36)),
    }
    for name, arr in fields.items():
        f = jnp.asarray(arr)
        nbytes = f.size * 4
        cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
        eb = C.resolve_eb(cfg, f)
        block = cfg.block_for(f.ndim)

        dquant = jax.jit(lambda x: dq.blocked_delta(x, eb, block))
        t = timeit(dquant, f)
        emit(f"T7_{name}_dualquant", t, f"GBps={nbytes / t / 1e9:.3f}")
        delta = dquant(f)
        codes, _ = dq.postquant_codes(delta, cfg.nbins)

        t = timeit(jax.jit(lambda c: hf.histogram(c, cfg.nbins)), codes)
        emit(f"T7_{name}_histogram", t, f"GBps={nbytes / t / 1e9:.3f}")
        hist = hf.histogram(codes, cfg.nbins)

        build = jax.jit(lambda h: hf.canonical_codebook(
            hf.codeword_lengths(h)).codes)
        t = timeit(build, hist)
        emit(f"T7_{name}_codebook", t, f"ms={t * 1e3:.2f}")
        cb = hf.canonical_codebook(hf.codeword_lengths(hist))

        enc = jax.jit(lambda c: hf.encode(c, cb))
        t = timeit(enc, codes)
        emit(f"T7_{name}_encode", t, f"GBps={nbytes / t / 1e9:.3f}")
        cw, bw = enc(codes)

        defl = jax.jit(lambda c, b: hf.deflate(c, b, cfg.chunk_size))
        t = timeit(defl, cw, bw)
        emit(f"T7_{name}_deflate", t, f"GBps={nbytes / t / 1e9:.3f}")

        comp = jax.jit(lambda x: C._compress_impl(x, cfg, eb).words)
        t_comp = timeit(comp, f)
        emit(f"T7_{name}_compress_total", t_comp,
             f"GBps={nbytes / t_comp / 1e9:.3f}")

        blob, _ = C.compress(f, cfg)
        ml = max(1, int(blob.max_len))
        dec = jax.jit(lambda b: C._decompress_impl(b, cfg, eb,
                                                   tuple(f.shape), ml))
        t_dec = timeit(dec, blob)
        emit(f"T7_{name}_decompress_total", t_dec,
             f"GBps={nbytes / t_dec / 1e9:.3f}")


if __name__ == "__main__":
    main()
