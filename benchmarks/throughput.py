"""Paper Table 7: per-stage throughput breakdown of the full pipeline —
now swept over the kernel-dispatch IMPL AXIS:

  jax               XLA reference impls (the pre-dispatch baseline)
  pallas-interpret  Pallas kernels in interpret mode (route validation;
                    its absolute timings are NOT a perf claim on CPU)
  pallas            compiled Pallas kernels (added automatically when the
                    backend is tpu/gpu)

The stage axis is DERIVED from the configured pipeline: each benchmarked
kernel row comes from the predictor's and encoder's declared ``kernels``
tuples (``core.stages`` registries), so a new stage composition gets its
rows without touching this file — no hard-coded stage list to go stale.
The lorenzo+huffman composition additionally keeps its historical rows
(`dualquant_unfused`, `codebook`, `inflate_seq`, the jitted
compress/decompress totals) and historical short stage names
(``dualquant`` for ``lorenzo.dualquant`` etc.) so the perf trajectory
stays comparable across runs.  A second sweep times the cusz-i and fz
stage compositions end to end (``pipeline_compress``/
``pipeline_decompress`` rows).

CPU wall-clock numbers are *relative* signals (DESIGN.md §9); the TPU
story is the roofline.  Emits CSV lines on stdout and writes
BENCH_throughput.json records: {stage, field, impl, seconds, GBps}.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C, dualquant as dq, huffman as hf
from repro.core import interp as interp_mod
from repro.core import stages
from repro.data import scidata
from repro.kernels import dispatch
from repro.kernels.bitshuffle import ops as bitshuffle_ops
from repro.kernels.deflate import ops as deflate_ops
from repro.kernels.encode import ops as encode_ops
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.inflate import ops as inflate_ops
from repro.kernels.interp import ops as interp_ops
from repro.kernels.lorenzo import ops as lorenzo_ops
from .common import emit, timeit, write_json

JSON_NAME = "BENCH_throughput.json"

#: historical short row names for the original six pipeline stages (the
#: CI trend lines key on these); new stage kernels report under their
#: registry key verbatim
_SHORT = {v: k for k, v in dispatch._LEGACY_FIELDS.items()}


def _impl_axis() -> List[str]:
    impls = ["jax", "pallas-interpret"]
    if jax.default_backend() in ("tpu", "gpu", "cuda", "rocm"):
        impls.append("pallas")
    return impls


def _fields(small: bool) -> Dict[str, np.ndarray]:
    if small:
        return {
            "hacc": scidata.hacc_like(1 << 16),
            "cesm": scidata.cesm_like((90, 180)),
            "hurricane": scidata.hurricane_like((10, 50, 50)),
            "nyx": scidata.nyx_like((32, 32, 32)),
        }
    return {
        "hacc": scidata.hacc_like(1 << 21),
        "cesm": scidata.cesm_like((450, 900)),
        "hurricane": scidata.hurricane_like((25, 250, 250)),
        "nyx": scidata.nyx_like((96, 96, 96)),
        "qmcpack": scidata.qmcpack_like((12, 36, 36, 36)),
    }


def _stage_timers(f: jax.Array, cfg: C.CompressorConfig, eb: float,
                  needed) -> Dict[str, Callable[[str], Tuple[Callable,
                                                             tuple]]]:
    """Kernel-name -> (impl -> (callable, args)) table for exactly the
    stage kernels the configured pipeline composes.  Inputs are prepared
    once per field from the reference (jax) path, so each timer measures
    one stage in isolation."""
    timers: Dict[str, Callable] = {}
    need = set(needed)

    if {"lorenzo.dualquant", "lorenzo.reverse"} & need:
        block = cfg.block_for(f.ndim)
        xb = dq.block_split(dq.pad_to_blocks(f, block), block)
        nb = tuple(p // b for p, b in
                   zip(dq.padded_shape(f.shape, block), block))
        dblk = jnp.zeros(nb + tuple(block), jnp.int32)
        timers["lorenzo.dualquant"] = lambda impl: (
            lambda x: lorenzo_ops.dualquant_blocks(x, eb, cfg.nbins,
                                                   impl=impl), (xb,))
        timers["lorenzo.reverse"] = lambda impl: (
            lambda d: lorenzo_ops.reverse_blocks(d, eb, impl=impl), (dblk,))

    if {"interp.predict", "interp.reconstruct"} & need:
        steps, _ = interp_mod.interp_plan(f.shape)
        axis, _ = steps[0]
        xm = jnp.moveaxis(dq.prequant(f, eb), axis, -1)
        even, odd = xm[..., 0::2], xm[..., 1::2]
        e2 = interp_mod._pad_even(even.reshape(-1, even.shape[-1]))
        o2 = odd.reshape(-1, odd.shape[-1])
        r2 = interp_ops.residual_rows(e2, o2, impl="jax")
        timers["interp.predict"] = lambda impl: (
            lambda a, b: interp_ops.residual_rows(a, b, impl=impl),
            (e2, o2))
        timers["interp.reconstruct"] = lambda impl: (
            lambda a, b: interp_ops.odd_rows(a, b, impl=impl), (e2, r2))

    # every downstream (encoder) stage consumes the predictor's codes
    if need - {"lorenzo.dualquant", "lorenzo.reverse",
               "interp.predict", "interp.reconstruct"}:
        pred = stages.get_predictor(cfg.predictor)
        codes, _ = pred.predict(f, cfg, eb, dispatch.pipeline_policy("jax"))
        codes_flat = codes.reshape(-1)

    if {"histogram", "encode", "deflate", "inflate"} & need:
        hist = hist_ops.histogram(codes, cfg.nbins, impl="jax")
        cb = hf.canonical_codebook(hf.codeword_lengths(hist))
        cw, bw = encode_ops.encode(codes, cb, impl="jax")
        words, bits_used, gap_bits, _ = deflate_ops.deflate(
            cw, bw, cfg.chunk_size, cfg.sub_size, impl="jax")
        nv = jnp.minimum(
            jnp.maximum(0, codes_flat.shape[0]
                        - jnp.arange(bits_used.shape[0]) * cfg.chunk_size),
            cfg.chunk_size).astype(jnp.int32)
        ml = hf.bucket_max_len(max(1, int(jnp.max(cb.lengths))))
        table = hf.decode_table(cb.lengths, ml)
        timers["histogram"] = lambda impl: (
            lambda c: hist_ops.histogram(c, cfg.nbins, impl=impl), (codes,))
        timers["encode"] = lambda impl: (
            lambda c: encode_ops.encode(c, cb, impl=impl), (codes,))
        timers["deflate"] = lambda impl: (
            lambda c, b: deflate_ops.deflate(c, b, cfg.chunk_size,
                                             cfg.sub_size, impl=impl)[0],
            (cw, bw))
        timers["inflate"] = lambda impl: (
            lambda w, bu, n, g: inflate_ops.inflate(
                w, bu, n, table, ml, gaps=g, impl=impl),
            (words, bits_used, nv, gap_bits))

    if {"bitshuffle.encode", "bitshuffle.decode"} & need:
        chunk = int(cfg.chunk_size)
        n = codes_flat.shape[0]
        nc = -(-n // chunk)
        flat = jnp.concatenate(
            [codes_flat, jnp.full((nc * chunk - n,), cfg.nbins // 2,
                                  jnp.int32)]) if nc * chunk != n \
            else codes_flat
        codes2 = flat.reshape(nc, chunk)
        planes = bitshuffle_ops.encode_planes(codes2, cfg.nbins, impl="jax")
        timers["bitshuffle.encode"] = lambda impl: (
            lambda c: bitshuffle_ops.encode_planes(c, cfg.nbins, impl=impl),
            (codes2,))
        timers["bitshuffle.decode"] = lambda impl: (
            lambda p: bitshuffle_ops.decode_planes(p, cfg.nbins, impl=impl),
            (planes,))

    return timers


def _bench_field(name: str, arr: np.ndarray, cfg: C.CompressorConfig,
                 impls: List[str], records: list) -> None:
    f = jnp.asarray(arr)
    nbytes = f.size * 4
    eb = C.resolve_eb(cfg, f)

    def rec(stage, impl, t, gbps=None):
        tag = f"T7_{name}_{stage}" + ("" if impl == "jax" else f"_{impl}")
        derived = (f"GBps={gbps:.3f}" if gbps is not None
                   else f"ms={t * 1e3:.2f}")
        emit(tag, t, derived)
        records.append({"stage": stage, "field": name, "impl": impl,
                        "seconds": t,
                        "GBps": gbps if gbps is not None else 0.0})

    # the stage axis comes from the pipeline's own stage declarations
    pipe = C.StagedPipeline.from_cfg(cfg)
    stage_kernels = pipe.predictor.kernels + pipe.encoder.kernels
    timers = _stage_timers(f, cfg, eb, stage_kernels)

    # unfused baseline (jax only — it IS the old reference path): two
    # dispatches with the delta tree materialized in between
    block = cfg.block_for(f.ndim)
    pre = jax.jit(lambda x: dq.blocked_delta(x, eb, block))
    post = jax.jit(lambda d: dq.postquant_codes(d, cfg.nbins)[0])

    def unfused(x):
        return post(pre(x))

    t = timeit(unfused, f)
    rec("dualquant_unfused", "jax", t, nbytes / t / 1e9)

    # lorenzo+huffman keeps its historical blob-path rows (codebook,
    # sequential-inflate cliff, jitted compress/decompress totals)
    hist = hist_ops.histogram(
        pipe.predictor.predict(f, cfg, eb,
                               dispatch.pipeline_policy("jax"))[0],
        cfg.nbins, impl="jax")
    t = timeit(jax.jit(lambda h: hf.canonical_codebook(
        hf.codeword_lengths(h)).codes), hist)
    rec("codebook", "jax", t)

    blob, _ = C.compress(f, dataclasses.replace(cfg, kernel_impl="jax"))
    ml = hf.bucket_max_len(max(1, int(blob.max_len)))
    table = hf.decode_table(blob.lengths, ml)

    # legacy sequential decode (the format-v1 path): one jax-only row —
    # the cliff the gap-array decode exists to kill
    t = timeit(lambda w, bu, nv: inflate_ops.inflate(
        w, bu, nv, table, ml, impl="jax"),
        blob.words, blob.bits_used, blob.n_valid)
    rec("inflate_seq", "jax", t, nbytes / t / 1e9)

    for impl in impls:
        for kname in stage_kernels:
            fn, fargs = timers[kname](impl)
            t = timeit(fn, *fargs)
            rec(_SHORT.get(kname, kname), impl, t, nbytes / t / 1e9)

        icfg = dataclasses.replace(cfg, kernel_impl=impl)
        pp = dispatch.pipeline_policy(impl)
        t = timeit(lambda x: C._compress_impl(x, icfg, eb, pp).words, f)
        rec("compress_total", impl, t, nbytes / t / 1e9)

        dec = jax.jit(lambda b: C._decompress_impl(
            b, table, icfg, eb, tuple(f.shape), ml, pp))
        t = timeit(dec, blob)
        rec("decompress_total", impl, t, nbytes / t / 1e9)


def _bench_staged(name: str, arr: np.ndarray, label: str,
                  cfg: C.CompressorConfig, impls: List[str],
                  records: list) -> None:
    """Stage rows + end-to-end staged-pipeline rows for a non-default
    predictor x encoder composition (cusz-i, fz)."""
    f = jnp.asarray(arr)
    nbytes = f.size * 4
    eb = C.resolve_eb(cfg, f)
    field = f"{name}[{label}]"

    def rec(stage, impl, t, gbps=None):
        tag = f"T7_{field}_{stage}" + ("" if impl == "jax" else f"_{impl}")
        emit(tag, t, f"GBps={gbps:.3f}" if gbps is not None
             else f"ms={t * 1e3:.2f}")
        records.append({"stage": stage, "field": field, "impl": impl,
                        "seconds": t,
                        "GBps": gbps if gbps is not None else 0.0})

    pipe = C.StagedPipeline.from_cfg(cfg)
    stage_kernels = pipe.predictor.kernels + pipe.encoder.kernels
    timers = _stage_timers(f, cfg, eb, stage_kernels)
    payload, _ = C.staged_compress(f, cfg)

    for impl in impls:
        for kname in stage_kernels:
            fn, fargs = timers[kname](impl)
            t = timeit(fn, *fargs)
            rec(_SHORT.get(kname, kname), impl, t, nbytes / t / 1e9)

        icfg = dataclasses.replace(cfg, kernel_impl=impl)
        t = timeit(lambda x: C.staged_compress(x, icfg)[0], f)
        rec("pipeline_compress", impl, t, nbytes / t / 1e9)
        t = timeit(lambda p: C.staged_decompress(p, icfg, eb,
                                                 tuple(f.shape)), payload)
        rec("pipeline_decompress", impl, t, nbytes / t / 1e9)


def main(small: bool = False, json_dir: str = ".",
         impls: Optional[List[str]] = None) -> list:
    impls = impls or _impl_axis()
    records: list = []
    cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel",
                             chunk_size=512 if small else 4096)
    for name, arr in _fields(small).items():
        _bench_field(name, arr, cfg, impls, records)
    # the non-default stage compositions, one representative field each
    staged_field = "cesm"
    arr = _fields(small)[staged_field]
    _bench_staged(staged_field, arr, "cusz-i",
                  dataclasses.replace(cfg, predictor="interp"),
                  impls, records)
    _bench_staged(staged_field, arr, "fz",
                  dataclasses.replace(cfg, encoder="bitshuffle",
                                      outlier_frac=1.0),
                  impls, records)
    write_json(os.path.join(json_dir, JSON_NAME), records)
    return records


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--small", action="store_true")
    p.add_argument("--json-dir", default=".")
    args = p.parse_args()
    main(small=args.small, json_dir=args.json_dir)
