"""Paper Table 7: per-stage throughput breakdown of the full pipeline
(dual-quant, histogram, codebook, encode, deflate; decoding: inflate,
reversed dual-quant) — now swept over the kernel-dispatch IMPL AXIS:

  jax               XLA reference impls (the pre-dispatch baseline)
  pallas-interpret  Pallas kernels in interpret mode (route validation;
                    its absolute timings are NOT a perf claim on CPU)
  pallas            compiled Pallas kernels (added automatically when the
                    backend is tpu/gpu)

plus the fused-vs-unfused dual-quant comparison: `dualquant_unfused` is
the old two-dispatch form (materialize the delta tree, then postquant),
`dualquant` is the single fused kernels-op invocation the compressor now
uses.  CPU wall-clock numbers are *relative* signals (DESIGN.md §9); the
TPU story is the roofline.

Emits CSV lines on stdout (as before) and writes BENCH_throughput.json
records: {stage, field, impl, seconds, GBps}.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C, dualquant as dq, huffman as hf
from repro.data import scidata
from repro.kernels import dispatch
from repro.kernels.deflate import ops as deflate_ops
from repro.kernels.encode import ops as encode_ops
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.inflate import ops as inflate_ops
from repro.kernels.lorenzo import ops as lorenzo_ops
from .common import emit, timeit, write_json

JSON_NAME = "BENCH_throughput.json"


def _impl_axis() -> List[str]:
    impls = ["jax", "pallas-interpret"]
    if jax.default_backend() in ("tpu", "gpu", "cuda", "rocm"):
        impls.append("pallas")
    return impls


def _fields(small: bool) -> Dict[str, np.ndarray]:
    if small:
        return {
            "hacc": scidata.hacc_like(1 << 16),
            "cesm": scidata.cesm_like((90, 180)),
            "hurricane": scidata.hurricane_like((10, 50, 50)),
            "nyx": scidata.nyx_like((32, 32, 32)),
        }
    return {
        "hacc": scidata.hacc_like(1 << 21),
        "cesm": scidata.cesm_like((450, 900)),
        "hurricane": scidata.hurricane_like((25, 250, 250)),
        "nyx": scidata.nyx_like((96, 96, 96)),
        "qmcpack": scidata.qmcpack_like((12, 36, 36, 36)),
    }


def _bench_field(name: str, arr: np.ndarray, cfg: C.CompressorConfig,
                 impls: List[str], records: list) -> None:
    f = jnp.asarray(arr)
    nbytes = f.size * 4
    eb = C.resolve_eb(cfg, f)
    block = cfg.block_for(f.ndim)
    xb = dq.block_split(dq.pad_to_blocks(f, block), block)

    def rec(stage, impl, t, gbps=None):
        tag = f"T7_{name}_{stage}" + ("" if impl == "jax" else f"_{impl}")
        derived = (f"GBps={gbps:.3f}" if gbps is not None
                   else f"ms={t * 1e3:.2f}")
        emit(tag, t, derived)
        records.append({"stage": stage, "field": name, "impl": impl,
                        "seconds": t,
                        "GBps": gbps if gbps is not None else 0.0})

    # unfused baseline (jax only — it IS the old reference path): two
    # dispatches with the delta tree materialized in between
    pre = jax.jit(lambda x: dq.blocked_delta(x, eb, block))
    post = jax.jit(lambda d: dq.postquant_codes(d, cfg.nbins)[0])

    def unfused(x):
        return post(pre(x))

    t = timeit(unfused, f)
    rec("dualquant_unfused", "jax", t, nbytes / t / 1e9)

    # shared stage inputs (reference impls, policy-independent values)
    codes, delta = lorenzo_ops.dualquant_blocks(xb, eb, cfg.nbins, impl="jax")
    hist = hist_ops.histogram(codes, cfg.nbins, impl="jax")
    cb = hf.canonical_codebook(hf.codeword_lengths(hist))
    cw, bw = encode_ops.encode(codes, cb, impl="jax")

    t = timeit(jax.jit(lambda h: hf.canonical_codebook(
        hf.codeword_lengths(h)).codes), hist)
    rec("codebook", "jax", t)

    # blob values are impl-independent (parity is bit-exact); build once
    blob, _ = C.compress(f, dataclasses.replace(cfg, kernel_impl="jax"))
    ml = hf.bucket_max_len(max(1, int(blob.max_len)))
    table = hf.decode_table(blob.lengths, ml)

    # legacy sequential decode (the format-v1 path): one jax-only row —
    # the cliff the gap-array decode exists to kill
    t = timeit(lambda w, bu, nv: inflate_ops.inflate(
        w, bu, nv, table, ml, impl="jax"),
        blob.words, blob.bits_used, blob.n_valid)
    rec("inflate_seq", "jax", t, nbytes / t / 1e9)

    nb = tuple(p // b for p, b in
               zip(dq.padded_shape(f.shape, block), block))
    dblk = jnp.zeros(nb + tuple(block), jnp.int32)

    for impl in impls:
        t = timeit(lambda x: lorenzo_ops.dualquant_blocks(
            x, eb, cfg.nbins, impl=impl), xb)
        rec("dualquant", impl, t, nbytes / t / 1e9)

        t = timeit(lambda c: hist_ops.histogram(c, cfg.nbins, impl=impl),
                   codes)
        rec("histogram", impl, t, nbytes / t / 1e9)

        t = timeit(lambda c: encode_ops.encode(c, cb, impl=impl), codes)
        rec("encode", impl, t, nbytes / t / 1e9)

        t = timeit(lambda c, b: deflate_ops.deflate(
            c, b, cfg.chunk_size, cfg.sub_size, impl=impl)[0], cw, bw)
        rec("deflate", impl, t, nbytes / t / 1e9)

        # gap-array two-phase inflate: the full impl axis (the Pallas
        # kernel exists now — this is the row the old jax-only note said
        # would never appear)
        t = timeit(lambda w, bu, nv, g: inflate_ops.inflate(
            w, bu, nv, table, ml, gaps=g, impl=impl),
            blob.words, blob.bits_used, blob.n_valid, blob.gap_bits)
        rec("inflate", impl, t, nbytes / t / 1e9)

        t = timeit(lambda d: lorenzo_ops.reverse_blocks(d, eb, impl=impl),
                   dblk)
        rec("reverse", impl, t, nbytes / t / 1e9)

        icfg = dataclasses.replace(cfg, kernel_impl=impl)
        pp = dispatch.pipeline_policy(impl)
        t = timeit(lambda x: C._compress_impl(x, icfg, eb, pp).words, f)
        rec("compress_total", impl, t, nbytes / t / 1e9)

        dec = jax.jit(lambda b: C._decompress_impl(
            b, table, icfg, eb, tuple(f.shape), ml, pp))
        t = timeit(dec, blob)
        rec("decompress_total", impl, t, nbytes / t / 1e9)


def main(small: bool = False, json_dir: str = ".",
         impls: Optional[List[str]] = None) -> list:
    impls = impls or _impl_axis()
    records: list = []
    cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel",
                             chunk_size=512 if small else 4096)
    for name, arr in _fields(small).items():
        _bench_field(name, arr, cfg, impls, records)
    write_json(os.path.join(json_dir, JSON_NAME), records)
    return records


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--small", action="store_true")
    p.add_argument("--json-dir", default=".")
    args = p.parse_args()
    main(small=args.small, json_dir=args.json_dir)
