"""Paper Figs 6-8: rate-distortion curves, cuSZ (fixed valrel sweep) vs
the cuZFP-like baseline (fixed rate sweep), on Hurricane- and Nyx-like
fields.  Emits curve points as CSV for plotting."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import compressor as C, metrics as M, zfp_like as Z
from repro.data import scidata
from .common import emit


def main() -> None:
    fields = {"hurricane": scidata.hurricane_like((25, 125, 125)),
              "nyx": scidata.nyx_like((96, 96, 96))}
    for name, arr in fields.items():
        f = jnp.asarray(arr)
        for valrel in (1e-2, 1e-3, 1e-4, 1e-5):
            cfg = C.CompressorConfig(eb=valrel, eb_mode="valrel")
            recon, blob, eb, ratio = C.roundtrip(f, cfg)
            rate = M.bitrate(f.size, C.compressed_bytes(blob, cfg.nbins))
            emit(f"rd_cusz_{name}_valrel{valrel:g}", 0.0,
                 f"bitrate={rate:.2f};PSNR={float(M.psnr(f, recon)):.1f}")
        for r in (4, 8, 12, 16, 20):
            rec, br = Z.compress_decompress(f, r)
            emit(f"rd_zfplike_{name}_rate{r}", 0.0,
                 f"bitrate={br:.2f};PSNR={float(M.psnr(f, rec)):.1f}")


if __name__ == "__main__":
    main()
