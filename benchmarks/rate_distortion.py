"""Paper Figs 6-8: rate-distortion curves, cuSZ (fixed valrel sweep) vs
the cuZFP-like baseline (fixed rate sweep), on Hurricane- and Nyx-like
fields — both sides through the `repro.codecs` registry.  Emits curve
points as CSV for plotting."""
from __future__ import annotations

import jax.numpy as jnp

from repro import codecs
from repro.core import metrics as M
from repro.data import scidata
from .common import emit


def main() -> None:
    fields = {"hurricane": scidata.hurricane_like((25, 125, 125)),
              "nyx": scidata.nyx_like((96, 96, 96))}
    for name, arr in fields.items():
        f = jnp.asarray(arr)
        for valrel in (1e-2, 1e-3, 1e-4, 1e-5):
            codec = codecs.get("cusz", eb=valrel, eb_mode="valrel")
            c = codec.encode(f)
            recon = codecs.decode(c)
            rate = M.bitrate(f.size, codec.stored_nbytes(c))
            emit(f"rd_cusz_{name}_valrel{valrel:g}", 0.0,
                 f"bitrate={rate:.2f};PSNR={float(M.psnr(f, recon)):.1f}")
        for r in (4, 8, 12, 16, 20):
            codec = codecs.get("zfp", rate_bits=r)
            c = codec.encode(f)
            rec = codecs.decode(c)
            emit(f"rd_zfplike_{name}_rate{r}", 0.0,
                 f"bitrate={codec.achieved_bitrate(c):.2f};"
                 f"PSNR={float(M.psnr(f, rec)):.1f}")


if __name__ == "__main__":
    main()
