"""Shared benchmark utilities: timing + CSV emission.

CPU-container caveat (DESIGN.md §9): wall-clock numbers here are CPU
measurements used as *relative* signals between variants; the TPU
performance story is the dry-run roofline (benchmarks/roofline.py).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def write_json(path: str, records: list):
    """Machine-readable benchmark output (one BENCH_*.json per module) so
    perf-trajectory tooling reads structured records instead of scraping
    the CSV stdout."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"# wrote {path} ({len(records)} records)")
