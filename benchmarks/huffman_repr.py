"""Paper Table 4: adaptive 32- vs 64-bit Huffman codeword representation.

Times the encode (codebook gather + unpack) with the packed u32 unit vs
the u64-emulated unit; derived column reports achieved GB/s over the
source bytes and the selected representation."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C, dualquant as dq, huffman as hf
from repro.data import scidata
from .common import emit, timeit


@partial(jax.jit, static_argnames=("unit",))
def encode_packed(codes, packed, unit):
    flat = codes.reshape(-1)
    if unit == 32:
        e = packed[flat]
        return e & jnp.uint32((1 << 26) - 1), e >> 26
    e = packed[flat]                       # [N,2] (hi=len, lo=code)
    return e[:, 1], e[:, 0]


def main() -> None:
    f = jnp.asarray(scidata.nyx_like((96, 96, 96)))
    cfg = C.CompressorConfig(eb=1e-4, eb_mode="valrel")
    eb = C.resolve_eb(cfg, f)
    delta = dq.blocked_delta(f, eb, (8, 8, 8))
    codes, _ = dq.postquant_codes(delta, cfg.nbins)
    cb = hf.canonical_codebook(hf.codeword_lengths(hf.histogram(codes, cfg.nbins)))
    nbytes = f.size * 4
    for unit in (32, 64):
        packed = hf.packed_codebook(cb, unit)
        t = timeit(lambda c, p: encode_packed(c, p, unit), codes, packed)
        emit(f"encode_u{unit}", t, f"GBps={nbytes / t / 1e9:.2f}")
    emit("selected_repr", 0.0,
         f"u{hf.select_repr(int(cb.max_len))} maxlen={int(cb.max_len)}")


if __name__ == "__main__":
    main()
