"""Benchmark harness entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; modules that support it
also write machine-readable ``BENCH_<module>.json`` records (currently
``throughput`` -> BENCH_throughput.json with {stage, field, impl,
seconds, GBps}).

  Table 3  -> codebook            Table 4  -> huffman_repr
  Table 5/8-> quality             Table 6  -> chunksize
  Table 7  -> throughput          Figs 6-8 -> rate_distortion
  beyond   -> grad_compression    §Roofline-> roofline (from dry-run JSONs)
  beyond   -> checkpoint (sync/async/sharded write path per codec)
  beyond   -> serve_latency (compressed-KV decode per token)
  beyond   -> serve_load (continuous vs static batching on the paged pool)
  beyond   -> reshard (prefill->decode handoff wire bytes per codec)
  beyond   -> fault_recovery (chaos-injected fault recovery wall time)

CLI:
  --only MOD[,MOD]   run a subset (e.g. --only throughput)
  --small            small-size smoke path (CI: fast, still sweeps the
                     kernel impl axis)
  --json-dir DIR     where BENCH_*.json files land (default: cwd)
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback

from . import (checkpoint, chunksize, codebook, fault_recovery,
               grad_compression, huffman_repr, quality, rate_distortion,
               reshard, roofline, serve_latency, serve_load, throughput)

MODULES = [
    ("codebook", codebook),
    ("huffman_repr", huffman_repr),
    ("quality", quality),
    ("chunksize", chunksize),
    ("throughput", throughput),
    ("rate_distortion", rate_distortion),
    ("grad_compression", grad_compression),
    ("checkpoint", checkpoint),
    ("serve_latency", serve_latency),
    ("serve_load", serve_load),
    ("reshard", reshard),
    ("fault_recovery", fault_recovery),
    ("roofline", roofline),
]


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated module subset")
    p.add_argument("--small", action="store_true",
                   help="small-size smoke path (CI)")
    p.add_argument("--json-dir", default=".",
                   help="directory for BENCH_*.json outputs")
    args = p.parse_args(argv)

    selected = MODULES
    if args.only:
        names = {s.strip() for s in args.only.split(",")}
        unknown = names - {n for n, _ in MODULES}
        if unknown:
            raise SystemExit(f"unknown modules: {sorted(unknown)}")
        selected = [(n, m) for n, m in MODULES if n in names]

    kwargs_all = {"small": args.small, "json_dir": args.json_dir}
    print("name,us_per_call,derived")
    failed = []
    for name, mod in selected:
        # pass only the kwargs each module's main() accepts
        accepted = inspect.signature(mod.main).parameters
        kwargs = {k: v for k, v in kwargs_all.items() if k in accepted}
        try:
            mod.main(**kwargs)
        except Exception as e:                     # noqa: BLE001
            failed.append(name)
            print(f"{name}_FAILED,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
