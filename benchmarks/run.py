"""Benchmark harness entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

  Table 3  -> codebook            Table 4  -> huffman_repr
  Table 5/8-> quality             Table 6  -> chunksize
  Table 7  -> throughput          Figs 6-8 -> rate_distortion
  beyond   -> grad_compression    §Roofline-> roofline (from dry-run JSONs)
"""
from __future__ import annotations

import sys
import traceback

from . import (chunksize, codebook, grad_compression, huffman_repr, quality,
               rate_distortion, roofline, throughput)

MODULES = [
    ("codebook", codebook),
    ("huffman_repr", huffman_repr),
    ("quality", quality),
    ("chunksize", chunksize),
    ("throughput", throughput),
    ("rate_distortion", rate_distortion),
    ("grad_compression", grad_compression),
    ("roofline", roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, mod in MODULES:
        try:
            mod.main()
        except Exception as e:                     # noqa: BLE001
            failed.append(name)
            print(f"{name}_FAILED,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
