"""Continuous-batching serve load test: many concurrent synthetic
sessions through the paged compressed-KV pool scheduler, continuous vs
static (wave) admission at the SAME pool budget.

Sessions have mixed prompt/generation lengths and Poisson-style seeded
arrivals (exponential inter-arrival gaps in decode-step units, from an
explicitly seeded generator — reruns see the identical trace).  Request
latency is measured arrival -> last token in decode-step units and
converted to seconds with the run's measured mean step time, so the
p50/p99 split reflects scheduling (queueing + waves) rather than
compile noise.

Writes ``BENCH_serve_load.json`` records
``{mode, requests, tokens, tokens_per_s, p50_s, p99_s, first_token_s,
mean_occupancy, peak_pages, preemptions, n_steps}``; CI asserts the
records are non-empty with a finite p99.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve import engine as E
from repro.serve import scheduler as S
from .common import emit, write_json

JSON_NAME = "BENCH_serve_load.json"

ARCH = "qwen2.5-3b"
SEED = 0


def _requests(n: int, max_prompt: int, max_new: int,
              mean_gap: float, seed: int):
    """Seeded synthetic session trace: mixed lengths, Poisson arrivals."""
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(mean_gap, size=n))
                        ).astype(int)
    return [S.Request(
        rid=i,
        prompt=rng.integers(1, 200, size=int(rng.integers(4, max_prompt + 1))
                            ).astype(np.int32),
        max_new=int(rng.integers(2, max_new + 1)),
        arrival=int(arrivals[i])) for i in range(n)]


def _run_mode(params, cfg, scfg, schedcfg, reqs, mode: str):
    runner = S.run_continuous if mode == "continuous" else S.run_static
    # warmup: replay the full trace once so every prefill length and the
    # (cfg, scfg, schedcfg) batched step are compiled before timing —
    # the timed run below measures scheduling, not tracing
    runner(params, cfg, scfg, schedcfg, reqs)
    t0 = time.perf_counter()
    fin, sched = runner(params, cfg, scfg, schedcfg, reqs)
    wall = time.perf_counter() - t0
    total = sum(len(f["tokens"]) for f in fin.values())
    step_s = wall / max(1, sched.n_steps)
    lat = sorted((f["t_finish"] - r.arrival) * step_s
                 for f, r in ((fin[r.rid], r) for r in reqs))
    first = sorted((f["t_submit"] - r.arrival + 1) * step_s
                   for f, r in ((fin[r.rid], r) for r in reqs))
    st = sched.pool.stats()
    return {"mode": mode, "requests": len(reqs), "tokens": total,
            "tokens_per_s": round(total / wall, 2),
            "p50_s": round(float(np.percentile(lat, 50)), 4),
            "p99_s": round(float(np.percentile(lat, 99)), 4),
            "first_token_s": round(float(np.percentile(first, 50)), 4),
            "mean_occupancy": round(float(np.mean(
                sched.occupancy_samples)), 4) if sched.occupancy_samples
            else 0.0,
            "peak_pages": st["peak_used"],
            "evicted_pages": st["evicted_pages"],
            "restored_pages": st["restored_pages"],
            "preemptions": sched.preemptions,
            "n_steps": sched.n_steps}


def main(small: bool = False, json_dir: str = ".") -> None:
    cfg = configs.reduced(ARCH, n_periods=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    if small:
        n, max_prompt, max_new, gap = 6, 12, 8, 1.0
        s_max, max_batch, pool_pages = 256, 2, 16
    else:
        n, max_prompt, max_new, gap = 16, 48, 24, 2.0
        s_max, max_batch, pool_pages = 512, 4, 32
    scfg = E.ServeConfig(s_max=s_max, compressed_kv=True,
                         compute_dtype=jnp.float32)
    schedcfg = S.SchedulerConfig(max_batch=max_batch,
                                 pool_pages=pool_pages,
                                 evict_codec="int8-block")
    reqs = _requests(n, max_prompt, max_new, gap, SEED)

    records = []
    for mode in ("continuous", "static"):
        rec = _run_mode(params, cfg, scfg, schedcfg, reqs, mode)
        records.append(rec)
        emit(f"serve_load_{mode}", rec["n_steps"],
             f"tokens_per_s={rec['tokens_per_s']};p99_s={rec['p99_s']};"
             f"n_steps={rec['n_steps']}")
    cont, stat = records
    # the deterministic form of "continuous beats static": fewer decode
    # steps for the same emitted tokens at the same pool budget
    assert cont["tokens"] == stat["tokens"], records
    assert cont["n_steps"] <= stat["n_steps"], records
    # same continuous trace with the fz eviction codec: the scheduler's
    # admission decisions must not change (page count, not page bytes,
    # drives scheduling), so tokens/steps match the int8-block run
    fz_cfg = S.SchedulerConfig(max_batch=max_batch, pool_pages=pool_pages,
                               evict_codec="fz")
    rec_fz = _run_mode(params, cfg, scfg, fz_cfg, reqs, "continuous")
    rec_fz["mode"] = "continuous-fz"
    records.append(rec_fz)
    emit("serve_load_continuous_fz", rec_fz["n_steps"],
         f"tokens_per_s={rec_fz['tokens_per_s']};p99_s={rec_fz['p99_s']};"
         f"n_steps={rec_fz['n_steps']}")
    assert rec_fz["tokens"] == cont["tokens"], records
    assert rec_fz["n_steps"] == cont["n_steps"], records
    write_json(os.path.join(json_dir, JSON_NAME), records)


if __name__ == "__main__":
    main()
