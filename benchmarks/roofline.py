"""§Roofline builder: joins the dry-run JSONs (compile proof, per-device
memory, collective inventory) with the analytic cost model (loop-aware
FLOPs/bytes/collective terms — see repro/perf/costmodel.py for why the
HLO cost_analysis alone cannot provide these) into the per-cell table.

Writes results/roofline.csv and prints a readable summary."""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.launch.dryrun import ARCH_TRAIN
from repro.perf import costmodel as CM


def build(dryrun_dir: str = "results/dryrun",
          out_csv: str = "results/roofline.csv"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            rows.append({"cell": rec["cell"], "status": "skipped",
                         "reason": rec.get("reason", "")})
            continue
        if rec.get("status") != "ok":
            rows.append({"cell": rec["cell"], "status": "error"})
            continue
        arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
        knobs = ARCH_TRAIN.get(arch, {})
        mb = knobs.get("microbatches", 1)
        if mesh == "multipod":
            mb = min(mb, 8)
        wc = "int8" if "__wc-int8" in rec["cell"] else "none"
        kvc = "__kvc" in rec["cell"]
        a2a = "int8" if "__a2a-int8" in rec["cell"] else "none"
        if "__mb" in rec["cell"]:
            mb = int(rec["cell"].split("__mb")[1].split("__")[0])
        cost = CM.cell_cost(
            arch, shape, mesh == "multipod",
            microbatches=mb,
            grad_compress=rec.get("grad_compress", "none"),
            accum_bytes=2 if knobs.get("accum_bf16") else 4,
            weight_compress=wc, kv_compress=kvc, a2a_compress=a2a)
        terms = cost.terms()
        mf = rec.get("model_flops_global", 0.0)
        chips = rec["n_chips"]
        useful = mf / (cost.flops * chips) if cost.flops else float("nan")
        bound = terms["bound_s"]
        ideal = terms["compute_s"]
        rows.append({
            "cell": rec["cell"], "status": "ok", "arch": arch,
            "shape": shape, "mesh": mesh,
            "gc": rec.get("grad_compress", "none"),
            "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "dominant": terms["dominant"],
            "roofline_frac": ideal / bound if bound else float("nan"),
            "useful_flops_ratio": useful,
            "mem_GiB_per_dev": rec["memory"]["per_device_total_GiB"],
            "hlo_coll_bytes_dev": rec["collective_bytes_per_device"],
            "hlo_coll_counts": json.dumps(rec.get("collective_counts", {})),
        })
    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    if rows:
        keys = ["cell", "status", "arch", "shape", "mesh", "gc", "compute_s",
                "memory_s", "collective_s", "dominant", "roofline_frac",
                "useful_flops_ratio", "mem_GiB_per_dev",
                "hlo_coll_bytes_dev", "hlo_coll_counts", "reason"]
        with open(out_csv, "w") as f:
            f.write(",".join(keys) + "\n")
            for r in rows:
                f.write(",".join(
                    f"\"{r.get(k, '')}\"" if k == "hlo_coll_counts"
                    else (f"{r.get(k, ''):.6g}" if isinstance(r.get(k), float)
                          else str(r.get(k, ""))) for k in keys) + "\n")
    return rows


def main() -> None:
    rows = build()
    ok = [r for r in rows if r.get("status") == "ok"]
    for r in ok:
        print(f"{r['cell']},{r['dominant']},"
              f"frac={r['roofline_frac']:.3f};mem={r['mem_GiB_per_dev']:.2f}GiB;"
              f"c/m/x={r['compute_s'] * 1e3:.1f}/{r['memory_s'] * 1e3:.1f}/"
              f"{r['collective_s'] * 1e3:.1f}ms")
    nskip = sum(1 for r in rows if r.get("status") == "skipped")
    nerr = sum(1 for r in rows if r.get("status") == "error")
    print(f"roofline_summary,0.0,ok={len(ok)};skipped={nskip};errors={nerr}")


if __name__ == "__main__":
    main()
