"""Compressed-KV decode latency per token (the serve-path latency leg
the PR-3 quality sweep left open).

Two measurement levels:

  * ``engine``  — a real jitted one-token decode step through the serve
    engine, uncompressed KV vs. each blockwise KV codec
    (`ServeConfig.kv_codec` registry ids), so the number includes the
    in-attention dequant on the hot path.  First-token latency (the cold
    call: trace + XLA compile + the step itself) and steady-state decode
    are reported as SEPARATE numbers — folding the one-off compile into
    a per-token mean made every engine row meaningless at small sizes.
  * ``dequant`` — the isolated blockwise dequantize of one layer's K/V
    buffers across scale-block sizes, which is the per-token marginal
    cost the cache codec adds.

Writes ``BENCH_serve_latency.json`` records
``{path, codec, block, first_token_ms, us_per_token}`` (``us_per_token``
is steady-state only; dequant rows have no first-token leg).  CPU
numbers are relative signals between codec variants (DESIGN.md §9).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs, configs
from repro.core import kvcache as KVC
from repro.models import model as M
from repro.serve.engine import ServeConfig, make_serve_step, prefill
from .common import emit, timeit, write_json

JSON_NAME = "BENCH_serve_latency.json"

# every registry codec that quantizes blockwise along one axis is a
# valid in-memory KV format; non-blockwise ids are rejected by
# get_block_codec, so this list is the sweepable axis
BLOCK_CODECS = ("int8-block",)


def _engine_records(small: bool, records: list) -> None:
    cfg = configs.reduced("qwen2.5-3b", n_periods=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, plen, n_new = (2, 8, 4) if small else (4, 32, 16)
    s_max = 128 if small else 512
    prompt = jnp.zeros((B, plen), jnp.int32)
    for codec in (None,) + BLOCK_CODECS:
        scfg = ServeConfig(s_max=s_max, compressed_kv=codec is not None,
                           kv_codec=codec or "int8-block")
        # a fresh jit per codec variant: the first call below is a true
        # cold start (trace + compile + execute) = the first-token number
        step = jax.jit(make_serve_step(cfg, scfg))
        last, caches, pl = prefill(params, cfg, prompt, scfg)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]

        t0 = time.perf_counter()
        logits, caches = jax.block_until_ready(
            step(params, tok, caches, jnp.int32(pl)))
        first = time.perf_counter() - t0
        tok = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)[:, None]

        def decode_tokens(tok, caches):
            for i in range(n_new):
                logits, caches = step(params, tok, caches,
                                      jnp.int32(pl + 1 + i))
                tok = jnp.argmax(logits[:, 0, :], axis=-1
                                 ).astype(jnp.int32)[:, None]
            return tok

        # steady state: timeit warms the loop once more, then medians
        # compiled-only iterations — the compile never rides in this mean
        t = timeit(decode_tokens, tok, caches) / n_new
        name = codec or "none"
        records.append({"path": "engine", "codec": name,
                        "block": KVC.SEQ_BLOCK if codec else 0,
                        "first_token_ms": round(first * 1e3, 2),
                        "us_per_token": round(t * 1e6, 2)})
        emit(f"serve_decode_{name}", t,
             f"first_token_ms={first * 1e3:.1f};"
             f"steady_us_per_token={t * 1e6:.1f}")


def _dequant_records(small: bool, records: list) -> None:
    B, H, S, hd = (2, 4, 512, 32) if small else (4, 8, 4096, 64)
    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.standard_normal((B, H, S, hd)).astype(np.float32))
    for name in BLOCK_CODECS:
        for block in (64, 128, 256):
            codec = codecs.get_block_codec(name, axis=2, block=block)
            cont = codec.encode(kv)
            dec = jax.jit(lambda c: codec.decode(c))
            t = timeit(dec, cont) / S           # amortized per cached token
            records.append({"path": "dequant", "codec": name, "block": block,
                            "us_per_token": round(t * 1e6, 3)})
            emit(f"kv_dequant_{name}_b{block}", t,
                 f"us_per_token={t * 1e6:.2f}")


def main(small: bool = False, json_dir: str = ".") -> None:
    records: list = []
    _engine_records(small, records)
    _dequant_records(small, records)
    write_json(os.path.join(json_dir, JSON_NAME), records)


if __name__ == "__main__":
    main()
