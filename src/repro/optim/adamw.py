"""AdamW with optional int8 block-quantized moments (the paper's PREQUANT
applied to optimizer state — halves-to-quarters the resident bytes of m/v,
which is what lets the 236B/398B configs fit 16 GB/chip; see DESIGN.md §5
and the dry-run memory analysis)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantized_moments: bool = False   # int8 m/v (large models)


class QTensor(NamedTuple):
    """Blockwise int8 tensor, same shape as the source (so it inherits the
    source's sharding rule); scales are per-QBLOCK along the last dim."""
    q: jax.Array        # int8, x.shape
    scale: jax.Array    # f32,  x.shape[:-1] + (last/QBLOCK,)


def _quantizable(x) -> bool:
    return x.ndim >= 1 and x.shape[-1] % QBLOCK == 0 and x.size >= 4096


def _quantize(x: jax.Array):
    if not _quantizable(x):
        return x.astype(jnp.float32)          # tiny leaves stay fp32
    nb = x.shape[-1] // QBLOCK
    xf = x.astype(jnp.float32).reshape(x.shape[:-1] + (nb, QBLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / 127.0, 1e-30)
    q = jnp.clip(jnp.rint(xf / scale[..., None]), -127, 127
                 ).astype(jnp.int8).reshape(x.shape)
    return QTensor(q, scale)


def _dequantize(qt, shape) -> jax.Array:
    if not isinstance(qt, QTensor):
        return qt
    nb = shape[-1] // QBLOCK
    xf = qt.q.astype(jnp.float32).reshape(tuple(shape[:-1]) + (nb, QBLOCK))
    return (xf * qt.scale[..., None]).reshape(shape)


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    if cfg.quantized_moments:
        zeros = jax.tree.map(lambda p: _quantize(jnp.zeros_like(p)), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(lambda p: _quantize(jnp.zeros_like(p)),
                                       params))
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), z,
                      jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   params))


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state)."""
    c = state.count + 1
    b1c = 1 - cfg.b1 ** c.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** c.astype(jnp.float32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32)
        if cfg.quantized_moments:
            m_f = _dequantize(m, g.shape)
            v_f = _dequantize(v, g.shape)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        newp = p - cfg.lr * (upd + cfg.weight_decay * p)
        if cfg.quantized_moments:
            return newp, _quantize(m_f), _quantize(v_f)
        return newp, m_f, v_f

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [leaf(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    newp = tdef.unflatten([o[0] for o in out])
    newm = tdef.unflatten([o[1] for o in out])
    newv = tdef.unflatten([o[2] for o in out])
    return newp, AdamWState(c, newm, newv)
