"""Pallas TPU kernels for cuSZ hot spots, each with ops.py (jit wrapper,
impl switch) and ref.py (pure-jnp oracle validated by tests)."""
from . import lorenzo, histogram, deflate  # noqa: F401
