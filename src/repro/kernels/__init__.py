"""Pallas TPU kernels for cuSZ hot spots, each with ops.py (jit wrapper,
dispatch-registered impl switch) and ref.py (pure-jnp oracle validated by
tests).  `dispatch` is the policy layer: it decides per backend — with a
process-level override for benchmarking/CI — whether a stage runs the
compiled Pallas kernel, the interpret-mode kernel, or the XLA reference.
"""
from . import dispatch  # noqa: F401  (import first: ops modules register)
from . import lorenzo, histogram, deflate, encode, inflate  # noqa: F401
