"""Jit'd public wrapper for the inflate stage; dispatch-registered.

Gap-array two-phase decode (Rivera et al., arXiv 2201.09118): when the
caller supplies the per-subchunk gap array that deflate now emits, decode
is parallel over subchunks and registers a real Pallas impl — the old
"inflate is RAW-bound, jax-only" era is over.  Gap-less streams (format
v1 containers) still decode through the sequential jax reference; an
explicit ``impl="pallas"`` request on such a stream raises, since the
Pallas kernel is the gap decoder.

The decode tables ride in a prebuilt `huffman.DecodeTable` (see
`huffman.decode_table` — built once per codebook, cached, never inside
the jitted decode).  A bare `Codebook` is accepted for convenience and
converted through the same cache.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.core import huffman as hf

from .. import dispatch
from . import kernel, ref

KERNEL = dispatch.register("inflate", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("max_len_static", "sub_size", "impl",
                                   "interpret"))
def _inflate_jit(words, bits_used, n_valid, table, gaps,
                 max_len_static: int, sub_size: int, impl: str,
                 interpret: bool):
    # repro-lint: allow[tracer-branch] `gaps` is a pytree-structure choice
    # (None on format-v1 streams), part of the jit cache key — not a tracer
    if gaps is None:
        del impl, interpret      # sequential path; uniform cache key
        return ref.inflate_seq_ref(words, bits_used, n_valid, table,
                                   max_len_static)
    if impl == "pallas":
        return kernel.inflate_pallas(words, n_valid, gaps, table, sub_size,
                                     interpret=interpret)
    return ref.inflate_gap_ref(words, n_valid, gaps, table, sub_size,
                               max_len_static)


def inflate(words, bits_used, n_valid, table, max_len_static: int,
            gaps=None, sub_size: Optional[int] = None,
            impl: Optional[str] = None, interpret: Optional[bool] = None):
    r = dispatch.resolve(KERNEL, impl, interpret)
    if isinstance(table, hf.Codebook):
        table = hf.decode_table(table.lengths, max_len_static)
    if gaps is None:
        if r.impl == "pallas" and impl is not None:
            raise NotImplementedError(
                "inflate impl='pallas' needs the gap array: the Pallas "
                "kernel is the gap-array subchunk decoder; gap-less "
                "(format v1) streams decode via the sequential jax path")
        sub_size = 0                       # unused on the sequential path
    elif sub_size is None:
        sub_size = words.shape[1] // gaps.shape[1]
    return _inflate_jit(words, bits_used, n_valid, table, gaps,
                        max_len_static, sub_size, r.impl, r.interpret)
