"""Jit'd public wrapper for the inflate stage; dispatch-registered.

Registered jax-only: the paper is explicit that inflate is RAW-bound and
sequential per chunk, so there is no Pallas win to chase here — an
ambient "pallas" policy resolves to this reference, and an explicit
``impl="pallas"`` request raises with the declared reason (see dispatch
module doc).  The LUT decode is the default whenever `max_len_static`
permits.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .. import dispatch
from . import ref

KERNEL = dispatch.register(
    "inflate", impls=("jax",),
    jax_only_reason="Huffman decode is RAW-bound and sequential per chunk "
                    "(cuSZ §V); a parallel gap-array two-phase decode is "
                    "the ROADMAP target before a pallas impl exists")


@partial(jax.jit, static_argnames=("max_len_static", "impl", "interpret"))
def _inflate_jit(words, bits_used, n_valid, cb, max_len_static: int,
                 impl: str, interpret: bool):
    del impl, interpret          # single impl; kept for a uniform cache key
    return ref.inflate_ref(words, bits_used, n_valid, cb, max_len_static)


def inflate(words, bits_used, n_valid, cb, max_len_static: int,
            impl: Optional[str] = None, interpret: Optional[bool] = None):
    r = dispatch.resolve(KERNEL, impl, interpret)
    return _inflate_jit(words, bits_used, n_valid, cb, max_len_static,
                        r.impl, r.interpret)
