"""Pallas TPU kernel: gap-array parallel Huffman inflate (phase 2 of
Rivera et al., arXiv 2201.09118).

The sequential decoder walks `chunk_size` symbols per chunk because every
codeword boundary depends on the previous one — the RAW hazard cuSZ §V
concedes.  The gap array breaks the chain: deflate records the bit offset
at every `sub_size`-symbol boundary, so each subchunk decodes
independently from its recorded start and the sequential walk shrinks to
`sub_size` steps with `n_sub = chunk_size / sub_size` lanes running in
lockstep.

One chunk per grid step; inside the kernel all `n_sub` subchunk cursors
advance together.  Per step, for each cursor:

  1. fetch the two words straddling the cursor's bit position via ONE-HOT
     CONTRACTIONS over the word index (the repo's standing MXU idiom —
     int32 matmuls are bit-exact, and an out-of-range index matches no
     one-hot row, yielding 0 exactly like a zero-padded stream);
  2. splice the 32-bit left-aligned peek window;
  3. canonical length-interval compare: left-aligned code intervals tile
     [0, 2^32) contiguously in length order, so
     `len = 1 + sum_l lmask[l] * [peek >= thresh[l]]` — no LUT in VMEM
     (the dense LUT would be a 2^16-entry gather; the compare is ~32
     lane-ops and serves every max-length regime);
  4. index the canonical symbol table, again via one-hot contraction.

Emitted symbols land in a [n_sub, sub_size] tile whose row-major reshape
is exactly chunk order.  Bit-exact with `core.huffman.inflate_gap` (the
vmapped jax reference of the same shape) and with the sequential decoder.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import huffman as hf

_TB = 64                     # padded table-row lanes (MAXLEN + 1 = 33)


def _gather_i32(idx, table_row):
    """table_row[idx] for a vector of indices, as a one-hot int32 matmul.

    idx: [n] int32; table_row: [T] int32.  Out-of-range idx -> 0."""
    n = idx.shape[0]
    t = table_row.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (n, t), 1)
    oh = (idx[:, None] == iota).astype(jnp.int32)
    return jax.lax.dot_general(oh, table_row[:, None],
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)[:, 0]


def _inflate_kernel(sub, n_sub, words_ref, gaps_ref, nv_ref, thresh_ref,
                    lmask_ref, fcode_ref, sidx_ref, scanon_ref, out_ref):
    W = n_sub * sub
    wrow = words_ref[...].reshape(-1).astype(jnp.int32)       # [W] bit-cast
    gaps = gaps_ref[...].reshape(-1).astype(jnp.int32)        # [n_sub]
    nv = nv_ref[0, 0]
    thresh = thresh_ref[...].reshape(-1)                      # [TB] uint32
    lmask = lmask_ref[...].reshape(-1)                        # [TB] int32
    fcode = fcode_ref[...].reshape(-1).astype(jnp.int32)      # [TB] bit-cast
    sidx = sidx_ref[...].reshape(-1)                          # [TB] int32
    scanon = scanon_ref[...].reshape(-1)                      # [K] int32
    base = jnp.arange(n_sub, dtype=jnp.int32) * sub

    def step(i, carry):
        bitpos, out = carry
        wi = bitpos >> 5
        bo = (bitpos & 31).astype(jnp.uint32)
        cur = _gather_i32(wi, wrow).astype(jnp.uint32) << bo
        nxt_w = _gather_i32(wi + 1, wrow).astype(jnp.uint32)
        nxt = jnp.where(bo > 0, nxt_w >> (jnp.uint32(32) - bo),
                        jnp.uint32(0))
        peek = cur | nxt                  # 32-bit left-aligned window
        hit = (peek[:, None] >= thresh[None, :]) & (lmask[None, :] > 0)
        ln = 1 + jnp.sum(hit.astype(jnp.int32), axis=1)
        lnc = jnp.clip(ln, 1, hf.MAXLEN)
        code = peek >> (jnp.uint32(32) - lnc.astype(jnp.uint32))
        fc = _gather_i32(lnc, fcode)
        si = _gather_i32(lnc, sidx)
        idx = si + code.astype(jnp.int32) - fc
        sym = _gather_i32(jnp.clip(idx, 0, scanon.shape[0] - 1), scanon)
        ok = (base + i) < nv
        out = jax.lax.dynamic_update_slice(
            out, jnp.where(ok, sym, 0)[:, None], (0, i))
        return bitpos + jnp.where(ok, ln, 0), out

    _, out = jax.lax.fori_loop(
        0, sub, step,
        (gaps, jnp.zeros((n_sub, sub), jnp.int32)))
    out_ref[...] = out.reshape(out_ref.shape)   # [n_sub, sub] -> chunk order


def _pad_row(x, n, dtype):
    x = jnp.asarray(x, dtype)
    return jnp.pad(x, (0, n - x.shape[0]))[None, :]


def inflate_pallas(words: jax.Array, n_valid: jax.Array, gap_bits: jax.Array,
                   table: hf.DecodeTable, sub_size: int,
                   interpret: bool = True) -> jax.Array:
    """words: [nc, W] uint32, n_valid: [nc], gap_bits: [nc, W//sub_size].
    Returns codes [nc, W] int32 (chunk order)."""
    nc, W = words.shape
    n_sub = gap_bits.shape[1]
    if n_sub * sub_size != W:
        raise ValueError(f"gap array [{nc}, {n_sub}] does not tile chunks "
                         f"of {W} symbols with sub_size={sub_size}")
    cb = table.cb
    k = cb.sym_canon.shape[0]
    kp = -(-k // 128) * 128                     # lane-pad the symbol table
    thresh = _pad_row(table.thresh, _TB, jnp.uint32)
    lmask = _pad_row(table.lmask, _TB, jnp.int32)
    fcode = _pad_row(cb.first_code, _TB, jnp.uint32)
    sidx = _pad_row(cb.start_idx, _TB, jnp.int32)
    scanon = _pad_row(cb.sym_canon, kp, jnp.int32)
    tspec = pl.BlockSpec((1, _TB), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_inflate_kernel, sub_size, n_sub),
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, W), lambda i: (i, 0)),
                  pl.BlockSpec((1, n_sub), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0)),
                  tspec, tspec, tspec, tspec,
                  pl.BlockSpec((1, kp), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, W), jnp.int32),
        interpret=interpret,
    )(words, gap_bits.astype(jnp.int32),
      n_valid.astype(jnp.int32).reshape(nc, 1),
      thresh, lmask, fcode, sidx, scanon)
