"""Reference impl for the inflate stage (= core/huffman.inflate).

The LUT path (max codeword length <= LUT_BITS) decodes O(symbols) per
chunk; the bit-scan fallback is O(bits).  Both are vmapped over chunks,
which is exactly the paper's coarse-grained inflate parallelism.
"""
import jax

from repro.core import huffman as hf


def inflate_ref(words: jax.Array, bits_used: jax.Array, n_valid: jax.Array,
                cb, max_len_static: int) -> jax.Array:
    return hf.inflate(words, bits_used, n_valid, cb, max_len_static)
