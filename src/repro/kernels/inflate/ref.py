"""Reference impls for the inflate stage (= core/huffman decoders).

`inflate_gap_ref` is the vmapped jax gap-array decoder — same shape as
the Pallas kernel (n_sub lockstep subchunk cursors per chunk, `sub_size`
sequential steps each) and bit-exact with it.  `inflate_seq_ref` is the
legacy per-chunk sequential decode kept for gap-less (format v1)
streams: LUT path when the max codeword length permits, bit-scan
fallback otherwise.
"""
import jax

from repro.core import huffman as hf


def inflate_seq_ref(words: jax.Array, bits_used: jax.Array,
                    n_valid: jax.Array, table, max_len_static: int
                    ) -> jax.Array:
    if max_len_static <= hf.LUT_BITS:
        # prebuilt LUT from the DecodeTable — the scatter+cummax build no
        # longer re-runs inside this decode trace
        return hf.inflate_lut(words, n_valid, table.cb,
                              lut_bits=max(1, max_len_static),
                              lut=(table.lut_sym, table.lut_len))
    return hf.inflate_bitscan(words, bits_used, n_valid, table.cb)


def inflate_gap_ref(words: jax.Array, n_valid: jax.Array, gap_bits: jax.Array,
                    table, sub_size: int, max_len_static: int) -> jax.Array:
    return hf.inflate_gap(words, n_valid, gap_bits, table, sub_size,
                          max_len_static)
