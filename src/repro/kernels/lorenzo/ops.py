"""Jit'd public wrapper for the Lorenzo dual-quant kernel.

impl='jax'    -> pure-jnp oracle (XLA; works on any backend, used in the
                 multi-pod dry-run where the TPU Pallas lowering is
                 unavailable on the CPU host platform)
impl='pallas' -> Pallas kernel (interpret=True on CPU for validation,
                 compiled on real TPUs)
"""
from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


@partial(jax.jit, static_argnames=("eb", "nbins", "impl", "interpret"))
def dualquant_blocks(xb, eb: float, nbins: int, impl: str = "jax",
                     interpret: bool = True):
    if impl == "pallas":
        return kernel.dualquant_blocks_pallas(xb, eb, nbins,
                                              interpret=interpret)
    return ref.dualquant_blocks_ref(xb, eb, nbins)


@partial(jax.jit, static_argnames=("eb", "impl", "interpret"))
def reverse_blocks(delta, eb: float, impl: str = "jax", interpret: bool = True):
    if impl == "pallas":
        return kernel.reverse_blocks_pallas(delta, eb, interpret=interpret)
    return ref.reverse_blocks_ref(delta, eb)
