"""Jit'd public wrappers for the Lorenzo dual-quant kernels, registered
with the dispatch layer.

With `impl=None` the ambient `KernelPolicy` (context > $REPRO_KERNEL_IMPL
> auto) decides; an explicit `impl` always wins.  Resolution happens
outside the jit boundary so the concrete choice is part of the cache key.

impl='jax'    -> pure-jnp oracle (XLA; works on any backend, used in the
                 multi-pod dry-run where the TPU Pallas lowering is
                 unavailable on the CPU host platform)
impl='pallas' -> Pallas kernel (interpret=True on CPU for validation,
                 compiled on real TPUs)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .. import dispatch
from . import kernel, ref

DUALQUANT = dispatch.register("lorenzo.dualquant", impls=("jax", "pallas"))
REVERSE = dispatch.register("lorenzo.reverse", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("eb", "nbins", "impl", "interpret"))
def _dualquant_jit(xb, eb: float, nbins: int, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.dualquant_blocks_pallas(xb, eb, nbins,
                                              interpret=interpret)
    return ref.dualquant_blocks_ref(xb, eb, nbins)


def dualquant_blocks(xb, eb: float, nbins: int, impl: Optional[str] = None,
                     interpret: Optional[bool] = None):
    """Fused PREQUANT + ℓ-delta + POSTQUANT on blocked input.
    Returns (codes, delta), both int32 shaped like xb."""
    r = dispatch.resolve(DUALQUANT, impl, interpret)
    return _dualquant_jit(xb, eb, nbins, r.impl, r.interpret)


@partial(jax.jit, static_argnames=("eb", "impl", "interpret"))
def _reverse_jit(delta, eb: float, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.reverse_blocks_pallas(delta, eb, interpret=interpret)
    return ref.reverse_blocks_ref(delta, eb)


def reverse_blocks(delta, eb: float, impl: Optional[str] = None,
                   interpret: Optional[bool] = None):
    """Per-block cumsum inverse + dequant.  Returns blocked float32."""
    r = dispatch.resolve(REVERSE, impl, interpret)
    return _reverse_jit(delta, eb, r.impl, r.interpret)
