"""Pallas TPU kernel: fused PREQUANT + Lorenzo delta + POSTQUANT.

Tiling insight (DESIGN.md §2): cuSZ's prediction is *block-independent*
(zero padding layer at every block boundary, paper §3.1.1), so the Pallas
tile IS the cuSZ block — the BlockSpec decomposition needs no halo, and
the grid is embarrassingly parallel exactly like the paper's CUDA blocks.

One HBM->VMEM read of the f32 tile produces both int32 outputs in a single
fused pass (the paper's motivation: the stage is memory-bound, so fusing
prequant/predict/postquant maximizes bandwidth utilization).  Tiles default
to lane-aligned shapes ((8,128) multiples for f32/int32).

The reverse kernel computes the in-block N-D inclusive prefix sum (the
cumsum inverse) + dequant, also one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift1(x, axis):
    """In-tile shift-by-one with zero fill (the padding layer)."""
    zshape = list(x.shape)
    zshape[axis] = 1
    z = jnp.zeros(zshape, x.dtype)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, x.shape[axis] - 1)
    return jnp.concatenate([z, x[tuple(sl)]], axis=axis)


def _dualquant_kernel(nd, nbins, eb, x_ref, codes_ref, delta_ref):
    x = x_ref[...]
    dq = jnp.rint(x / (2.0 * eb)).astype(jnp.int32)           # PREQUANT
    # (same division form as the oracle: reciprocal-multiply would flip
    # rint ties and break bit-equality with ref.py)
    delta = dq
    for ax in range(x.ndim - nd, x.ndim):                     # ℓ-delta
        delta = delta - _shift1(delta, ax)
    radius = nbins // 2                                       # POSTQUANT
    in_cap = (delta > -radius) & (delta < radius)
    codes_ref[...] = jnp.where(in_cap, delta + radius, 0).astype(jnp.int32)
    delta_ref[...] = delta


def _reverse_kernel(nd, eb, delta_ref, out_ref):
    d = delta_ref[...]
    for ax in range(d.ndim - nd, d.ndim):                     # cumsum inverse
        d = jnp.cumsum(d, axis=ax, dtype=jnp.int32)
    out_ref[...] = d.astype(jnp.float32) * (2.0 * eb)


def _grid_and_specs(xb_shape, nd, blocks_per_tile):
    """Grid over leading block axes; each tile carries `blocks_per_tile`
    blocks on the first block axis to keep VMEM tiles lane/sublane aligned
    even for small paper blocks (e.g. 8x8x8)."""
    nblk = xb_shape[:len(xb_shape) - nd]
    blk = xb_shape[len(xb_shape) - nd:]
    flat = 1
    for b in nblk:
        flat *= b
    bpt = min(blocks_per_tile, flat)
    while flat % bpt:
        bpt -= 1
    grid = (flat // bpt,)
    tile = (bpt,) + blk
    def idx(i):
        return (i,) + (0,) * nd
    spec = pl.BlockSpec((bpt,) + blk, idx)
    return grid, tile, spec, (flat,) + blk


def dualquant_blocks_pallas(xb: jax.Array, eb: float, nbins: int,
                            blocks_per_tile: int = 64,
                            interpret: bool = True):
    """xb: [nb..., b...] float32 blocked input (block axes last nd)."""
    nd = xb.ndim // 2
    grid, tile, spec, flat_shape = _grid_and_specs(xb.shape, nd, blocks_per_tile)
    xf = xb.reshape(flat_shape)
    kern = functools.partial(_dualquant_kernel, nd, nbins, eb)
    codes, delta = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(flat_shape, jnp.int32),
                   jax.ShapeDtypeStruct(flat_shape, jnp.int32)],
        interpret=interpret,
    )(xf)
    return codes.reshape(xb.shape), delta.reshape(xb.shape)


def reverse_blocks_pallas(delta: jax.Array, eb: float,
                          blocks_per_tile: int = 64,
                          interpret: bool = True) -> jax.Array:
    nd = delta.ndim // 2
    grid, tile, spec, flat_shape = _grid_and_specs(delta.shape, nd, blocks_per_tile)
    df = delta.reshape(flat_shape)
    kern = functools.partial(_reverse_kernel, nd, eb)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(flat_shape, jnp.float32),
        interpret=interpret,
    )(df)
    return out.reshape(delta.shape)
