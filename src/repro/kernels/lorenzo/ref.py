"""Pure-jnp oracle for the fused dual-quant Lorenzo kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dualquant as dq


def dualquant_blocks_ref(xb: jax.Array, eb: float, nbins: int):
    """xb: [..., b1(, b2(, b3))] float32 blocks (block axes last `nd`).

    Returns (codes int32, delta int32) with code 0 reserved for outliers.
    This is PREQUANT + ℓ-delta + POSTQUANT, exactly core/dualquant.
    """
    nd = xb.ndim // 2
    dqv = dq.prequant(xb, eb)
    delta = dq.lorenzo_delta(dqv, axes=range(xb.ndim - nd, xb.ndim))
    codes, _ = dq.postquant_codes(delta, nbins)
    return codes, delta


def reverse_blocks_ref(delta: jax.Array, eb: float):
    """Inverse: per-block cumsum + dequant.  delta: blocked int32."""
    nd = delta.ndim // 2
    dqv = dq.lorenzo_reconstruct(delta, axes=range(delta.ndim - nd, delta.ndim))
    return dq.dequant(dqv, eb)
