"""Pallas TPU kernel: Huffman encode (codebook gather, cuSZ §3.2.4).

The paper calls this stage "basically memory copy": every symbol gathers
its (codeword, bitwidth) pair from the codebook.  TPUs have no fast
VMEM gather with per-lane dynamic indices; the TPU-native formulation is
the same ONE-HOT CONTRACTION as the histogram kernel, run the other way:
a [T, K] one-hot of the tile's codes against a K iota, contracted on the
MXU with the [K, 2] table of (codeword-bits, bitwidth).  One matmul per
tile yields both outputs; int32 accumulation keeps full 32-bit codewords
exact (one selected row per symbol — no sums that could overflow).

Codewords are bitcast u32<->i32 across the MXU (two's-complement bit
patterns survive addition-free selection unchanged), matching the
bit-identical trick in the deflate kernel.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(nbins, codes_ref, table_ref, out_ref):
    codes = codes_ref[...].reshape(-1)                        # [T]
    iota = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], nbins), 1)
    onehot = (codes[:, None] == iota).astype(jnp.int32)       # [T, K]
    out_ref[...] = jax.lax.dot_general(
        onehot, table_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                     # [T, 2]


def encode_pallas(codes: jax.Array, cb, tile: int = 512,
                  interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """codes: int32 quant codes (any shape); cb: huffman.Codebook.
    Returns (codewords uint32 [n], bitwidths int32 [n]) flat, matching
    core/huffman.encode bit-for-bit."""
    flat = codes.reshape(-1).astype(jnp.int32)
    nbins = cb.codes.shape[0]
    n = flat.shape[0]
    npad = -(-n // tile) * tile - n
    # pad with an out-of-range symbol: its one-hot row is all-zero, so the
    # padded tail encodes to (0 bits, 0 width) and is cropped below
    flat = jnp.pad(flat, (0, npad), constant_values=nbins)
    nt = flat.shape[0] // tile
    table = jnp.stack([jax.lax.bitcast_convert_type(cb.codes, jnp.int32),
                       cb.lengths.astype(jnp.int32)], axis=1)  # [K, 2]
    out = pl.pallas_call(
        functools.partial(_encode_kernel, nbins),
        grid=(nt,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((nbins, 2), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((tile, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * tile, 2), jnp.int32),
        interpret=interpret,
    )(flat, table)
    cw = jax.lax.bitcast_convert_type(out[:n, 0], jnp.uint32)
    bw = out[:n, 1]
    return cw, bw
