"""Jit'd public wrapper for the encode kernel; dispatch-registered."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .. import dispatch
from . import kernel, ref

KERNEL = dispatch.register("encode", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("impl", "interpret"))
def _encode_jit(codes, cb, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.encode_pallas(codes, cb, interpret=interpret)
    return ref.encode_ref(codes, cb)


def encode(codes, cb, impl: Optional[str] = None,
           interpret: Optional[bool] = None):
    r = dispatch.resolve(KERNEL, impl, interpret)
    return _encode_jit(codes, cb, r.impl, r.interpret)
