"""Pure-jnp oracle for the encode kernel (= core/huffman.encode)."""
import jax

from repro.core import huffman as hf


def encode_ref(codes: jax.Array, cb):
    return hf.encode(codes, cb)
