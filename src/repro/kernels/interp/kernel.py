"""Pallas kernel: blocked cubic interpolation predict/reconstruct.

The level step is embarrassingly parallel over rows (each row is an
independent 1D line through the field along the working axis), so the
grid tiles the row axis and each tile computes its residuals (encode) or
odd samples (decode) from four statically-offset slices of the padded
even rows — no halo exchange, the ops layer bakes the 3-sample edge
padding into the input.  One VMEM read of the (rows, me+3) tile produces
the (rows, mo) output in a single fused pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ROW_TILE = 8


def _predict_tile(pe, mo: int):
    a = pe[:, 0:mo]
    b = pe[:, 1:1 + mo]
    c = pe[:, 2:2 + mo]
    d = pe[:, 3:3 + mo]
    return (9 * (b + c) - a - d + 8) >> 4


def _residual_kernel(mo, pe_ref, odd_ref, out_ref):
    out_ref[...] = odd_ref[...] - _predict_tile(pe_ref[...], mo)


def _odd_kernel(mo, pe_ref, res_ref, out_ref):
    out_ref[...] = res_ref[...] + _predict_tile(pe_ref[...], mo)


def _run(kern_fn, pe: jax.Array, other: jax.Array,
         interpret: bool) -> jax.Array:
    rows, mo = other.shape
    mp = pe.shape[1]
    tile = min(_ROW_TILE, max(1, rows))
    pad = (-rows) % tile
    if pad:
        pe = jnp.concatenate([pe, jnp.zeros((pad, mp), pe.dtype)], axis=0)
        other = jnp.concatenate(
            [other, jnp.zeros((pad, mo), other.dtype)], axis=0)
    grid = ((rows + pad) // tile,)
    kern = functools.partial(kern_fn, mo)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, mp), lambda i: (i, 0)),
                  pl.BlockSpec((tile, mo), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, mo), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, mo), jnp.int32),
        interpret=interpret,
    )(pe, other)
    return out[:rows]


def residual_rows_pallas(pe: jax.Array, odd: jax.Array,
                         interpret: bool = True) -> jax.Array:
    return _run(_residual_kernel, pe, odd, interpret)


def odd_rows_pallas(pe: jax.Array, resid: jax.Array,
                    interpret: bool = True) -> jax.Array:
    return _run(_odd_kernel, pe, resid, interpret)
