"""Jit'd public wrappers for the interpolation-level kernels, registered
with the dispatch layer (same contract as kernels/lorenzo/ops.py:
resolution happens outside the jit boundary, an explicit `impl` wins
over the ambient policy).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .. import dispatch
from . import kernel, ref

PREDICT = dispatch.register("interp.predict", impls=("jax", "pallas"))
RECONSTRUCT = dispatch.register("interp.reconstruct", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("impl", "interpret"))
def _residual_jit(pe, odd, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.residual_rows_pallas(pe, odd, interpret=interpret)
    return ref.residual_rows_ref(pe, odd)


def residual_rows(pe, odd, impl: Optional[str] = None,
                  interpret: Optional[bool] = None):
    """Encode direction of one interpolation level: residual = odd − p(even).
    `pe` is the padded even rows [R, me+3], `odd` the odd rows [R, mo]."""
    r = dispatch.resolve(PREDICT, impl, interpret)
    return _residual_jit(pe, odd, r.impl, r.interpret)


@partial(jax.jit, static_argnames=("impl", "interpret"))
def _odd_jit(pe, resid, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.odd_rows_pallas(pe, resid, interpret=interpret)
    return ref.odd_rows_ref(pe, resid)


def odd_rows(pe, resid, impl: Optional[str] = None,
             interpret: Optional[bool] = None):
    """Decode direction: odd = residual + p(even)."""
    r = dispatch.resolve(RECONSTRUCT, impl, interpret)
    return _odd_jit(pe, resid, r.impl, r.interpret)
