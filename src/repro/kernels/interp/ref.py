"""XLA reference implementation of the per-level cubic interpolation step.

One interpolation level along one axis, collapsed to 2D rows (the ops
layer moves the working axis last and flattens the rest):

  pe   [R, me+3] int32  even-sample rows, edge-replicate padded with one
                        sample left and two right (so every odd position
                        sees four even neighbors with static offsets)
  odd  [R, mo]   int32  the odd samples (encode) / their residuals (decode)

The predictor for odd position i is the integer cubic (Catmull-Rom style)
stencil over even neighbors  p = (9·(b+c) − a − d + 8) >> 4  with
a..d = pe[i .. i+3].  All arithmetic is exact int32 (prequant magnitudes
are < 2^23, so 9·(b+c) stays far from overflow) and the arithmetic right
shift is floor division on both sides, so encode/decode are exact
inverses — the scheme is lossless on the prequantized integers.
"""
from __future__ import annotations

import jax


def _predict(pe: jax.Array, mo: int) -> jax.Array:
    a = pe[:, 0:mo]
    b = pe[:, 1:1 + mo]
    c = pe[:, 2:2 + mo]
    d = pe[:, 3:3 + mo]
    return (9 * (b + c) - a - d + 8) >> 4


def residual_rows_ref(pe: jax.Array, odd: jax.Array) -> jax.Array:
    """Encode direction: residual = odd − prediction(even)."""
    return odd - _predict(pe, odd.shape[1])


def odd_rows_ref(pe: jax.Array, resid: jax.Array) -> jax.Array:
    """Decode direction: odd = residual + prediction(even)."""
    return resid + _predict(pe, resid.shape[1])
