"""Multi-level interpolation predictor kernels (cuSZ-i, arXiv 2312.05492)."""
