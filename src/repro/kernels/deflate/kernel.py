"""Pallas TPU kernel: Huffman deflate (bitstream concatenation, cuSZ §3.2.4).

The CUDA version packs each chunk sequentially in one thread (atomic ORs).
TPU-native formulation, one chunk per grid step, all vectorized:

  1. in-tile exclusive cumsum of bitwidths -> per-symbol bit offsets;
  2. each codeword splits into <=2 disjoint u32 fragments (hi at word w,
     lo at word w+1);
  3. fragments land via TWO ONE-HOT CONTRACTIONS over the word index
     (add == OR for disjoint bits; int32 two's-complement addition of
     disjoint-bit patterns is exact) — the same MXU trick as the
     histogram kernel, replacing atomics.

Alongside the packed words the kernel samples the already-computed
exclusive prefix sums at every `sub_size`-th symbol, emitting the gap
arrays (bit offset + valid-symbol offset per subchunk boundary) that the
gap-array inflate kernel decodes from in parallel — the phase-1 half of
Rivera et al. (arXiv 2201.09118), essentially free at encode time.

VMEM: tile of C=512 symbols -> one-hot [C, C] i32 = 1 MB; fits easily.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _deflate_kernel(chunk, sub, cw_ref, bw_ref, words_ref, bits_ref,
                    gbits_ref, gsyms_ref):
    cw = cw_ref[...].reshape(-1).astype(jnp.uint32)          # [C]
    bw = bw_ref[...].reshape(-1).astype(jnp.int32)           # [C]
    offs = jnp.cumsum(bw) - bw                               # exclusive
    bits_ref[...] = (offs[-1] + bw[-1]).reshape(bits_ref.shape)

    # gap arrays: bit / valid-symbol offsets sampled at every sub-th symbol
    n_sub = chunk // sub
    gbits_ref[...] = offs.reshape(n_sub, sub)[:, 0].reshape(gbits_ref.shape)
    valid = (bw > 0).astype(jnp.int32)
    vcnt = jnp.cumsum(valid) - valid                         # exclusive
    gsyms_ref[...] = vcnt.reshape(n_sub, sub)[:, 0].reshape(gsyms_ref.shape)

    w = (offs >> 5).astype(jnp.int32)
    b = (offs & 31).astype(jnp.int32)
    sh = 32 - b - bw
    hi = jnp.where(sh >= 0,
                   cw << jnp.clip(sh, 0, 31).astype(jnp.uint32),
                   cw >> jnp.clip(-sh, 0, 31).astype(jnp.uint32))
    lo = jnp.where(sh < 0, cw << jnp.clip(32 + sh, 0, 31).astype(jnp.uint32),
                   jnp.uint32(0))
    valid = bw > 0
    hi = jnp.where(valid, hi, 0).astype(jnp.int32)           # bit-identical
    lo = jnp.where(valid, lo, 0).astype(jnp.int32)

    iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)  # [C, W]
    oh_hi = (w[:, None] == iota).astype(jnp.int32)
    oh_lo = ((w + 1)[:, None] == iota).astype(jnp.int32)
    packed = jax.lax.dot_general(hi[None, :], oh_hi,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32) \
        + jax.lax.dot_general(lo[None, :], oh_lo,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)    # [1, W]
    words_ref[...] = packed.astype(jnp.uint32).reshape(words_ref.shape)


def deflate_pallas(cw: jax.Array, bw: jax.Array, chunk_size: int = 512,
                   sub_size: int = 128, interpret: bool = True):
    n = cw.shape[0]
    nc = -(-n // chunk_size)
    pad = nc * chunk_size - n
    n_sub = chunk_size // sub_size
    cwp = jnp.pad(cw.astype(jnp.uint32), (0, pad)).reshape(nc, chunk_size)
    bwp = jnp.pad(bw.astype(jnp.int32), (0, pad)).reshape(nc, chunk_size)
    words, bits, gbits, gsyms = pl.pallas_call(
        functools.partial(_deflate_kernel, chunk_size, sub_size),
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, chunk_size), lambda i: (i, 0)),
                  pl.BlockSpec((1, chunk_size), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, chunk_size), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0)),
                   pl.BlockSpec((1, n_sub), lambda i: (i, 0)),
                   pl.BlockSpec((1, n_sub), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nc, chunk_size), jnp.uint32),
                   jax.ShapeDtypeStruct((nc, 1), jnp.int32),
                   jax.ShapeDtypeStruct((nc, n_sub), jnp.int32),
                   jax.ShapeDtypeStruct((nc, n_sub), jnp.int32)],
        interpret=interpret,
    )(cwp, bwp)
    return words, bits[:, 0], gbits, gsyms
