"""Pure-jnp oracle for the deflate kernel (= core/huffman.deflate)."""
import jax

from repro.core import huffman as hf


def deflate_ref(cw: jax.Array, bw: jax.Array, chunk_size: int,
                sub_size: int = hf.SUBCHUNK):
    return hf.deflate(cw, bw, chunk_size, sub_size)
