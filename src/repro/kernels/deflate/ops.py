"""Jit'd public wrapper for the deflate kernel; dispatch-registered."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .. import dispatch
from . import kernel, ref

KERNEL = dispatch.register("deflate", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("chunk_size", "impl", "interpret"))
def _deflate_jit(cw, bw, chunk_size: int, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.deflate_pallas(cw, bw, chunk_size, interpret=interpret)
    return ref.deflate_ref(cw, bw, chunk_size)


def deflate(cw, bw, chunk_size: int = 512, impl: Optional[str] = None,
            interpret: Optional[bool] = None):
    r = dispatch.resolve(KERNEL, impl, interpret)
    return _deflate_jit(cw, bw, chunk_size, r.impl, r.interpret)
