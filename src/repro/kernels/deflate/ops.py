"""Jit'd public wrapper for the deflate kernel; dispatch-registered.

Returns `(words, bits_used, gap_bits, gap_syms)`: alongside the packed
bitstream, deflate samples its exclusive prefix-sum of bitwidths at every
`sub_size`-symbol boundary (the gap array of Rivera et al., arXiv
2201.09118) so the inflate side can decode subchunks in parallel.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.core import huffman as hf

from .. import dispatch
from . import kernel, ref

KERNEL = dispatch.register("deflate", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("chunk_size", "sub_size", "impl",
                                   "interpret"))
def _deflate_jit(cw, bw, chunk_size: int, sub_size: int, impl: str,
                 interpret: bool):
    if impl == "pallas":
        return kernel.deflate_pallas(cw, bw, chunk_size, sub_size,
                                     interpret=interpret)
    return ref.deflate_ref(cw, bw, chunk_size, sub_size)


def deflate(cw, bw, chunk_size: int = 512, sub_size: int = hf.SUBCHUNK,
            impl: Optional[str] = None, interpret: Optional[bool] = None):
    r = dispatch.resolve(KERNEL, impl, interpret)
    return _deflate_jit(cw, bw, chunk_size,
                        hf.norm_sub_size(chunk_size, sub_size),
                        r.impl, r.interpret)
