"""Jit'd public wrapper for the deflate kernel."""
from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


@partial(jax.jit, static_argnames=("chunk_size", "impl", "interpret"))
def deflate(cw, bw, chunk_size: int = 512, impl: str = "jax",
            interpret: bool = True):
    if impl == "pallas":
        return kernel.deflate_pallas(cw, bw, chunk_size, interpret=interpret)
    return ref.deflate_ref(cw, bw, chunk_size)
