"""Backend-aware kernel dispatch: one policy decides, per pipeline stage,
whether the Pallas kernel or the XLA reference implementation runs.

Every cuSZ hot-path stage registers here (`register`) with the impls it
supports; callers resolve a concrete `(impl, interpret)` pair *outside*
any jit trace so the choice is part of the jit cache key, never a stale
thread-local baked into a compiled function.

Policy values:
  "auto"             compiled Pallas on tpu/gpu backends, XLA reference
                     on cpu (the safe production default)
  "jax"              force the XLA reference impl everywhere
  "pallas"           force the Pallas kernel (interpret mode on cpu,
                     where the TPU lowering is unavailable)
  "pallas-interpret" force the Pallas kernel in interpret mode on any
                     backend (CI / parity validation)

Resolution order (most specific wins):
  1. explicit per-call ``impl=`` argument (the ops-layer escape hatch —
     benchmarks use it for the impl axis, so the overrides below never
     silently flip a measurement that names its impl)
  2. an active ``KernelPolicy`` context (``kernel_policy(...)``)
  3. the ``REPRO_KERNEL_IMPL`` environment variable (process-level
     override for benchmarking and CI)
  4. the caller's configured default (``CompressorConfig.kernel_impl``,
     threaded through ``pipeline_policy``)
  5. "auto"

A stage registered without a Pallas impl declares itself jax-only with
a reason.  Ambient policies ("auto", env var, `kernel_policy(...)`,
config defaults) still resolve such a stage to its jax impl so a forced
policy never crashes mid-pipeline — but an *explicit* per-call
``impl="pallas"`` request raises `NotImplementedError` carrying the
declared reason instead of silently measuring the reference path.
(Every pipeline stage currently registers a Pallas impl — `inflate`,
the last holdout, gained one with the gap-array two-phase decode — but
the jax-only protocol remains for future stages.)
"""
from __future__ import annotations

import dataclasses
import os
import threading
from contextlib import contextmanager
from typing import Dict, Iterator, Mapping, Optional, Tuple

import jax

ENV_VAR = "REPRO_KERNEL_IMPL"
IMPL_CHOICES = ("auto", "jax", "pallas", "pallas-interpret")
# backends with a compiled Pallas lowering
_PALLAS_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def _validate(impl: str) -> str:
    if impl not in IMPL_CHOICES:
        raise ValueError(f"unknown kernel impl {impl!r}; expected one of "
                         f"{IMPL_CHOICES}")
    return impl


@dataclasses.dataclass(frozen=True)
class Resolved:
    """A concrete dispatch decision, safe to use as a jit static arg."""
    impl: str            # "jax" | "pallas"
    interpret: bool      # Pallas interpret mode (cpu validation path)

    def as_kwargs(self) -> dict:
        return {"impl": self.impl, "interpret": self.interpret}


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Process/scope-level impl choice with optional per-kernel overrides.

    `overrides` maps a kernel name ("histogram") or name prefix
    ("lorenzo" covers "lorenzo.dualquant" and "lorenzo.reverse") to an
    impl choice; stored as a sorted tuple so the policy stays hashable.
    """
    impl: str = "auto"
    overrides: Tuple[Tuple[str, str], ...] = ()

    @staticmethod
    def make(impl: str = "auto",
             overrides: Optional[Mapping[str, str]] = None) -> "KernelPolicy":
        _validate(impl)
        items = tuple(sorted((overrides or {}).items()))
        for _, v in items:
            _validate(v)
        return KernelPolicy(impl, items)

    def impl_for(self, kernel: str) -> str:
        ov = dict(self.overrides)
        if kernel in ov:
            return ov[kernel]
        head = kernel.split(".", 1)[0]
        if head in ov:
            return ov[head]
        return self.impl


# ---------------------------------------------------------------------------
# Registry: kernel name -> supported impls.  Ops modules register at import.
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Tuple[str, ...]] = {}
# capability note for kernels registered without a pallas impl: why the
# pallas path does not exist (surfaced in the explicit-request error)
_JAX_ONLY_REASON: Dict[str, str] = {}


def register(kernel: str, impls: Tuple[str, ...] = ("jax", "pallas"),
             jax_only_reason: Optional[str] = None) -> str:
    for i in impls:
        if i not in ("jax", "pallas"):
            raise ValueError(f"registry impls must be concrete, got {i!r}")
    if jax_only_reason is not None and "pallas" in impls:
        raise ValueError(f"kernel {kernel!r} registers a pallas impl but "
                         "also passes jax_only_reason")
    _REGISTRY[kernel] = tuple(impls)
    if jax_only_reason is not None:
        _JAX_ONLY_REASON[kernel] = jax_only_reason
    return kernel


def registered() -> Dict[str, Tuple[str, ...]]:
    return dict(_REGISTRY)


def jax_only_reason(kernel: str) -> Optional[str]:
    """Why `kernel` has no pallas impl, if it declared one."""
    return _JAX_ONLY_REASON.get(kernel)


# ---------------------------------------------------------------------------
# Ambient policy: context stack (thread-local) > environment variable.
# ---------------------------------------------------------------------------

_tls = threading.local()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


@contextmanager
def use_policy(policy: KernelPolicy) -> Iterator[KernelPolicy]:
    st = _stack()
    st.append(policy)
    try:
        yield policy
    finally:
        st.pop()


def kernel_policy(impl: str = "auto",
                  overrides: Optional[Mapping[str, str]] = None):
    """Scoped policy override::

        with kernel_policy("pallas-interpret"):
            blob, eb = compress(x, cfg)        # every stage forced
    """
    return use_policy(KernelPolicy.make(impl, overrides))


def current_policy() -> Optional[KernelPolicy]:
    """Active context policy, else the env-var policy, else None."""
    st = _stack()
    if st:
        return st[-1]
    env = os.environ.get(ENV_VAR, "").strip()
    if env:
        return KernelPolicy.make(_validate(env))
    return None


def ambient_impl(kernel: Optional[str] = None) -> Optional[str]:
    pol = current_policy()
    if pol is None:
        return None
    return pol.impl_for(kernel) if kernel is not None else pol.impl


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def resolve(kernel: str, impl: Optional[str] = None,
            interpret: Optional[bool] = None, *,
            explicit: Optional[bool] = None) -> Resolved:
    """Resolve a kernel name (+ optional explicit request) to a concrete
    (impl, interpret) pair.  Call OUTSIDE jit so the result is static.

    `explicit` marks whether `impl` is a direct per-call request (the
    default when `impl` is given) or a forwarded ambient/config value
    (`pipeline_policy` passes False).  An explicit pallas request on a
    jax-only kernel raises instead of silently falling back.
    """
    if kernel not in _REGISTRY:
        raise KeyError(f"kernel {kernel!r} not registered; known: "
                       f"{sorted(_REGISTRY)}")
    supported = _REGISTRY[kernel]
    if explicit is None:
        explicit = impl is not None
    if impl is None:
        impl = ambient_impl(kernel) or "auto"
    _validate(impl)
    if impl == "pallas-interpret":
        impl = "pallas"
        interpret = True if interpret is None else interpret
    backend = jax.default_backend()
    if impl == "auto":
        impl = ("pallas" if "pallas" in supported
                and backend in _PALLAS_BACKENDS else "jax")
    if impl == "pallas" and "pallas" not in supported:
        if explicit:
            reason = _JAX_ONLY_REASON.get(kernel, "no pallas impl registered")
            raise NotImplementedError(
                f"kernel {kernel!r} has no pallas implementation "
                f"({reason}); pass impl='jax' (or drop the impl argument "
                "to use the ambient policy, which falls back to jax)")
        impl = "jax"                       # documented fallback (see module doc)
    if impl == "jax":
        return Resolved("jax", False)
    if interpret is None:
        interpret = backend not in _PALLAS_BACKENDS
    return Resolved("pallas", bool(interpret))


# ---------------------------------------------------------------------------
# Whole-pipeline policy: the compressor resolves every stage once, outside
# jit, and passes the frozen result as a static argument.
#
# PIPELINE_STAGES lists every kernel a registered predictor/encoder stage
# (core.stages) may dispatch.  `pipeline_policy` resolves whichever of
# them are registered at call time (stage kernels register when their
# stage module imports), so a policy built before an optional stage
# loads never KeyErrors — the stage itself cannot run either way.
# ---------------------------------------------------------------------------

PIPELINE_STAGES = ("lorenzo.dualquant", "lorenzo.reverse", "histogram",
                   "encode", "deflate", "inflate",
                   "interp.predict", "interp.reconstruct",
                   "bitshuffle.encode", "bitshuffle.decode")

# legacy attribute names kept for the original six-stage cusz pipeline
# (tests/benchmarks address e.g. `pp.dualquant` directly)
_LEGACY_FIELDS = {
    "dualquant": "lorenzo.dualquant",
    "reverse": "lorenzo.reverse",
    "histogram": "histogram",
    "encode": "encode",
    "deflate": "deflate",
    "inflate": "inflate",
}


@dataclasses.dataclass(frozen=True)
class PipelinePolicy:
    """Frozen per-kernel dispatch decisions, safe as a jit static arg.

    Generic over the stage set: `entries` maps kernel name -> Resolved
    for every registered PIPELINE_STAGES kernel; `for_kernel` is the
    lookup stage implementations use.  The six original cusz stages
    remain addressable as attributes (`pp.dualquant`, `pp.inflate`, ...).
    """
    entries: Tuple[Tuple[str, "Resolved"], ...] = ()

    def for_kernel(self, kernel: str) -> Resolved:
        for name, r in self.entries:
            if name == kernel:
                return r
        raise KeyError(
            f"pipeline policy has no resolution for kernel {kernel!r} "
            f"(resolved: {[n for n, _ in self.entries]}); was the stage's "
            "ops module imported before pipeline_policy()?")

    def __getattr__(self, name: str) -> Resolved:
        kernel = _LEGACY_FIELDS.get(name)
        if kernel is None:
            raise AttributeError(name)
        return self.for_kernel(kernel)


def pipeline_policy(default_impl: Optional[str] = None) -> PipelinePolicy:
    """Resolve all pipeline stages under the ambient policy, falling back
    to `default_impl` (e.g. CompressorConfig.kernel_impl), then "auto"."""
    if default_impl is not None:
        _validate(default_impl)

    def r(kernel: str) -> Resolved:
        impl = ambient_impl(kernel)
        if impl is None:
            impl = default_impl
        # ambient/config impls are forwarded, not per-call requests: a
        # forced "pallas" policy must not crash the jax-only stages
        return resolve(kernel, impl, explicit=False)

    return PipelinePolicy(entries=tuple(
        (k, r(k)) for k in PIPELINE_STAGES if k in _REGISTRY))
