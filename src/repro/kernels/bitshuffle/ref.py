"""XLA reference implementation of the fused bit-plane shuffle.

Encode maps each quant code to its zigzag distance from the bin radius
(so near-prediction codes become small unsigned values whose high bit
planes are all zero — the OUTLIER sentinel 0 lands on the max value
nbins−1 and simply keeps its chunk's planes nonzero), then transposes
each chunk into P = bitlength(nbins−1) bit planes of chunk/32 uint32
words:

  planes[c, p, w] bit l  =  bit p of zigzag(codes[c, 32·w + l])

A plane whose words are all zero carries no information; the host-side
pack elides it (zero-plane elision), which is where the compression
comes from.  Decode is the exact bitwise inverse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def nplanes(nbins: int) -> int:
    """Bit planes needed for the zigzag code domain [0, nbins)."""
    return max(1, int(nbins - 1).bit_length())


def encode_planes_ref(codes2: jax.Array, nbins: int) -> jax.Array:
    """[nc, chunk] int32 codes in [0, nbins) -> [nc, P, chunk/32] uint32."""
    nc, chunk = codes2.shape
    p_count = nplanes(nbins)
    d = codes2 - nbins // 2
    v = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)       # zigzag >= 0
    vw = v.reshape(nc, chunk // 32, 32)
    planes = (vw[:, None, :, :] >>
              jnp.arange(p_count, dtype=jnp.uint32)[None, :, None, None]) & 1
    lane_w = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(planes * lane_w, axis=-1, dtype=jnp.uint32)


def decode_planes_ref(planes: jax.Array, nbins: int) -> jax.Array:
    """[nc, P, W] uint32 planes -> [nc, 32·W] int32 codes in [0, nbins)."""
    nc, p_count, w = planes.shape
    lanes = jnp.arange(32, dtype=jnp.uint32)
    bits = (planes[..., None] >> lanes) & 1             # [nc, P, W, 32]
    plane_w = jnp.uint32(1) << jnp.arange(p_count, dtype=jnp.uint32)
    v = jnp.sum(bits * plane_w[None, :, None, None], axis=1,
                dtype=jnp.uint32)                       # [nc, W, 32]
    vi = v.reshape(nc, w * 32).astype(jnp.int32)
    d = (vi >> 1) ^ -(vi & 1)                           # un-zigzag
    return d + nbins // 2
