"""Fused bit-plane shuffle kernels (FZ-GPU, arXiv 2304.12557)."""
