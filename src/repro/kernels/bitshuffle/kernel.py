"""Pallas kernel: fused zigzag quantize-map + bit-plane shuffle.

Chunks are independent, so the grid tiles the chunk axis and each
program transposes its chunk into bit planes in one fused VMEM pass
(zigzag + P masked shifts + lane reduction — the FZ-GPU fusion: no
materialized intermediate between the quantize map and the shuffle).
The static plane count P ≤ 16 keeps the in-kernel plane loop unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import nplanes


def _encode_kernel(nbins, p_count, x_ref, out_ref):
    x = x_ref[...]                                     # [1, chunk] int32
    d = x - nbins // 2
    v = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)      # zigzag
    w = x.shape[1] // 32
    vw = v.reshape(w, 32)
    lane_w = jnp.uint32(1) << jax.lax.broadcasted_iota(
        jnp.uint32, (w, 32), 1)
    for p in range(p_count):
        bits = (vw >> p) & jnp.uint32(1)
        out_ref[0, p, :] = jnp.sum(bits * lane_w, axis=1, dtype=jnp.uint32)


def _decode_kernel(nbins, p_count, planes_ref, out_ref):
    planes = planes_ref[...]                           # [1, P, W] uint32
    w = planes.shape[2]
    lanes = jax.lax.broadcasted_iota(jnp.uint32, (w, 32), 1)
    v = jnp.zeros((w, 32), jnp.uint32)
    for p in range(p_count):
        bits = (planes[0, p, :, None] >> lanes) & jnp.uint32(1)
        v = v | (bits << p)
    vi = v.reshape(1, w * 32).astype(jnp.int32)
    d = (vi >> 1) ^ -(vi & 1)                          # un-zigzag
    out_ref[...] = d + nbins // 2


def encode_planes_pallas(codes2: jax.Array, nbins: int,
                         interpret: bool = True) -> jax.Array:
    nc, chunk = codes2.shape
    p_count = nplanes(nbins)
    kern = functools.partial(_encode_kernel, nbins, p_count)
    return pl.pallas_call(
        kern,
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, chunk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, p_count, chunk // 32),
                               lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, p_count, chunk // 32),
                                       jnp.uint32),
        interpret=interpret,
    )(codes2)


def decode_planes_pallas(planes: jax.Array, nbins: int,
                         interpret: bool = True) -> jax.Array:
    nc, p_count, w = planes.shape
    kern = functools.partial(_decode_kernel, nbins, p_count)
    return pl.pallas_call(
        kern,
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, p_count, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 32 * w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, 32 * w), jnp.int32),
        interpret=interpret,
    )(planes)
