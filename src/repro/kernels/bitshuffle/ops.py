"""Jit'd public wrappers for the bit-plane shuffle kernels, registered
with the dispatch layer (same contract as kernels/lorenzo/ops.py)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .. import dispatch
from . import kernel, ref
from .ref import nplanes  # noqa: F401  (re-exported for stage/payload sizing)

ENCODE = dispatch.register("bitshuffle.encode", impls=("jax", "pallas"))
DECODE = dispatch.register("bitshuffle.decode", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("nbins", "impl", "interpret"))
def _encode_jit(codes2, nbins: int, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.encode_planes_pallas(codes2, nbins,
                                           interpret=interpret)
    return ref.encode_planes_ref(codes2, nbins)


def encode_planes(codes2, nbins: int, impl: Optional[str] = None,
                  interpret: Optional[bool] = None):
    """Fused zigzag + bitshuffle: [nc, chunk] codes -> [nc, P, W] planes."""
    r = dispatch.resolve(ENCODE, impl, interpret)
    return _encode_jit(codes2, nbins, r.impl, r.interpret)


@partial(jax.jit, static_argnames=("nbins", "impl", "interpret"))
def _decode_jit(planes, nbins: int, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.decode_planes_pallas(planes, nbins,
                                           interpret=interpret)
    return ref.decode_planes_ref(planes, nbins)


def decode_planes(planes, nbins: int, impl: Optional[str] = None,
                  interpret: Optional[bool] = None):
    """Inverse bitshuffle: [nc, P, W] planes -> [nc, 32·W] codes."""
    r = dispatch.resolve(DECODE, impl, interpret)
    return _decode_jit(planes, nbins, r.impl, r.interpret)
