"""Pallas TPU kernel: histogram of quantization bins (cuSZ §3.2.1).

GPU cuSZ uses shared-memory replicated histograms with atomics
(Gomez-Luna et al.).  TPUs have no fast atomics; the TPU-native
formulation is a ONE-HOT CONTRACTION: each VMEM tile of codes becomes a
[T, K] one-hot (compare against a K iota) and is summed over T on the
MXU via a [1,T]x[T,K] matmul.  Tiles accumulate into the single output
block across grid steps (standard Pallas reduction: every grid index maps
to output block 0; step 0 initializes).

Conflict-free by construction — the replication/atomics machinery of the
CUDA version is unnecessary here (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(nbins, tile, codes_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...].reshape(-1)                       # [T]
    onehot = (codes[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, nbins), 1)
              ).astype(jnp.float32)                          # [T, K]
    ones = jnp.ones((1, codes.shape[0]), jnp.float32)
    part = jax.lax.dot_general(ones, onehot,
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [1, K]
    out_ref[...] += part.astype(jnp.int32)


def histogram_pallas(codes: jax.Array, nbins: int, tile: int = 2048,
                     interpret: bool = True) -> jax.Array:
    flat = codes.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    npad = -(-n // tile) * tile - n
    # pad with an out-of-range bin id; one-hot rows become all-zero
    flat = jnp.pad(flat, (0, npad), constant_values=nbins)
    nt = flat.shape[0] // tile
    out = pl.pallas_call(
        functools.partial(_hist_kernel, nbins, tile),
        grid=(nt,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, nbins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, nbins), jnp.int32),
        interpret=interpret,
    )(flat)
    return out[0]
