"""Jit'd public wrapper for the histogram kernel."""
from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


@partial(jax.jit, static_argnames=("nbins", "impl", "interpret"))
def histogram(codes, nbins: int, impl: str = "jax", interpret: bool = True):
    if impl == "pallas":
        return kernel.histogram_pallas(codes, nbins, interpret=interpret)
    return ref.histogram_ref(codes, nbins)
