"""Jit'd public wrapper for the histogram kernel; dispatch-registered."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .. import dispatch
from . import kernel, ref

KERNEL = dispatch.register("histogram", impls=("jax", "pallas"))


@partial(jax.jit, static_argnames=("nbins", "impl", "interpret"))
def _histogram_jit(codes, nbins: int, impl: str, interpret: bool):
    if impl == "pallas":
        return kernel.histogram_pallas(codes, nbins, interpret=interpret)
    return ref.histogram_ref(codes, nbins)


def histogram(codes, nbins: int, impl: Optional[str] = None,
              interpret: Optional[bool] = None):
    r = dispatch.resolve(KERNEL, impl, interpret)
    return _histogram_jit(codes, nbins, r.impl, r.interpret)
