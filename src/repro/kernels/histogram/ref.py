"""Pure-jnp oracle for the histogram kernel."""
import jax
import jax.numpy as jnp


def histogram_ref(codes: jax.Array, nbins: int) -> jax.Array:
    return jnp.bincount(codes.reshape(-1), length=nbins).astype(jnp.int32)
