"""Train step: CE loss -> (microbatched) grads -> optional cuSZ-quantized
cross-pod gradient all-reduce -> AdamW.

Gradient compression layout (DESIGN.md §3): in compressed mode the batch
keeps an explicit leading pod axis [npods, B/npods, S] sharded P('pod',
'data', ...); per-pod grads come from `jax.vmap` over that axis, and the
narrow-int sum over it lowers to an int8/int16 all-reduce across the
slow inter-pod links.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gradient as G
from repro.core import weights as W
from repro.dist.context import (dp_axes_override, constrain_like_params,
                                current_mesh, use_weight_compress,
                                use_a2a_compress)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    grad_compress: str = "none"      # 'none' | 'int8' | 'int16'
    weight_compress: str = "none"    # 'none' | 'int8' (FSDP gather path)
    a2a_compress: str = "none"       # 'none' | 'int8' (MoE dispatch/combine)
    npods: int = 1
    accum_dtype: Any = jnp.float32   # bf16 for the 300B+ configs
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()


CE_CHUNK = 1024      # sequence positions per CE chunk


def loss_fn(params, cfg: ModelConfig, tokens, extra=None):
    """Chunked cross-entropy: the [B,S,V] logits are never materialized —
    each CE_CHUNK of positions projects + reduces inside a checkpointed
    scan step (vital when the vocab doesn't divide the TP axis, e.g.
    mamba2's 50280: replicated full logits cost 6 GiB/device on the
    dry-run).  The vocab-dim reduction uses the lse + one-hot contraction
    form (a vocab gather would force SPMD to replicate)."""
    hidden, _ = M.forward(params, cfg, tokens, extra, return_hidden=True)
    hidden = hidden[:, cfg.n_prepend_embeds:, :]
    head = M.lm_head_of(params, cfg).astype(hidden.dtype)
    B, S, D = hidden.shape
    x = hidden[:, :-1, :]
    tgt = tokens[:, 1:]
    n = S - 1
    nchunks = max(1, -(-n // CE_CHUNK))
    pad = nchunks * CE_CHUNK - n
    x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
    valid = jnp.pad(jnp.ones((B, n), jnp.float32), ((0, 0), (0, pad)))
    xc = x.reshape(B, nchunks, CE_CHUNK, D).swapaxes(0, 1)
    tc = tgt.reshape(B, nchunks, CE_CHUNK).swapaxes(0, 1)
    vc = valid.reshape(B, nchunks, CE_CHUNK).swapaxes(0, 1)

    def chunk(acc, args):
        xi, ti, vi = args
        lg = jnp.einsum("bsd,dv->bsv", xi, head).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        onehot = jax.nn.one_hot(ti, cfg.vocab, dtype=lg.dtype)
        tgt_logit = jnp.einsum("bsv,bsv->bs", lg, onehot)
        return acc + jnp.sum((lse - tgt_logit) * vi), None

    tot, _ = jax.lax.scan(jax.checkpoint(chunk), jnp.float32(0.0),
                          (xc, tc, vc))
    return tot / (B * n)


def _microbatched_grads(params, cfg, tcfg: TrainConfig, tokens, extra):
    """Returns (loss, grads) averaged over microbatches."""
    nmb = tcfg.microbatches
    if nmb == 1:
        loss, g = jax.value_and_grad(loss_fn)(params, cfg, tokens, extra)
        return loss, constrain_like_params(g)
    B = tokens.shape[0]
    assert B % nmb == 0, (B, nmb)
    tmb = tokens.reshape(nmb, B // nmb, *tokens.shape[1:])
    emb = jax.tree.map(lambda a: a.reshape(nmb, B // nmb, *a.shape[1:]),
                       extra) if extra else None

    def body(carry, mb):
        acc_loss, acc_g = carry
        tm, em = mb
        l, g = jax.value_and_grad(loss_fn)(params, cfg, tm, em)
        acc_g = jax.tree.map(
            lambda a, b: a + b.astype(tcfg.accum_dtype), acc_g,
            constrain_like_params(g))
        return (acc_loss + l, constrain_like_params(acc_g)), None

    zero_g = constrain_like_params(
        jax.tree.map(lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params))
    (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero_g),
                                    (tmb, emb))
    inv = 1.0 / nmb
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns step(params, opt_state, tokens[, extra]) -> (loss, params,
    opt_state).  In compressed mode tokens has shape [npods, B/npods, S]."""

    def step(params, opt_state, tokens, extra=None):
        on_mesh = current_mesh() is not None
        if tcfg.weight_compress == "int8" and not on_mesh:
            # single-device tests: additive-STE variant (numerics only)
            use_params = W.compress_for_gather(params)
        else:
            # mesh path: the int8 gather happens inside the period scan
            # via the weight_gather_info hook (custom_vjp STE) — the
            # additive form would gather the fp32 master anyway
            # (§Perf A1, refuted).
            use_params = params

        # arm the hooks with the configured codec names ("int8" is the
        # legacy alias for the blockwise wire codec; "none"/off-mesh
        # disarms) — custom registry ids flow through unchanged
        wc_ctx = use_weight_compress(tcfg.weight_compress if on_mesh
                                     else False)
        a2a_ctx = use_a2a_compress(tcfg.a2a_compress if on_mesh else False)

        if tcfg.grad_compress != "none" and tcfg.npods > 1:
            # spmd_axis_name pins every vmapped intermediate's lane dim to
            # the 'pod' mesh axis (otherwise SPMD materializes both pods'
            # activations on every device — found in the dry-run HLO).
            def pod_grads(t, e):
                with dp_axes_override(("data",)):
                    return _microbatched_grads(use_params, cfg, tcfg, t, e)

            with wc_ctx, a2a_ctx:
                per_pod = jax.vmap(pod_grads,
                                   in_axes=(0, 0 if extra else None),
                                   spmd_axis_name="pod")
                losses, grads_podded = per_pod(tokens, extra)
            loss = jnp.mean(losses)
            grads = G.compressed_psum_mean(grads_podded, tcfg.grad_compress,
                                           tcfg.npods)
        else:
            if tokens.ndim == 3:                 # podded layout, no compress
                tokens = tokens.reshape(-1, tokens.shape[-1])
                # repro-lint: allow[tracer-branch] `extra` is a pytree
                # container; truthiness checks emptiness, not values
                if extra:
                    extra = jax.tree.map(
                        lambda a: a.reshape(-1, *a.shape[2:]), extra)
            with wc_ctx, a2a_ctx:
                loss, grads = _microbatched_grads(use_params, cfg, tcfg,
                                                  tokens, extra)
        new_params, new_opt = adamw.update(grads, opt_state, params,
                                           tcfg.adamw)
        return loss, new_params, new_opt

    return step
