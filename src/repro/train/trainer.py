"""Training loop with checkpoint/restart, NaN guard, straggler watchdog.

Single-controller JAX: the same loop drives 1 CPU device (tests/examples)
or a full pod mesh (launch/train.py) — only the shardings differ.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import pipeline
from repro.dist import chaos, fault
from repro.io import checkpoint as ckpt_io
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw
from .train_step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 128
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    # error-bounded restart files: per-leaf codec selection via the
    # repro.codecs registry (one policy object, no mode strings)
    checkpoint_policy: ckpt_io.CheckpointPolicy = \
        ckpt_io.CheckpointPolicy(codec="cusz", eb_valrel=1e-5)
    # async write phase: the step-N encode/write overlaps the step-N+1
    # compute; submit blocks only when the writer falls behind
    checkpoint_async: bool = True
    checkpoint_nshards: Optional[int] = None   # None = jax.process_count()
    # transient write failures (OSError class) retry on the writer thread
    # with exponential backoff before surfacing
    writer_retries: int = 2
    # straggler mitigation: a `fault.MitigationPolicy` rebalances work
    # shares away from flagged hosts and skip-and-logs NaN losses; None
    # keeps detection-only behavior (the PR 5 watchdog)
    mitigation: Optional[fault.MitigationPolicy] = None
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, lcfg: LoopConfig):
        self.cfg, self.tcfg, self.lcfg = cfg, tcfg, lcfg
        self.step_fn = jax.jit(make_train_step(cfg, tcfg))
        self.straggler = fault.StragglerDetector()
        self.history: List[Dict[str, float]] = []

    def init_state(self):
        params = M.init_params(jax.random.PRNGKey(self.lcfg.seed), self.cfg)
        opt = adamw.init(params, self.tcfg.adamw)
        return params, opt

    def run(self) -> List[Dict[str, float]]:
        lc = self.lcfg
        params, opt = self.init_state()
        start = 0
        if lc.checkpoint_dir and ckpt_io.latest_step(lc.checkpoint_dir) is not None:
            (params, opt), start = ckpt_io.load_checkpoint(
                lc.checkpoint_dir, (params, opt))
            start += 1
        last_good = None
        # bounded to one in-flight write: a second save while the writer
        # is still streaming the previous step blocks the loop (the
        # writer-fell-behind barrier) instead of growing an unbounded
        # backlog of device snapshots; scoped to this run so the worker
        # thread never outlives it
        writer = (ckpt_io.AsyncWriter(max_pending=1,
                                      retries=lc.writer_retries)
                  if lc.checkpoint_async and lc.checkpoint_dir else None)
        monkey = chaos.current()
        policy = lc.mitigation
        try:
            for step in range(start, lc.steps):
                toks = jnp.asarray(pipeline.host_batch(
                    self.cfg.vocab, lc.batch, lc.seq, step, lc.seed))
                t0 = time.perf_counter()
                loss, params, opt = self.step_fn(params, opt, toks)
                loss.block_until_ready()  # repro-lint: allow[host-sync] straggler timer fence
                dt = time.perf_counter() - t0
                if monkey is not None:
                    # armed chaos: the step wall time becomes the simulated
                    # cluster's (real sleep), and per-host durations feed
                    # the mitigation policy's rebalancing
                    shares = policy.shares if policy is not None else None
                    dt, host_dts = monkey.inject_step(step, dt, shares)
                    if policy is not None:
                        policy.observe(step, host_dts)
                slow = self.straggler.observe(step, dt)
                loss_val = (float("nan")
                            if monkey is not None and monkey.nan_burst(step)
                            else loss)
                bad = (policy.on_bad_loss(step, loss_val)
                       if policy is not None else fault.loss_is_bad(loss_val))
                if bad:
                    # NaN guard: restore last good state, skip this step's data
                    if last_good is not None:
                        params, opt = last_good
                    continue
                # n_flagged rides in the step log: the first concrete
                # hook for straggler *mitigation* (rebalancing decisions
                # key off the running flag count, not one step's bool)
                self.history.append({"step": step, "loss": float(loss),
                                     "dt": dt, "slow": bool(slow),
                                     "n_flagged": self.straggler.n_flagged})
                if step % 20 == 0:
                    last_good = (params, opt)
                if lc.checkpoint_dir and (step + 1) % lc.checkpoint_every == 0:
                    # async: returns after the on-device encode; the write
                    # streams on the writer thread under the next steps
                    ckpt_io.save_checkpoint(lc.checkpoint_dir, step,
                                            (params, opt),
                                            policy=lc.checkpoint_policy,
                                            nshards=lc.checkpoint_nshards,
                                            writer=writer)
        finally:
            # drain + stop the worker and surface any write failure
            # instead of losing it with the thread (the old background=
            # stub bug)
            if writer is not None:
                writer.close()
        return self.history
