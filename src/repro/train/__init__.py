from . import train_step, trainer  # noqa: F401
