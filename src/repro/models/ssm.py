"""Mamba2 SSD (state-space duality) block: chunked quadratic-intra /
linear-inter scan for training+prefill, O(1) recurrent step for decode.

Faithful to the SSD formulation (scalar A per head, shared B/C across
heads, causal conv on x/B/C, gated RMSNorm) in pure JAX: the intra-chunk
term is a masked [Q,Q] matmul (MXU-friendly), the inter-chunk term is a
`lax.scan` over chunk states — exactly the parallelism structure the SSD
paper derives, which is also the TPU-native one.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init, rms_norm


def init_mamba_params(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    N = s.d_state
    ks = jax.random.split(key, 4)
    conv_dim = d_in + 2 * N
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H)),
        "conv_w": dense_init(ks[1], (s.conv_kernel, conv_dim)) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2, jnp.float32))),
        "gate_norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d)),
    }


class MambaState(NamedTuple):
    h: jax.Array          # [B, H, N, P] SSM state
    conv: jax.Array       # [B, K-1, conv_dim] causal-conv tail


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B,S,C]; depthwise causal conv, kernel K."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
              for i in range(K))
    return jax.nn.silu(out + b.astype(x.dtype))


def _split_proj(zxbcdt, cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    N = s.d_state
    z, x, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, x, Bm, Cm, dt, d_in, H, N


HEAD_BLOCK = 8          # heads per intra-chunk block (bounds the [Q,Q,hb]
                        # score tensor; see DESIGN.md §5 memory notes)
SEG_CHUNKS = 32         # chunks per sequence segment (outer scan carries
                        # the SSM state => O(SEG) activation memory even
                        # for 32k/500k prefill)


def _ssd_segment(xc, Bc, Cc, lc, h0):
    """SSD over one segment of chunks.

    xc: [B,nC,Q,H,P] (already dt-scaled, f32); Bc/Cc: [B,nC,Q,N];
    lc: [B,nC,Q,H] in-chunk cumulative log decay; h0: [B,H,N,P] carry.
    Returns (y [B,nC,Q,H,P], hT)."""
    B_, nC, Q, H, P = xc.shape
    total = lc[:, :, -1, :]                                   # [B,nC,H]

    cb = jnp.einsum("bcqn,bcun->bcqu", Cc, Bc,
                    preferred_element_type=jnp.float32)       # [B,nC,Q,U]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    cbm = jnp.where(tri[None, None], cb, 0.0)

    # intra-chunk per head-block (keeps [Q,U,hb] bounded)
    hb = HEAD_BLOCK if H % HEAD_BLOCK == 0 else 1
    nHB = H // hb
    lc_b = jnp.moveaxis(lc.reshape(B_, nC, Q, nHB, hb), 3, 0)   # [HB,B,nC,Q,hb]
    xc_b = jnp.moveaxis(xc.reshape(B_, nC, Q, nHB, hb, P), 3, 0)

    def hb_body(_, args):
        l_b, x_b = args
        seg = l_b[:, :, :, None, :] - l_b[:, :, None, :, :]     # [B,nC,Q,U,hb]
        scores = cbm[..., None] * jnp.exp(seg)
        y_b = jnp.einsum("bcquh,bcuhp->bcqhp", scores, x_b,
                         preferred_element_type=jnp.float32)
        return None, y_b

    # checkpoint: backward recomputes per-head-block scores (otherwise the
    # scan stacks the full [Q,U,H] segsum tensor as residuals)
    _, y_intra_b = jax.lax.scan(jax.checkpoint(hb_body), None, (lc_b, xc_b))
    y_intra = jnp.moveaxis(y_intra_b, 0, 3).reshape(B_, nC, Q, H, P)

    # chunk states: S_c = sum_u exp(total - l_u) B_u x_u^T   [B,nC,H,N,P]
    decay_to_end = jnp.exp(total[:, :, None, :] - lc)           # [B,nC,Q,H]
    Sc = jnp.einsum("bcun,bcuh,bcuhp->bchnp", Bc, decay_to_end, xc,
                    preferred_element_type=jnp.float32)

    def step(h, args):
        sc, tot = args
        h_out = h                                               # state BEFORE chunk
        h = h * jnp.exp(tot)[:, :, None, None] + sc
        return h, h_out

    hT, h_prev = jax.lax.scan(step, h0,
                              (Sc.swapaxes(0, 1), total.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                              # [B,nC,H,N,P]

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc, jnp.exp(lc), h_prev,
                         preferred_element_type=jnp.float32)
    return y_intra + y_inter, hT


def mamba_forward(p, cfg: ModelConfig, u: jax.Array
                  ) -> Tuple[jax.Array, MambaState]:
    """u: [B,S,D].  Returns (out [B,S,D], final MambaState for decode).

    Long sequences run as an outer scan over segments (SEG_CHUNKS·chunk
    tokens) carrying the SSM state — the parallel SSD form within each
    segment, linear recurrence across segments."""
    s = cfg.ssm
    dt_ = u.dtype
    B_, S, D = u.shape
    zxbcdt = jnp.einsum("bsd,dz->bsz", u, p["in_proj"].astype(dt_))
    z, x, Bm, Cm, dtp, d_in, H, N = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    x, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    P = s.head_dim
    xh = x.reshape(B_, S, H, P)
    dt = jax.nn.softplus(dtp.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])          # [B,S,H]
    A = -jnp.exp(p["A_log"])                                      # [H]
    dA = dt * A[None, None, :]                                    # log decay
    xdt = xh.astype(jnp.float32) * dt[..., None]

    Q = min(s.chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q
    seg_c = min(SEG_CHUNKS, nC)
    assert nC % seg_c == 0, (nC, seg_c)
    nseg = nC // seg_c

    def shape_seg(t, extra):
        return t.reshape((B_, nseg, seg_c, Q) + extra).swapaxes(0, 1)

    xs = shape_seg(xdt, (H, P))
    Bs = shape_seg(Bm.astype(jnp.float32), (N,))
    Cs = shape_seg(Cm.astype(jnp.float32), (N,))
    ls = jnp.cumsum(dA.reshape(B_, nseg, seg_c, Q, H), axis=3).swapaxes(0, 1)

    def seg_body(h, args):
        xc, Bc, Cc, lc = args
        y, hT = _ssd_segment(xc, Bc, Cc, lc, h)
        return hT, y

    h0 = jnp.zeros((B_, H, N, P), jnp.float32)
    hT, ys = jax.lax.scan(jax.checkpoint(seg_body), h0, (xs, Bs, Cs, ls))
    y = ys.swapaxes(0, 1).reshape(B_, S, H, P)

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S, d_in).astype(dt_)

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsz,zd->bsd", y, p["out_proj"].astype(dt_))

    K = s.conv_kernel
    conv_tail = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):, :]
    return out, MambaState(hT, conv_tail)


def mamba_decode(p, cfg: ModelConfig, u: jax.Array, state: MambaState
                 ) -> Tuple[jax.Array, MambaState]:
    """u: [B,1,D]; O(1) recurrent step (the long_500k path)."""
    s = cfg.ssm
    dt_ = u.dtype
    B_ = u.shape[0]
    zxbcdt = jnp.einsum("bsd,dz->bsz", u, p["in_proj"].astype(dt_))
    z, x, Bm, Cm, dtp, d_in, H, N = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)               # [B,1,C]
    K = s.conv_kernel
    window = jnp.concatenate([state.conv, conv_in], axis=1)       # [B,K,C]
    w = p["conv_w"].astype(dt_)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w)
                           + p["conv_b"].astype(dt_))[:, None, :]
    x, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    P = s.head_dim
    xh = x.reshape(B_, 1, H, P)[:, 0]                             # [B,H,P]
    dt = jax.nn.softplus(dtp.astype(jnp.float32)[:, 0] + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                                  # [B,H]
    Bv = Bm[:, 0].astype(jnp.float32)                             # [B,N]
    Cv = Cm[:, 0].astype(jnp.float32)
    xdt = xh.astype(jnp.float32) * dt[..., None]

    h = state.h * a[:, :, None, None] \
        + jnp.einsum("bn,bhp->bhnp", Bv, xdt)
    y = jnp.einsum("bn,bhnp->bhp", Cv, h)
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, 1, d_in).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsz,zd->bsd", y, p["out_proj"].astype(dt_))
    return out, MambaState(h, window[:, 1:, :])
