"""Architecture config schema covering the 10 assigned families:
dense / MoE / MLA-MoE / SSM (Mamba2 SSD) / hybrid (Jamba) / VLM & audio
backbones (stub frontends)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden dim
    n_shared: int = 0            # always-on shared experts (deepseek-v2)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:                 # deepseek-v2 multi-head latent attention
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:                 # mamba2 SSD
    d_state: int = 128
    head_dim: int = 64           # P
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128             # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                    # dense MLP hidden (0 = no dense MLP)
    vocab: int
    head_dim: int = 128
    # layer pattern: tuple of kinds, tiled to n_layers.  kinds:
    #   'attn+mlp' | 'attn+moe' | 'mamba+mlp' | 'mamba+moe' | 'mamba'
    pattern: Tuple[str, ...] = ("attn+mlp",)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    qk_norm: bool = False        # qwen3
    qkv_bias: bool = False       # qwen2.5
    mlp_gated: bool = True       # False: 2-matrix GELU MLP (granite)
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # modality stubs: extra precomputed embeddings prepended (vlm) or
    # added per-position (audio frames)
    n_prepend_embeds: int = 0    # phi-3-vision patch tokens
    add_frame_embeds: bool = False  # musicgen EnCodec frame embeddings
    # attention classes for shape handling
    sub_quadratic: bool = False  # True for SSM/hybrid (long_500k eligible)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.name,)
        return self.n_layers // len(self.pattern)

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.pattern) * self.n_periods

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.pattern:
            blk = 0
            if kind.startswith("attn"):
                if self.mla is not None:
                    m = self.mla
                    blk += d * m.q_lora_rank \
                        + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim) \
                        + d * (m.kv_lora_rank + m.qk_rope_dim) \
                        + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim) \
                        + self.n_heads * m.v_head_dim * d
                else:
                    blk += d * self.n_heads * self.head_dim * 2 \
                        + d * self.n_kv_heads * self.head_dim * 2
            if kind.startswith("mamba"):
                s = self.ssm
                d_in = s.expand * d
                blk += d * (2 * d_in + 2 * s.d_state) + d_in * d
            if kind.endswith("+mlp") and self.d_ff:
                blk += (3 if self.mlp_gated else 2) * d * self.d_ff
            if kind.endswith("+moe"):
                blk += 3 * d * self.moe.d_ff * (self.moe.n_experts + self.moe.n_shared)
                blk += d * self.moe.n_experts       # router
            total += blk * (self.n_layers // len(self.pattern))
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters — the MoE 6·N_active·D term."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe_layers = sum(1 for k in self.layer_kinds() if k.endswith("+moe"))
        all_experts = 3 * d * self.moe.d_ff * self.moe.n_experts * n_moe_layers
        active = 3 * d * self.moe.d_ff * self.moe.top_k * n_moe_layers
        return full - all_experts + active
