"""The LM: embedding -> scan over layer periods -> norm -> logits.

One implementation covers all 10 assigned architectures via the config's
layer-kind `pattern` (dense / MoE / MLA / Mamba2 / hybrid) with:
  * `lax.scan` over periods (stacked params) — small HLO even at 88 layers;
  * `jax.checkpoint` (remat) around each period — activation memory is
    one period's boundary activations;
  * heterogeneous periods (Jamba) unrolled inside the scan body;
  * per-kind caches for decode (KV / MLA-latent / Mamba state), with
    optional cuSZ int8 cache compression for GQA KV.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import dense_init, rms_norm, swiglu
from repro.core import kvcache as KVC
from repro.core import weights as WQ
from repro.dist.context import constrain, weight_gather_info


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_position(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"pre_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if kind.startswith("attn"):
        p["attn"] = attn.init_mla_params(ks[0], cfg) if cfg.mla else \
            attn.init_gqa_params(ks[0], cfg)
    else:
        p["mamba"] = ssm_mod.init_mamba_params(ks[0], cfg)
    if kind.endswith("+mlp"):
        p["mlp_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = {"w_up": dense_init(ks[2], (cfg.d_model, cfg.d_ff)),
                    "w_down": dense_init(ks[3], (cfg.d_ff, cfg.d_model))}
        if cfg.mlp_gated:
            p["mlp"]["w_gate"] = dense_init(ks[1], (cfg.d_model, cfg.d_ff))
    elif kind.endswith("+moe"):
        p["mlp_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["moe"] = moe_mod.init_moe_params(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    kp = jax.random.split(key, 3 + len(cfg.pattern))
    params: Dict[str, Any] = {
        "embed": jax.random.normal(kp[0], (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "out_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kp[1], (cfg.d_model, cfg.vocab))
    layers = []
    for i, kind in enumerate(cfg.pattern):
        pk = jax.random.split(kp[3 + i], cfg.n_periods)
        layers.append(jax.vmap(lambda k: _init_position(k, cfg, kind))(pk))
    params["layers"] = layers
    return params


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _position_forward(p, cfg: ModelConfig, kind: str, x, pos):
    """One layer. Returns (x, cache_entry)."""
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if kind.startswith("attn"):
        if cfg.mla:
            a, cache = attn.mla_forward(p["attn"], cfg, h, pos)
        else:
            a, cache = attn.gqa_forward(p["attn"], cfg, h, pos)
    else:
        a, cache = ssm_mod.mamba_forward(p["mamba"], cfg, h)
    x = x + a
    if kind.endswith("+mlp"):
        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + _mlp(p["mlp"], cfg, h)
    elif kind.endswith("+moe"):
        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + moe_mod.moe_forward(p["moe"], cfg, h)
    return x, cache


def _mlp(m, cfg: ModelConfig, h):
    if cfg.mlp_gated:
        return swiglu(h, m["w_gate"], m["w_up"], m["w_down"])
    u = jnp.einsum("...d,df->...f", h, m["w_up"].astype(h.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(u),
                      m["w_down"].astype(h.dtype))


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            extra: Optional[Dict[str, jax.Array]] = None,
            compute_dtype=jnp.bfloat16, collect_caches: bool = False,
            return_hidden: bool = False):
    """tokens: [B,S] int32.  extra: modality stubs (patch/frame embeds).
    Returns (logits [B,S_total,V] fp32, caches or None); with
    return_hidden=True returns the post-norm hidden [B,S_total,D] instead
    of logits (the chunked-CE path avoids materializing [B,S,V])."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(compute_dtype)
    if cfg.add_frame_embeds and extra and "frame_embeds" in extra:
        x = x + extra["frame_embeds"].astype(compute_dtype)
    if cfg.n_prepend_embeds and extra and "patch_embeds" in extra:
        x = jnp.concatenate(
            [extra["patch_embeds"].astype(compute_dtype), x], axis=1)
    S_total = x.shape[1]
    x = constrain(x, "dp", None, None)
    pos = jnp.broadcast_to(jnp.arange(S_total, dtype=jnp.int32)[None, :],
                           (B, S_total))

    kinds = cfg.pattern

    wg = weight_gather_info()

    def period_body(x, period_params):
        if wg is not None:
            # int8 weight-gather hook (inside the scan: one period's
            # weights resident gathered at a time — §Perf iteration A2)
            specs_tuple, mesh_ = wg
            period_params = tuple(
                WQ.gather_dequant_tree(pp, sp, mesh_)
                for pp, sp in zip(period_params, specs_tuple))
        caches = []
        for i, kind in enumerate(kinds):
            x, c = _position_forward(period_params[i], cfg, kind, x, pos)
            caches.append(c)
        x = constrain(x, "dp", None, None)
        return x, tuple(caches) if collect_caches else None

    body = jax.checkpoint(period_body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    x, caches = jax.lax.scan(body, x, tuple(params["layers"]))

    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    if return_hidden:
        return x, caches
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(compute_dtype))
    logits = constrain(logits, "dp", None, "model")
    return logits.astype(jnp.float32), caches


def lm_head_of(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class DecodeCaches(NamedTuple):
    """Tuple-aligned with cfg.pattern; each entry stacked over periods."""
    entries: Tuple[Any, ...]


def init_caches(cfg: ModelConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16, compressed_kv: bool = False) -> DecodeCaches:
    nP = cfg.n_periods
    entries = []
    for kind in cfg.pattern:
        if kind.startswith("attn"):
            if cfg.mla:
                m = cfg.mla
                R = m.kv_lora_rank + m.qk_rope_dim
                if compressed_kv:
                    entries.append(KVC.QuantKV(
                        jnp.zeros((nP, batch, s_max, R), jnp.int8),
                        jnp.full((nP, batch, s_max // KVC.SEQ_BLOCK, R),
                                 KVC.SCALE_FLOOR, jnp.float32)))
                else:
                    entries.append(jnp.zeros((nP, batch, s_max, R), dtype))
            elif compressed_kv:
                kq = KVC.QuantKV(
                    jnp.zeros((nP, batch, s_max, cfg.n_kv_heads, cfg.head_dim),
                              jnp.int8),
                    jnp.full((nP, batch, s_max // KVC.SEQ_BLOCK,
                              cfg.n_kv_heads, cfg.head_dim),
                             KVC.SCALE_FLOOR, jnp.float32))
                entries.append((kq, kq))
            else:
                z = jnp.zeros((nP, batch, s_max, cfg.n_kv_heads, cfg.head_dim),
                              dtype)
                entries.append((z, z))
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            H = d_in // s.head_dim
            entries.append(ssm_mod.MambaState(
                jnp.zeros((nP, batch, H, s.d_state, s.head_dim), jnp.float32),
                jnp.zeros((nP, batch, s.conv_kernel - 1, d_in + 2 * s.d_state),
                          dtype)))
    return DecodeCaches(tuple(entries))


def decode_step(params, cfg: ModelConfig, token: jax.Array,
                caches: DecodeCaches, cache_len: jax.Array,
                compute_dtype=jnp.bfloat16, compressed_kv: bool = False):
    """token: [B,1] int32; caches as from init_caches/prefill.
    Returns (logits [B,1,V], new DecodeCaches)."""
    x = params["embed"][token].astype(compute_dtype)
    kinds = cfg.pattern

    def period_body(x, scanned):
        period_params, period_caches = scanned
        new_caches = []
        for i, kind in enumerate(kinds):
            p = period_params[i]
            h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
            c = period_caches[i]
            if kind.startswith("attn"):
                if cfg.mla:
                    a, nc = attn.mla_decode(p["attn"], cfg, h, c, cache_len,
                                            compressed=compressed_kv)
                else:
                    ck, cv = c
                    a, nck, ncv = attn.gqa_decode(
                        p["attn"], cfg, h, ck, cv, cache_len,
                        compressed=compressed_kv)
                    nc = (nck, ncv)
            else:
                a, nc = ssm_mod.mamba_decode(p["mamba"], cfg, h, c)
            x = x + a
            if kind.endswith("+mlp"):
                hm = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
                x = x + _mlp(p["mlp"], cfg, hm)
            elif kind.endswith("+moe"):
                hm = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
                x = x + moe_mod.moe_forward(p["moe"], cfg, hm)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_entries = jax.lax.scan(period_body, x,
                                  (tuple(params["layers"]), caches.entries))
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(compute_dtype))
    return logits.astype(jnp.float32), DecodeCaches(new_entries)
