"""Attention: GQA/MQA (+qk_norm, +qkv bias), MLA (deepseek-v2), with a
flash-style blocked implementation for long sequences and a decode path
against (optionally int8-compressed) KV caches.

The blocked "flash-scan" is pure JAX (lax.scan over KV blocks with online
softmax), so it compiles on any backend — this is the path the multi-pod
dry-run exercises.  On real TPUs the same interface can dispatch to a
Pallas flash kernel; the cuSZ paper has no attention-kernel contribution,
so we keep the XLA-native form as primary (DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import apply_rope, rms_norm, dense_init
from repro.core import kvcache as KVC

Q_BLOCK = 1024
KV_BLOCK = 1024


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_gqa_params(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h, hd)),
        "wk": dense_init(ks[1], (d, kv, hd)),
        "wv": dense_init(ks[2], (d, kv, hd)),
        "wo": dense_init(ks[3], (h, hd, d), in_axis=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def init_mla_params(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank)),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h, m.qk_nope_dim + m.qk_rope_dim)),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim)),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, h, m.qk_nope_dim)),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, h, m.v_head_dim)),
        "wo": dense_init(ks[5], (h, m.v_head_dim, d), in_axis=(0, 1)),
    }


# ---------------------------------------------------------------------------
# flash-scan core
# ---------------------------------------------------------------------------

def _flash(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
           q_offset: int | jax.Array = 0) -> jax.Array:
    """Blocked online-softmax attention.

    q: [B, Sq, H, hd]; k/v: [B, Sk, KV, hd] (KV divides H).  Returns
    [B, Sq, H, hd].  Memory is O(Sq·KV_BLOCK) per step instead of O(Sq·Sk).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    vd = v.shape[-1]                                   # may differ (MLA)
    g = H // KV
    scale = 1.0 / np.sqrt(hd)
    nkb = -(-Sk // KV_BLOCK)
    pad_k = nkb * KV_BLOCK - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kb = k.reshape(B, nkb, KV_BLOCK, KV, hd)
    vb = v.reshape(B, nkb, KV_BLOCK, KV, vd)
    qh = q.reshape(B, Sq, KV, g, hd)
    q_pos = jnp.arange(Sq) + q_offset                      # [Sq]

    def step(carry, blk):
        acc, m, l = carry
        kblk, vblk, bi = blk                               # [B,KB,KV,hd]
        s = jnp.einsum("bqkgd,bckd->bqkgc", qh, kblk,
                       preferred_element_type=jnp.float32) * scale
        k_pos = bi * KV_BLOCK + jnp.arange(KV_BLOCK)
        mask = k_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((Sq, KV_BLOCK), bool)
        mask = mask & (k_pos < Sk)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isfinite(m_new)[..., None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Sq, KV, g, vd), jnp.float32)
    m0 = jnp.full((B, Sq, KV, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, g), jnp.float32)
    # checkpoint the block step: backward recomputes the [Sq, KV_BLOCK]
    # scores instead of stacking them for every block (flash-bwd memory;
    # without this the scan saves O(S^2) residuals — §Perf iteration 7)
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(step), (acc0, m0, l0),
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nkb)))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.reshape(B, Sq, H, vd).astype(q.dtype)


def _flash_qblocked(q, k, v, causal):
    """Outer scan over query blocks keeps the online-softmax state small
    for very long prefill (32k+).  Non-multiple Sq (e.g. +256 VLM patch
    tokens) is handled by padding queries at the end and slicing off."""
    B, Sq, H, hd = q.shape
    if Sq <= Q_BLOCK:
        return _flash(q, k, v, causal)
    pad = (-Sq) % Q_BLOCK
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nqb = q.shape[1] // Q_BLOCK
    qb = q.reshape(B, nqb, Q_BLOCK, H, hd).swapaxes(0, 1)

    def step(_, args):
        qi, bi = args
        o = _flash(qi, k, v, causal, q_offset=bi * Q_BLOCK)
        return None, o

    _, ob = jax.lax.scan(jax.checkpoint(step), None, (qb, jnp.arange(nqb)))
    out = ob.swapaxes(0, 1).reshape(B, q.shape[1], H, ob.shape[-1])
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# GQA/MQA
# ---------------------------------------------------------------------------

def gqa_forward(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Training / prefill.  x: [B,S,D].  Returns (out, (k, v)) with k/v in
    cache layout [B, S, KV, hd]."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dke->bske", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt); k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = _flash_qblocked(q, k, v, causal=True)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(dt))
    return out, (k, v)


def gqa_decode(p, cfg: ModelConfig, x: jax.Array, cache_k, cache_v,
               cache_len: jax.Array, compressed: bool = False):
    """One-token decode.  x: [B,1,D]; cache_k/v: [B,Smax,KV,hd] (or QuantKV
    when compressed).  Returns (out, new_cache_k, new_cache_v)."""
    dt = x.dtype
    B = x.shape[0]
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dke->bske", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt); k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    if compressed:
        cache_k = KVC.kv_update_block(cache_k, k, cache_len, seq_axis=1)
        cache_v = KVC.kv_update_block(cache_v, v, cache_len, seq_axis=1)
        kf = KVC.kv_dequantize(cache_k, seq_axis=1, dtype=dt)
        vf = KVC.kv_dequantize(cache_v, seq_axis=1, dtype=dt)
    else:
        cache_k = jax.lax.dynamic_update_index_in_dim(cache_k, k[:, 0], cache_len, 1)
        cache_v = jax.lax.dynamic_update_index_in_dim(cache_v, v[:, 0], cache_len, 1)
        kf, vf = cache_k, cache_v

    Smax = kf.shape[1]
    KV = kf.shape[2]
    g = cfg.n_heads // KV
    scale = 1.0 / np.sqrt(cfg.head_dim)
    qh = q.reshape(B, 1, KV, g, cfg.head_dim)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qh, kf,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(Smax) <= cache_len
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", pattn.astype(dt), vf,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, cfg.n_heads, cfg.head_dim).astype(dt)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(dt))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): the latent IS the cache
# ---------------------------------------------------------------------------

def mla_forward(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array):
    """Returns (out, latent_cache [B,S,kv_lora+rope])."""
    m = cfg.mla
    dt = x.dtype
    B, S, _ = x.shape
    ql = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt))
    q = jnp.einsum("bsr,rhe->bshe", ql, p["wq_b"].astype(dt))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    latent, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)  # [B,S,1,rope]
    k_nope = jnp.einsum("bsr,rhe->bshe", latent, p["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhe->bshe", latent, p["wv_b"].astype(dt))

    k_rope_b = jnp.broadcast_to(k_rope, (B, S, cfg.n_heads, m.qk_rope_dim))
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = _flash_qblocked(qf, kf, v, causal=True)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(dt))
    return out, jnp.concatenate([latent, k_rope[:, :, 0, :]], axis=-1)


def mla_decode(p, cfg: ModelConfig, x: jax.Array, cache,
               cache_len: jax.Array, compressed: bool = False):
    """cache: [B, Smax, kv_lora+rope] latent cache (MLA's whole point: the
    per-token cache is ~576 floats, already 'compressed'), or its QuantKV
    form when `compressed` — the same blockwise-int8 codec the GQA cache
    uses, layered on top of the latent (PREQUANT on the already-low-rank
    entries; eb = scale/2 per coordinate)."""
    m = cfg.mla
    dt = x.dtype
    B = x.shape[0]
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    ql = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt))
    q = jnp.einsum("bsr,rhe->bshe", ql, p["wq_b"].astype(dt))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    latent, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    entry = jnp.concatenate([latent, k_rope], axis=-1)
    if compressed:
        cache = KVC.kv_update_block(cache, entry, cache_len, seq_axis=1)
        cache_f = KVC.kv_dequantize(cache, seq_axis=1, dtype=dt)
    else:
        cache = jax.lax.dynamic_update_index_in_dim(cache, entry[:, 0],
                                                    cache_len, 1)
        cache_f = cache

    lat_c = cache_f[..., :m.kv_lora_rank]
    kr_c = cache_f[..., m.kv_lora_rank:]
    k_nope = jnp.einsum("bsr,rhe->bshe", lat_c, p["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhe->bshe", lat_c, p["wv_b"].astype(dt))
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = jnp.einsum("bqhe,bshe->bqhs", q_nope, k_nope,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bqhe,bse->bqhs", q_rope, kr_c,
                       preferred_element_type=jnp.float32)
    s = s * scale
    Smax = cache_f.shape[1]
    valid = jnp.arange(Smax) <= cache_len
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhs,bshe->bqhe", pattn.astype(dt), v,
                   preferred_element_type=jnp.float32).astype(dt)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(dt))
    return out, cache
