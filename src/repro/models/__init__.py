from . import config, layers, attention, moe, ssm, model  # noqa: F401
from .config import ModelConfig  # noqa: F401
