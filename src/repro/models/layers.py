"""Shared layer primitives: RMSNorm, RoPE, SwiGLU MLP, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; pos: [..., S] int32 positions."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))                  # [hd/2]
    ang = pos[..., None].astype(jnp.float32) * freqs            # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))


def dense_init(key, shape, in_axis=0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else \
        int(np.prod([shape[a] for a in in_axis]))
    return jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)
