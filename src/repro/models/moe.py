"""Mixture-of-Experts with sort-based dropped-token dispatch (EP-friendly).

Routing, sorting and capacity are all PER BATCH ROW (GShard/Switch-style
groups): each [S] row sorts its own S·top_k assignments and keeps the
first `cap = S·top_k/E·cf` per expert.  Nothing ever crosses rows except
the expert einsum itself, so with batch sharded over 'data' and experts
over 'model' the only collective is the dispatch/combine all-to-all —
a *global* token sort would be unshardable and forces SPMD to replicate
the full [T·k, D] flattened batch (observed: 120 GiB/device on the
deepseek-v2 prefill dry-run; see EXPERIMENTS.md §Perf iteration 6).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, swiglu
from repro.dist.context import constrain, current_mesh, a2a_compress_active

def _qblock(d: int) -> int:
    """Largest power-of-two block (16..128) dividing d; 0 if none."""
    for b in (128, 64, 32, 16):
        if d % b == 0:
            return b
    return 0


def _compressed_reshard(x, to_spec, from_spec):
    """Reshard with the armed wire codec's representation on the wire,
    both directions: forward encodes -> reshards to `to_spec` (all-to-all
    in s8) -> decodes; the custom_vjp backward encodes the cotangent and
    reshards it back to `from_spec` (error-bounded both ways; the paper's
    PREQUANT on the EP dispatch/combine path).  The codec comes from the
    `use_a2a_compress` hook via the `repro.codecs` registry."""
    from repro import codecs
    from repro.dist.context import a2a_codec, constrain as _c

    mesh = current_mesh()
    blk = _qblock(x.shape[-1])
    if mesh is None or blk == 0:
        return constrain(x, *to_spec)
    codec = codecs.get_block_codec(a2a_codec() or "int8-block",
                                   axis=-1, block=blk)

    def _enc_reshard(v, spec):
        cont = codec.encode(v)
        # constrain q and scale separately: the all-to-all moves the
        # narrow payload (scale: same rank, last dim = blocks)
        q = _c(cont.payload["q"], *spec)
        s = _c(cont.payload["scale"], *spec)
        return codec.decode(
            cont.replace(payload={"q": q, "scale": s}), like=v)

    @jax.custom_vjp
    def reshard(v):
        # pin the producer side first: without this the scatter that built
        # v fuses the layout change into its own (f32) collective and the
        # int8 hop below becomes a no-op
        v = _c(v, *from_spec)
        return _enc_reshard(v, to_spec)

    def fwd(v):
        return reshard(v), None

    def bwd(_, g):
        g = _c(g, *to_spec)
        return (_enc_reshard(g, from_spec),)

    reshard.defvjp(fwd, bwd)
    return reshard(x)


def init_moe_params(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts)),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_ff)) / (cfg.n_layers ** 0.5),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_ff)),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_ff, d), in_axis=(0, 1)),
    }
    if m.n_shared:
        sk = jax.random.split(ks[4], 3)
        f = m.d_ff * m.n_shared
        p["shared"] = {"w_gate": dense_init(sk[0], (d, f)),
                       "w_up": dense_init(sk[1], (d, f)),
                       "w_down": dense_init(sk[2], (f, d))}
    return p


def moe_forward(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: [B,S,D] -> [B,S,D].  Row-local dropped-token top-k routing."""
    m = cfg.moe
    B, S, D = x.shape
    dt = x.dtype
    E, k = m.n_experts, m.top_k
    A = S * k

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt)
                        ).astype(jnp.float32)
    gates, eidx = jax.lax.top_k(logits, k)                   # [B,S,k]
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = eidx.reshape(B, A)                              # expert per slot
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)[None, :], (B, A))
    flat_g = gates.reshape(B, A)

    order = jnp.argsort(flat_e, axis=1, stable=True)         # group by expert
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(se)    # [B,E]
    starts = jnp.cumsum(counts, axis=1) - counts
    rank = jnp.arange(A, dtype=jnp.int32)[None, :] \
        - jnp.take_along_axis(starts, se, axis=1)

    cap = max(8, int(A / E * m.capacity_factor))
    cap = min(cap, A)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, E * cap)          # OOB -> dropped

    # dispatch: [B, E, cap, D], rows local, then reshard experts onto EP.
    # vmap'd scatter => batched scatter dims the SPMD partitioner keeps
    # row-sharded (an explicit [B,A] index array degrades to a full
    # all-gather of the token·top_k expansion — §Perf iteration 6b)
    gathered = jnp.where(keep[..., None],
                         jnp.take_along_axis(x, st[..., None], axis=1), 0)

    def row_scatter(vals, sl):
        return jnp.zeros((E * cap + 1, D), dt).at[sl].add(vals, mode="drop")

    disp = jax.vmap(row_scatter)(gathered, slot)
    disp = disp[:, :E * cap, :].reshape(B, E, cap, D)
    row_spec = ("dp", None, None, None)
    ep_spec = ("dp", "model", None, None)
    if a2a_compress_active():                                 # s8 all-to-all
        disp = _compressed_reshard(disp, ep_spec, row_spec)
    else:
        disp = constrain(disp, *ep_spec)                      # all-to-all

    h_g = jnp.einsum("becd,edf->becf", disp, p["w_gate"].astype(dt))
    h_u = jnp.einsum("becd,edf->becf", disp, p["w_up"].astype(dt))
    h = jax.nn.silu(h_g) * h_u
    eo = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))
    if a2a_compress_active():
        eo = _compressed_reshard(eo, row_spec, ep_spec)       # back to rows
    else:
        eo = constrain(eo, *row_spec)
    eo = eo.reshape(B, E * cap, D)

    # combine: gather each kept slot's output, weight, scatter to its token
    vals = jnp.take_along_axis(eo, jnp.minimum(slot, E * cap - 1)[..., None],
                               axis=1)
    contrib = jnp.where(keep[..., None], vals * sg[..., None].astype(dt), 0)

    def row_combine(c, t):
        return jnp.zeros((S, D), dt).at[t].add(c, mode="drop")

    out = jax.vmap(row_combine)(contrib, st)

    if m.n_shared:
        sp = p["shared"]
        out = out + swiglu(x, sp["w_gate"], sp["w_up"], sp["w_down"])
    return out
