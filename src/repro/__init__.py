"""repro: cuSZ (PACT'20) reproduced as a TPU-native JAX compression
substrate inside a multi-pod LM training/serving framework."""
__version__ = "1.0.0"
