"""repro: cuSZ (PACT'20) reproduced as a TPU-native JAX compression
substrate inside a multi-pod LM training/serving framework."""
from repro import _compat as _compat

_compat.install()

__version__ = "1.0.0"
