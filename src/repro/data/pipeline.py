"""Deterministic synthetic LM data pipeline.

Tokens follow a noisy bigram process (fixed random permutation table +
ε-uniform noise), so the stream is learnable (loss decreases) yet needs no
disk or network.  Batches are a pure function of (seed, step) — exactly
reproducible across restarts and across hosts, which is what makes the
checkpoint/restart and elastic-rescale paths deterministic (each host
generates only its shard of the global batch).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@lru_cache(maxsize=8)
def _bigram_table(vocab: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(vocab).astype(np.int64)


def host_batch(vocab: int, batch: int, seq: int, step: int,
               seed: int = 0, noise: float = 0.2) -> np.ndarray:
    """[batch, seq] int32, deterministic in (seed, step)."""
    table = _bigram_table(vocab, seed)
    rng = np.random.default_rng((seed << 20) ^ step)
    toks = np.empty((batch, seq), np.int64)
    toks[:, 0] = rng.integers(0, vocab, batch)
    flips = rng.random((batch, seq)) < noise
    rand = rng.integers(0, vocab, (batch, seq))
    for t in range(1, seq):
        follow = table[toks[:, t - 1]]
        toks[:, t] = np.where(flips[:, t], rand[:, t], follow)
    return toks.astype(np.int32)


def global_batch(mesh: Mesh, vocab: int, batch: int, seq: int, step: int,
                 seed: int = 0, podded: bool = False) -> jax.Array:
    """Build the global [B,S] (or [npods, B/npods, S]) batch with each
    device holding only its shard (multi-host-ready single-controller
    pattern via make_array_from_callback)."""
    if podded:
        npods = mesh.shape["pod"]
        shape = (npods, batch // npods, seq)
        spec = P("pod", "data", None)
    else:
        axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        shape = (batch, seq)
        spec = P(axes, None)
    sharding = NamedSharding(mesh, spec)
    full = host_batch(vocab, batch, seq, step, seed).reshape(shape)

    def cb(index):
        return full[index]

    return jax.make_array_from_callback(shape, sharding, cb)
