from . import scidata  # noqa: F401
