"""Synthetic SDRBench-like fields (paper Table 2 stand-ins).

The container has no network access, so we synthesize fields with the
statistical character the paper reports for each dataset: smooth large-scale
structure + localized features + (for HACC) particle-like low-coherence
series, plus heavy zero-concentration variants (paper Table 9: CLOUDf48 /
QSNOWf48 / baryon_density are ~89-99% within ±eb of 0/min).

Shapes default to scaled-down versions (CPU container); pass `full=True`
for the paper's sizes.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def _grids(shape, rng):
    axes = [np.linspace(0.0, 1.0, s, dtype=np.float32) for s in shape]
    return np.meshgrid(*axes, indexing="ij")


def _smooth(shape, rng, octaves=4, scale=8.0):
    """Band-limited random field via random Fourier features (cheap Perlin
    stand-in, fully vectorized)."""
    grids = _grids(shape, rng)
    out = np.zeros(shape, np.float32)
    amp = 1.0
    for o in range(octaves):
        k = scale * (2.0 ** o)
        nfeat = 6
        w = rng.standard_normal((nfeat, len(shape))).astype(np.float32) * k
        ph = rng.uniform(0, 2 * np.pi, nfeat).astype(np.float32)
        a = rng.standard_normal(nfeat).astype(np.float32) * amp
        acc = np.zeros(shape, np.float32)
        for i in range(nfeat):
            arg = ph[i]
            for d, g in enumerate(grids):
                arg = arg + w[i, d] * g
            acc += a[i] * np.sin(arg)
        out += acc
        amp *= 0.5
    return out


def hacc_like(n: int = 1 << 21, seed: int = 0) -> np.ndarray:
    """1D particle coordinates: sorted-by-cell positions => locally smooth
    with jumps (matches HACC X/VX compressibility profile)."""
    rng = np.random.default_rng(seed)
    ncell = max(1, n // 256)
    cell = np.repeat(np.sort(rng.uniform(0, 256.0, ncell)).astype(np.float32),
                     -(-n // ncell))[:n]
    jitter = rng.normal(0, 0.05, n).astype(np.float32)
    return cell + jitter


def cesm_like(shape: Tuple[int, int] = (450, 900), seed: int = 1) -> np.ndarray:
    """2D climate field, smooth with zonal structure (CESM-ATM CLDHGH)."""
    rng = np.random.default_rng(seed)
    base = _smooth(shape, rng, octaves=5, scale=4.0)
    lat = np.cos(np.linspace(-np.pi / 2, np.pi / 2, shape[0], dtype=np.float32))
    f = base * lat[:, None]
    f = 1.0 / (1.0 + np.exp(-f))            # cloud-fraction-like in [0,1]
    return f.astype(np.float32)


def hurricane_like(shape: Tuple[int, int, int] = (50, 250, 250),
                   seed: int = 2, zero_concentrated: bool = False) -> np.ndarray:
    """3D storm field; `zero_concentrated=True` mimics CLOUDf48/QSNOWf48
    (~89% of points within eb of 0, paper Table 9)."""
    rng = np.random.default_rng(seed)
    f = _smooth(shape, rng, octaves=4, scale=3.0)
    if zero_concentrated:
        f = np.maximum(f - np.quantile(f, 0.89), 0.0) ** 2
        f = f / max(f.max(), 1e-9) * 2.05e-3      # CLOUDf48 range
    return f.astype(np.float32)


def nyx_like(shape: Tuple[int, int, int] = (128, 128, 128),
             seed: int = 3, log_density: bool = True) -> np.ndarray:
    """3D cosmology baryon_density: lognormal with huge dynamic range and
    concentration near the minimum (paper Table 9)."""
    rng = np.random.default_rng(seed)
    g = _smooth(shape, rng, octaves=5, scale=4.0)
    f = np.exp(2.5 * g).astype(np.float32)        # heavy right tail
    return f


def qmcpack_like(shape: Tuple[int, int, int, int] = (48, 36, 36, 36),
                 seed: int = 4) -> np.ndarray:
    """4D einspline orbitals: smooth oscillatory per leading index."""
    rng = np.random.default_rng(seed)
    out = np.stack([_smooth(shape[1:], np.random.default_rng(seed + i),
                            octaves=3, scale=2.0 + 0.25 * i)
                    for i in range(shape[0])])
    return out.astype(np.float32)


def all_fields(small: bool = True, seed: int = 0) -> Dict[str, np.ndarray]:
    """The five-dataset suite used across tests/benchmarks."""
    s = 1 if small else 4
    return {
        "hacc": hacc_like(n=(1 << 18) * s, seed=seed),
        "cesm": cesm_like((225 * s, 450 * s), seed=seed + 1),
        "hurricane": hurricane_like((25 * s, 125 * s, 125 * s), seed=seed + 2),
        "hurricane_cloud": hurricane_like((25 * s, 125 * s, 125 * s),
                                          seed=seed + 2, zero_concentrated=True),
        "nyx": nyx_like((64 * s,) * 3, seed=seed + 3),
        "qmcpack": qmcpack_like((12 * s, 24, 24, 24), seed=seed + 4),
    }
