"""One hardened runtime setup for every entrypoint and CI job.

Both related launch stacks ship this module in some form (HomebrewNLP's
``run.sh`` exports ``--xla_force_host_platform_device_count`` + allocator
tuning; bayespec's ``config.py`` wraps platform/XLA-flag/NaN-debug
setup); here it is one importable, testable function instead of N copies
of environment-variable strings across scripts and CI YAML:

    from repro.launch import env
    env.setup_runtime(env.RuntimeConfig(host_device_count=8,
                                        nan_debug=True))

`env_overrides` is the pure core (config -> environment dict, merging
and deduplicating ``XLA_FLAGS`` against whatever is already set), so
tests assert on it without touching the process environment.
`setup_runtime` applies it to ``os.environ`` — call it **before the
first JAX backend touch** (importing jax is fine; creating arrays is
not), since XLA reads these at backend initialization.  Importing this
module never mutates the environment.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import warnings
from typing import Dict, Optional, Tuple

#: flag names this module owns inside XLA_FLAGS: a RuntimeConfig value
#: replaces any pre-set copy of these (last writer wins), while every
#: unmanaged flag already in the environment is preserved verbatim.
#: The per-op ``--xla_gpu_enable_async_*`` switches were removed from
#: XLA (async collectives are on by default under the latency-hiding
#: scheduler) and XLA *aborts* on unknown flags, so they are listed here
#: only to scrub stale copies out of inherited environments.
_MANAGED = (
    "--xla_force_host_platform_device_count",
    "--xla_gpu_enable_latency_hiding_scheduler",
    "--xla_gpu_enable_async_all_gather",
    "--xla_gpu_enable_async_reduce_scatter",
    "--xla_gpu_enable_async_collective_permute",
)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """One runtime environment policy.

    ``host_device_count`` forces N fake CPU devices (the 8-fake-device
    SPMD tests and big local mesh sims).  ``async_collectives`` turns on
    XLA:GPU's latency-hiding scheduler + async collective ops (harmless
    no-ops on CPU).  ``nan_debug`` arms ``jax_debug_nans`` — jitted
    functions re-run op-by-op on a NaN and raise at the producing op.
    ``preallocate=False`` disables the GPU client's 75% up-front arena
    (the multi-process-per-host setting)."""
    host_device_count: Optional[int] = None
    async_collectives: bool = True
    nan_debug: bool = False
    preallocate: bool = True
    extra_xla_flags: Tuple[str, ...] = ()


def env_overrides(cfg: RuntimeConfig,
                  base_env: Optional[Dict[str, str]] = None
                  ) -> Dict[str, str]:
    """The environment-variable dict `cfg` resolves to, merged over
    ``base_env`` (default: the live ``os.environ``).  Pure — nothing is
    applied; returns only the keys that need setting."""
    base_env = dict(os.environ) if base_env is None else base_env
    flags = [f for f in base_env.get("XLA_FLAGS", "").split()
             if f and not f.startswith(_MANAGED)]
    if cfg.host_device_count is not None:
        assert cfg.host_device_count >= 1, cfg.host_device_count
        flags.append(f"--xla_force_host_platform_device_count="
                     f"{int(cfg.host_device_count)}")
    if cfg.async_collectives:
        # one flag, not the removed per-op --xla_gpu_enable_async_*
        # family: the scheduler overlaps collectives with compute, and
        # current XLA runs collectives async by default underneath it
        flags.append("--xla_gpu_enable_latency_hiding_scheduler=true")
    flags += list(cfg.extra_xla_flags)
    out: Dict[str, str] = {}
    joined = " ".join(flags)
    if joined != base_env.get("XLA_FLAGS", ""):
        out["XLA_FLAGS"] = joined
    if not cfg.preallocate:
        out["XLA_PYTHON_CLIENT_PREALLOCATE"] = "false"
    if cfg.nan_debug:
        out["JAX_DEBUG_NANS"] = "1"
    return out


def _backends_initialized() -> bool:
    xb = sys.modules.get("jax._src.xla_bridge")
    return bool(getattr(xb, "_backends", None))


def setup_runtime(cfg: Optional[RuntimeConfig] = None, **kw) -> RuntimeConfig:
    """Apply `cfg` (or ``RuntimeConfig(**kw)``) to ``os.environ`` and the
    live jax config.  Safe to call after ``import jax`` but before the
    first backend touch; warns (rather than silently misconfiguring) if
    backends already initialized — XLA flags set now won't take effect.
    Returns the config it applied, so entrypoints can log it."""
    if cfg is None:
        cfg = RuntimeConfig(**kw)
    overrides = env_overrides(cfg)
    if "XLA_FLAGS" in overrides and _backends_initialized():
        warnings.warn(
            "launch.env.setup_runtime: JAX backends are already "
            "initialized; XLA_FLAGS changes will not apply to this "
            "process. Call setup_runtime() before the first jax "
            "device/array operation.", RuntimeWarning, stacklevel=2)
    os.environ.update(overrides)
    if "jax" in sys.modules:
        # env var alone is too late once jax.config snapshotted it
        sys.modules["jax"].config.update("jax_debug_nans",
                                         bool(cfg.nan_debug))
    return cfg


def add_arguments(ap) -> None:
    """Attach the shared runtime flags to an entrypoint's argparser."""
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N fake CPU devices "
                         "(--xla_force_host_platform_device_count)")
    ap.add_argument("--nan-debug", action="store_true",
                    help="arm jax_debug_nans (raise at the producing op)")
    ap.add_argument("--no-async-collectives", action="store_true",
                    help="disable XLA:GPU async collectives + "
                         "latency-hiding scheduler")


def from_args(args) -> RuntimeConfig:
    """Build the `RuntimeConfig` an `add_arguments`-extended namespace
    selects."""
    return RuntimeConfig(
        host_device_count=args.host_devices,
        nan_debug=bool(args.nan_debug),
        async_collectives=not args.no_async_collectives)
