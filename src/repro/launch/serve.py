"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 32 --compressed-kv
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve.engine import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--compressed-kv", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len))
                         .astype(np.int32))
    scfg = ServeConfig(s_max=args.s_max, compressed_kv=args.compressed_kv,
                       temperature=args.temperature)
    t0 = time.perf_counter()
    toks = generate(params, cfg, prompt, args.new_tokens, scfg)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={args.batch} new={args.new_tokens} "
          f"compressed_kv={args.compressed_kv}")
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", np.asarray(toks)[0].tolist())


if __name__ == "__main__":
    main()
