"""Serving launcher: batched prefill + decode loop, optionally split
into disaggregated prefill/decode phases with the compressed KV handoff,
or run as a continuous-batching server over the paged compressed-KV pool.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 32 --compressed-kv

    # disaggregated: prefill -> Containers -> reshard -> decode
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --compressed-kv --disaggregate --wire-codec int8-block

    # continuous batching on the paged pool (implies --compressed-kv)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --continuous --requests 8 --max-batch 4 --pool-pages 32 \
        --evict-codec cusz
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import env as launch_env
from repro.models import model as M
from repro.serve.engine import (LAST_HANDOFF_STATS, LAST_RESHARD_STATS,
                                ServeConfig, decode_tokens, encode_handoff,
                                generate, prefill, reshard_caches)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--compressed-kv", action="store_true")
    ap.add_argument("--kv-codec", default="int8-block",
                    help="registry id of the in-memory KV codec")
    ap.add_argument("--disaggregate", action="store_true",
                    help="run prefill and decode as separate phases with "
                         "the compressed Container handoff between them")
    # fz is the default wire: on the reshard benchmark it ships >3x the
    # int8-block ratio (17.7x vs 1.9x vs raw) within ~2x of its
    # steady-state encode time
    ap.add_argument("--wire-codec", default="fz",
                    choices=["int8-block", "cusz", "fz", "lossless"],
                    help="prefill->decode handoff wire codec")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler on the paged "
                         "compressed-KV pool (implies --compressed-kv)")
    ap.add_argument("--requests", type=int, default=8,
                    help="[continuous] synthetic request count")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="[continuous] decode slots")
    ap.add_argument("--pool-pages", type=int, default=32,
                    help="[continuous] device page budget of the pool")
    ap.add_argument("--evict-codec", default=None,
                    choices=["int8-block", "cusz", "fz", "lossless"],
                    help="[continuous] pool eviction codec (default: the "
                         "armed dist-context hook, else cusz)")
    launch_env.add_arguments(ap)
    args = ap.parse_args()

    launch_env.setup_runtime(launch_env.from_args(args))
    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len))
                         .astype(np.int32))
    scfg = ServeConfig(
        s_max=args.s_max,
        compressed_kv=args.compressed_kv or args.continuous,
        kv_codec=args.kv_codec, temperature=args.temperature)

    if args.continuous:
        from repro.serve import scheduler as sched_mod
        reqs = [sched_mod.Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab,
                                size=int(rng.integers(
                                    4, args.prompt_len + 1))
                                ).astype(np.int32),
            max_new=int(rng.integers(2, args.new_tokens + 1)),
            arrival=int(rng.integers(0, max(1, args.requests // 2))))
            for i in range(args.requests)]
        schedcfg = sched_mod.SchedulerConfig(
            max_batch=args.max_batch, pool_pages=args.pool_pages,
            evict_codec=args.evict_codec)
        t0 = time.perf_counter()
        fin, sched = sched_mod.run_continuous(params, cfg, scfg,
                                              schedcfg, reqs)
        dt = time.perf_counter() - t0
        total = sum(len(f["tokens"]) for f in fin.values())
        st = sched.pool.stats()
        print(f"arch={cfg.name} continuous requests={len(fin)} "
              f"max_batch={args.max_batch} pool_pages={args.pool_pages}")
        print(f"decode_steps={sched.n_steps} preemptions="
              f"{sched.preemptions} evicted={st['evicted_pages']} "
              f"restored={st['restored_pages']} "
              f"peak_pages={st['peak_used']} "
              f"evict_codec={st['evict_codec']}")
        print(f"generated {total} tokens in {dt:.2f}s "
              f"({total / dt:.1f} tok/s incl. compile)")
        return

    t0 = time.perf_counter()
    if args.disaggregate:
        last, caches, plen = prefill(params, cfg, prompt, scfg)
        handoff = encode_handoff(caches, cfg, scfg, plen=plen,
                                 wire=args.wire_codec)
        caches = reshard_caches(handoff, cfg, scfg)
        toks = decode_tokens(params, cfg, scfg, last, caches,
                             handoff.plen, args.new_tokens)
    else:
        toks = generate(params, cfg, prompt, args.new_tokens, scfg)
    jax.block_until_ready(toks)  # repro-lint: allow[host-sync] wall-clock fence

    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={args.batch} new={args.new_tokens} "
          f"compressed_kv={args.compressed_kv}")
    if args.disaggregate:
        hs, rs = LAST_HANDOFF_STATS, LAST_RESHARD_STATS
        print(f"handoff wire={hs['wire']} containers={hs['containers']} "
              f"wire_bytes={hs['wire_bytes']} "
              f"raw_bf16_bytes={hs['raw_bf16_bytes']} "
              f"adopted_quantkv={rs['adopted_quantkv']} "
              f"decoded={rs['decoded']}")
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", np.asarray(toks)[0].tolist())


if __name__ == "__main__":
    main()
