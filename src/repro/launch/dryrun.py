import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before ANY other import (jax locks the
# device count on first init).  Everything below may import jax.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                       # noqa: E402
from repro.configs.shapes import SHAPES, applicable  # noqa: E402
from repro.dist import sharding as SH           # noqa: E402
from repro.dist.context import use_mesh, use_param_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M             # noqa: E402
from repro.optim import adamw                   # noqa: E402
from repro.train.train_step import TrainConfig, make_train_step  # noqa: E402

# TPU v5e constants for the roofline terms (EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (aggregate simplification)

# dry-run knobs per arch (microbatching / quantized moments / accum dtype)
ARCH_TRAIN = {
    "mamba2-1.3b": dict(microbatches=2),
    "moonshot-v1-16b-a3b": dict(microbatches=8),
    "deepseek-v2-236b": dict(microbatches=16, quant_moments=True),
    "jamba-1.5-large-398b": dict(microbatches=16, quant_moments=True,
                                 accum_bf16=True),
    "phi-3-vision-4.2b": dict(microbatches=4),
    "qwen3-32b": dict(microbatches=16),
    "qwen3-4b": dict(microbatches=4),
    "granite-34b": dict(microbatches=16),
    "qwen2.5-3b": dict(microbatches=4),
    "musicgen-medium": dict(microbatches=2),
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective in the (post-SPMD,
    per-partition) HLO.  Returns (total_bytes, per-op dict, count dict)."""
    per_op, counts = {}, {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims \
                else 1
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        per_op[op] = per_op.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return sum(per_op.values()), per_op, counts


def _cache_shard_rule(mesh, dp, long_ctx, path, leaf):
    """Decode/prefill cache layout: batch over dp; KV/latent sequence over
    'model' (or over 'data' for batch=1 long-context = SP); mamba state
    heads over 'model'."""
    names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
    ndim = len(leaf.shape)
    if "conv" in names:                       # [nP,B,K-1,conv_dim]
        spec = P(None, dp if not long_ctx else None, None,
                 "model" if leaf.shape[-1] % mesh.shape["model"] == 0
                 else None)
    elif "h" in names:                        # mamba [nP,B,H,N,P]
        spec = P(None, dp if not long_ctx else None,
                 "model" if leaf.shape[2] % mesh.shape["model"] == 0
                 else None, None, None)
    elif ndim == 4:                           # MLA latent [nP,B,S,R]
        spec = P(None, dp if not long_ctx else None,
                 "data" if long_ctx else "model", None)
    else:                                     # KV [nP,B,S,kv,hd]
        spec = P(None, dp if not long_ctx else None,
                 "data" if long_ctx else "model", None, None)
    return NamedSharding(mesh, spec)


def _extra_specs(cfg, B, S, dtype=jnp.bfloat16):
    extra = {}
    if cfg.n_prepend_embeds:
        extra["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prepend_embeds, cfg.d_model), dtype)
    if cfg.add_frame_embeds:
        extra["frame_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                     dtype)
    return extra or None


def input_specs(arch: str, shape_name: str, mesh, grad_compress="none",
                weight_compress="none", microbatch_override=None,
                kv_compress=False, a2a_compress="none"):
    """ShapeDtypeStruct stand-ins + NamedShardings for one cell.

    Returns (fn, args, in_shardings, donate_argnums, meta)."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    multi = "pod" in mesh.shape
    dp = SH.dp_axes(mesh)
    knobs = ARCH_TRAIN.get(arch, {})
    pshapes = M.param_shapes(cfg)
    pshard = SH.param_shardings(pshapes, mesh, fsdp=True)

    if shape.kind == "train":
        nmb = microbatch_override or knobs.get("microbatches", 1)
        if multi:
            nmb = min(nmb, 8)
        tcfg = TrainConfig(
            microbatches=nmb,
            grad_compress=grad_compress if multi else "none",
            weight_compress=weight_compress,
            a2a_compress=a2a_compress,
            npods=mesh.shape.get("pod", 1),
            accum_dtype=jnp.bfloat16 if knobs.get("accum_bf16") else jnp.float32,
            adamw=adamw.AdamWConfig(
                quantized_moments=knobs.get("quant_moments", False)))
        opt_shapes = jax.eval_shape(partial(adamw.init, cfg=tcfg.adamw),
                                    pshapes)
        oshard = SH.param_shardings(opt_shapes, mesh, fsdp=True)
        B, S = shape.global_batch, shape.seq_len
        podded = tcfg.grad_compress != "none" and tcfg.npods > 1
        if podded:
            toks = jax.ShapeDtypeStruct((tcfg.npods, B // tcfg.npods, S),
                                        jnp.int32)
        else:
            toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tshard = NamedSharding(mesh, SH.batch_spec(mesh, podded))
        if podded:
            extra = _extra_specs(cfg, B // tcfg.npods, S)
            if extra:
                extra = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((tcfg.npods,) + s.shape,
                                                   s.dtype), extra)
        else:
            extra = _extra_specs(cfg, B, S)
        step = make_train_step(cfg, tcfg)
        args = (pshapes, opt_shapes, toks) + ((extra,) if extra else ())
        eshard = jax.tree.map(lambda _: NamedSharding(
            mesh, P("pod", "data", None, None) if podded
            else P(dp, None, None)), extra) if extra else None
        in_sh = (pshard, oshard, tshard) + ((eshard,) if extra else ())
        out_sh = (NamedSharding(mesh, P()), pshard, oshard)
        return step, args, in_sh, (0, 1), {"tcfg": str(tcfg)}, out_sh

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        extra = _extra_specs(cfg, B, S)

        def prefill_fn(params, tokens, extra=None):
            logits, caches = M.forward(params, cfg, tokens, extra,
                                       collect_caches=True)
            return logits[:, -1, :], caches

        tshard = NamedSharding(mesh, P(dp, None))
        eshard = jax.tree.map(lambda _: NamedSharding(mesh, P(dp, None, None)),
                              extra) if extra else None
        args = (pshapes, toks) + ((extra,) if extra else ())
        in_sh = (pshard, tshard) + ((eshard,) if extra else ())
        # pin the produced caches to the decode-input layout (batch over dp,
        # cache seq over 'model') — without this XLA replicates the MLA
        # latent cache (deepseek prefill: 140 GiB/dev, §Perf iteration 4)
        out_caches = jax.eval_shape(
            lambda p, t, e: prefill_fn(p, t, e)[1], pshapes, toks, extra) \
            if extra else jax.eval_shape(
                lambda p, t: prefill_fn(p, t)[1], pshapes, toks)
        cshard = jax.tree_util.tree_map_with_path(
            partial(_cache_shard_rule, mesh, dp, False), out_caches)
        vshard = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        out_sh = (NamedSharding(mesh, P(dp, vshard)), cshard)
        return prefill_fn, args, in_sh, (), {}, out_sh

    # decode
    B, S = shape.global_batch, shape.seq_len
    long_ctx = shape_name.startswith("long")
    cache_shapes = jax.eval_shape(
        partial(M.init_caches, cfg, B, S, jnp.bfloat16, kv_compress))
    cshard = jax.tree_util.tree_map_with_path(
        partial(_cache_shard_rule, mesh, dp, long_ctx), cache_shapes)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tshard = NamedSharding(mesh, P(dp if not long_ctx else None, None))

    def decode_fn(params, token, caches, cache_len):
        return M.decode_step(params, cfg, token, caches, cache_len,
                             compressed_kv=kv_compress)

    args = (pshapes, tok, cache_shapes,
            jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (pshard, tshard, cshard, NamedSharding(mesh, P()))
    # matching output shardings let the donated caches alias in place
    # (without them the cache is double-buffered — §Perf iteration 5)
    vshard = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
    out_sh = (NamedSharding(mesh, P(dp if not long_ctx else None, None,
                                    vshard)), cshard)
    return decode_fn, args, in_sh, (2,), {"long_ctx": long_ctx}, out_sh


def model_flops(arch: str, shape_name: str) -> float:
    """6·N(_active)·D — the 'useful' FLOPs yardstick for §Roofline."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/slot


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             grad_compress: str = "none", out_dir: str = "results/dryrun",
             force: bool = False, save_hlo: bool = False,
             weight_compress: str = "none", microbatch_override=None,
             kv_compress: bool = False, a2a_compress: str = "none"):
    mesh_tag = "multipod" if multi_pod else "singlepod"
    tag = f"{arch}__{shape_name}__{mesh_tag}" + (
        f"__gc-{grad_compress}" if grad_compress != "none" else "") + (
        f"__wc-{weight_compress}" if weight_compress != "none" else "") + (
        f"__mb{microbatch_override}" if microbatch_override else "") + (
        "__kvc" if kv_compress else "") + (
        f"__a2a-{a2a_compress}" if a2a_compress != "none" else "")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip cached] {tag}")
        return json.load(open(path))
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if not applicable(shape, cfg):
        rec = {"cell": tag, "status": "skipped",
               "reason": "long_500k needs sub-quadratic sequence handling"}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip n/a] {tag}")
        return rec

    t0 = time.time()
    rec = {"cell": tag, "arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "grad_compress": grad_compress}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, donate, meta, out_sh = input_specs(
            arch, shape_name, mesh, grad_compress, weight_compress,
            microbatch_override, kv_compress, a2a_compress)
        # repro-lint: allow[jit-cache] dryrun lowers each cell once and
        # discards it; caching would pin every variant's executable
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        # must mirror the fsdp=True placement in input_specs: the int8
        # weight-gather keys off the 'data' axis in these specs
        pspecs = SH.param_specs(M.param_shapes(cfg), mesh, fsdp=True)
        with use_mesh(mesh), use_param_specs(pspecs):
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        cbytes, per_op, counts = collective_bytes(hlo)
        nchips = int(np.prod(list(mesh.shape.values())))
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(sum(v for k, v in ca.items()
                              if k.startswith("bytes accessed")))
        mf = model_flops(arch, shape_name)
        terms = {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": cbytes / ICI_BW,
        }
        dominant = max(terms, key=terms.get)
        rec.update(
            status="ok", meta=meta, n_chips=nchips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_GiB=mem.argument_size_in_bytes / 2**30,
                output_GiB=mem.output_size_in_bytes / 2**30,
                temp_GiB=mem.temp_size_in_bytes / 2**30,
                alias_GiB=mem.alias_size_in_bytes / 2**30,
                code_MiB=mem.generated_code_size_in_bytes / 2**20,
                per_device_total_GiB=(mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes) / 2**30,
            ),
            flops_per_device=flops_dev,
            hbm_bytes_per_device=bytes_dev,
            collective_bytes_per_device=cbytes,
            collective_by_op={k: v for k, v in sorted(per_op.items())},
            collective_counts=counts,
            roofline=dict(terms, dominant=dominant,
                          bound_s=max(terms.values())),
            model_flops_global=mf,
            useful_flops_ratio=(mf / (flops_dev * nchips)
                                if flops_dev else None),
        )
        if save_hlo:
            hpath = os.path.join(out_dir, tag + ".hlo.txt")
            with open(hpath, "w") as f:
                f.write(hlo)
            rec["hlo_path"] = hpath
        print(f"[ok] {tag}  compile={t_compile:.0f}s  "
              f"dom={dominant}({terms[dominant]*1e3:.1f}ms)  "
              f"mem={rec['memory']['per_device_total_GiB']:.2f}GiB/dev")
    except Exception as e:                        # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
    json.dump(rec, open(path, "w"), indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=[*SHAPES], help="shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8", "int16"])
    ap.add_argument("--weight-compress", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--kv-compress", action="store_true")
    ap.add_argument("--a2a-compress", default="none", choices=["none", "int8"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else sorted(configs.ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    ok = True
    for a in archs:
        for s in shapes:
            rec = run_cell(a, s, args.mesh == "multi", args.grad_compress,
                           args.out, args.force, args.save_hlo,
                           args.weight_compress, args.microbatches,
                           args.kv_compress, args.a2a_compress)
            ok &= rec.get("status") in ("ok", "skipped")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
