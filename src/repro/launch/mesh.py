"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run pins the fake device count *before*
any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: 'data' carries DP+FSDP; 'model' carries TP/EP; 'pod' carries
    cross-pod data parallelism (and the compressed gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """1-device mesh with the production axis names (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
