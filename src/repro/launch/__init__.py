from . import mesh  # noqa: F401  (dryrun NOT imported here: it sets XLA_FLAGS)
