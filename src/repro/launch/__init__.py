from . import env, mesh  # noqa: F401  (dryrun NOT imported here: it sets
#                                      XLA_FLAGS at import; env only mutates
#                                      the environment when setup_runtime()
#                                      is called)
