"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --steps 20 --batch 8 --seq 128

On the production mesh (--mesh single|multi) the same script shards
params/optimizer/batch per repro.dist.sharding and runs the jitted step;
--reduced + --mesh host runs a real loop on this container's single CPU
device.  --lower-only stops after compile (the dry-run path with real
shapes)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data import pipeline
from repro.dist import chaos, fault
from repro.dist import sharding as SH
from repro.dist.context import use_mesh, use_param_specs
from repro.io import checkpoint as ckpt_io
from repro.launch import env as launch_env
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8", "int16"])
    ap.add_argument("--weight-compress", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--quantized-moments", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--checkpoint-sync", action="store_true",
                    help="block the step loop on checkpoint writes "
                         "(default: async writer, bounded queue)")
    ap.add_argument("--checkpoint-shards", type=int, default=None,
                    help="per-host shard files per step "
                         "(default: jax.process_count())")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--chaos", default=None,
                    help="fault-injection spec, e.g. "
                         "'straggler:host=1,delay=0.05;writer:failures=2' "
                         "(see repro.dist.chaos.from_spec)")
    ap.add_argument("--mitigate", action="store_true",
                    help="arm the straggler MitigationPolicy (rebalance/"
                         "exclude flagged hosts, skip NaN steps)")
    launch_env.add_arguments(ap)
    args = ap.parse_args()

    launch_env.setup_runtime(launch_env.from_args(args))
    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = make_host_mesh() if args.mesh == "host" else \
        make_production_mesh(multi_pod=args.mesh == "multi")
    npods = mesh.shape.get("pod", 1)
    tcfg = TrainConfig(
        microbatches=args.microbatches, grad_compress=args.grad_compress,
        weight_compress=args.weight_compress,
        npods=npods,
        adamw=adamw.AdamWConfig(lr=args.lr,
                                quantized_moments=args.quantized_moments))
    podded = tcfg.grad_compress != "none" and npods > 1

    pspecs = SH.param_specs(M.param_shapes(cfg), mesh)
    pshard = SH.param_shardings(M.param_shapes(cfg), mesh)
    # repro-lint: allow[jit-cache] launch entrypoint: built once per process
    step_fn = jax.jit(make_train_step(cfg, tcfg),
                      in_shardings=(pshard, None, None), donate_argnums=(0, 1))

    with use_mesh(mesh), use_param_specs(pspecs):
        if args.lower_only:
            toks = jax.ShapeDtypeStruct(
                (npods, args.batch // npods, args.seq) if podded
                else (args.batch, args.seq), jnp.int32)
            opt_shapes = jax.eval_shape(
                lambda p: adamw.init(p, tcfg.adamw), M.param_shapes(cfg))
            # repro-lint: allow[jit-cache] --lower-only path: compiles once
            # then returns; nothing to cache
            c = jax.jit(make_train_step(cfg, tcfg)).lower(
                M.param_shapes(cfg), opt_shapes, toks).compile()
            print("lowered+compiled OK;", c.memory_analysis())
            return
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, pshard)
        opt = adamw.init(params, tcfg.adamw)
        start = 0
        if args.checkpoint_dir and ckpt_io.latest_step(args.checkpoint_dir) is not None:
            (params, opt), start = ckpt_io.load_checkpoint(
                args.checkpoint_dir, (params, opt))
            start += 1
            print(f"resumed from step {start}")
        writer = None if args.checkpoint_sync or not args.checkpoint_dir \
            else ckpt_io.AsyncWriter(max_pending=1, retries=2)
        nhosts = max(1, jax.process_count())
        chaos_cfg = (chaos.from_spec(args.chaos, nhosts=nhosts)
                     if args.chaos else None)
        policy = (fault.MitigationPolicy(
                      chaos_cfg.nhosts if chaos_cfg is not None else nhosts)
                  if args.mitigate else None)
        try:
            with chaos.use_chaos(chaos_cfg) as monkey:
                for step in range(start, args.steps):
                    batch = pipeline.global_batch(mesh, cfg.vocab, args.batch,
                                                  args.seq, step, podded=podded)
                    t0 = time.perf_counter()
                    loss, params, opt = step_fn(params, opt, batch)
                    loss.block_until_ready()  # repro-lint: allow[host-sync] step-time fence
                    dt = time.perf_counter() - t0
                    if monkey is not None:
                        shares = policy.shares if policy is not None else None
                        dt, host_dts = monkey.inject_step(step, dt, shares)
                        if policy is not None:
                            policy.observe(step, host_dts)
                    bad = ((monkey is not None and monkey.nan_burst(step))
                           or fault.loss_is_bad(loss))
                    if bad and policy is not None:
                        policy.on_bad_loss(step, float("nan"))
                        print(f"step {step:5d}  skipped (bad loss)")
                        continue
                    if step % 5 == 0 or step == args.steps - 1:
                        tps = args.batch * args.seq / dt
                        extra = ""
                        if policy is not None and (policy.excluded
                                                   or policy.events):
                            extra = (f"  shares={[round(float(s), 3) for s in policy.shares]}"
                                     f"  excluded={sorted(policy.excluded)}")
                        print(f"step {step:5d}  loss {float(loss):.4f}  "
                              f"{dt * 1e3:7.1f} ms  {tps:9.0f} tok/s{extra}")
                    if args.checkpoint_dir and (step + 1) % args.checkpoint_every == 0:
                        ckpt_io.save_checkpoint(
                            args.checkpoint_dir, step, (params, opt),
                            policy=ckpt_io.CheckpointPolicy(codec="cusz"),
                            nshards=args.checkpoint_shards, writer=writer)
        finally:
            if writer is not None:
                writer.close()     # drain + surface any async write failure


if __name__ == "__main__":
    main()
