"""Self-describing versioned container: the one wire/storage format every
codec produces and consumes.

A `Container` is a pytree of payload arrays plus a static `Header` that
records everything needed to decode — codec id, codec version, the source
array's dtype and shape, and the codec's static parameters (error bound,
bin count, block table, ...).  Nothing travels out-of-band: the historical
`(packed_dict, eb, shape)` caller-side plumbing (which silently dropped
the source dtype) is replaced by `codecs.decode(container)`.

The header is the pytree aux data, so containers cross `jax.jit`
boundaries with the header as a static cache key, and `jax.tree` utilities
treat the payload arrays as leaves.  `to_arrays`/`from_arrays` give the
host/storage view (npz-friendly field dict + JSON-able header).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, Mapping, Tuple

import jax
import numpy as np

CONTAINER_FORMAT = 1


class ChecksumError(ValueError):
    """A container's payload does not match its header checksum — the
    bytes were corrupted somewhere between `pack` and now."""


def _freeze(v):
    """Make a params value hashable (lists -> tuples, recursively)."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


def _jsonable(v):
    if isinstance(v, tuple):
        return [_jsonable(x) for x in v]
    return v


@dataclasses.dataclass(frozen=True)
class Header:
    """Static, hashable codec header (safe as a jit static argument)."""
    codec: str                                   # registry id, e.g. "cusz"
    version: int                                 # codec format version
    dtype: str                                   # source dtype name
    shape: Tuple[int, ...]                       # source shape
    params: Tuple[Tuple[str, Any], ...] = ()     # static codec params

    def param(self, key: str, default=None):
        for k, v in self.params:
            if k == key:
                return v
        return default

    def with_params(self, **kw) -> "Header":
        """Return a header with `kw` merged into params (replace on key).
        Params stay key-sorted — the canonical order `make_header` and
        `from_json` produce — so header equality (and the jit cache key)
        never depends on merge order."""
        items = [(k, v) for k, v in self.params if k not in kw]
        items += [(k, _freeze(v)) for k, v in kw.items()]
        return dataclasses.replace(self, params=tuple(sorted(items)))

    def without_params(self, *keys: str) -> "Header":
        """Return a header with `keys` removed from params.  `unpack`
        uses this to drop storage-only params (``checksum``) so device
        headers — and therefore jit cache keys — never vary with the
        stored bytes."""
        return dataclasses.replace(
            self, params=tuple((k, v) for k, v in self.params
                               if k not in keys))

    def to_json(self) -> Dict[str, Any]:
        return {"format": CONTAINER_FORMAT, "codec": self.codec,
                "version": self.version, "dtype": self.dtype,
                "shape": list(self.shape),
                "params": {k: _jsonable(v) for k, v in self.params}}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "Header":
        fmt = d.get("format", CONTAINER_FORMAT)
        if fmt > CONTAINER_FORMAT:
            raise ValueError(f"container format {fmt} is newer than this "
                             f"reader ({CONTAINER_FORMAT})")
        params = tuple(sorted((k, _freeze(v))
                              for k, v in dict(d.get("params", {})).items()))
        return Header(codec=str(d["codec"]), version=int(d["version"]),
                      dtype=str(d["dtype"]), shape=tuple(d["shape"]),
                      params=params)


def make_header(codec: str, version: int, like, **params) -> Header:
    """Header for a source array `like` (anything with .dtype/.shape)."""
    items = tuple(sorted((k, _freeze(v)) for k, v in params.items()))
    return Header(codec=codec, version=int(version),
                  dtype=np.dtype(like.dtype).name,
                  shape=tuple(int(s) for s in like.shape), params=items)


@jax.tree_util.register_pytree_node_class
class Container:
    """header (static) + payload (dict of arrays; the pytree leaves)."""

    __slots__ = ("header", "payload")

    def __init__(self, header: Header, payload: Dict[str, Any]):
        self.header = header
        self.payload = dict(payload)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        keys = tuple(sorted(self.payload))
        return tuple(self.payload[k] for k in keys), (self.header, keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        header, keys = aux
        return cls(header, dict(zip(keys, children)))

    # -- conveniences -------------------------------------------------------
    @property
    def nbytes(self) -> int:
        # repro-lint: allow[host-sync] size accounting is a host-side query
        return sum(np.asarray(jax.device_get(v)).nbytes
                   for v in self.payload.values())

    def replace(self, header: Header = None, payload=None) -> "Container":
        return Container(header if header is not None else self.header,
                         payload if payload is not None else self.payload)

    def __repr__(self):
        h = self.header
        return (f"Container(codec={h.codec!r}, v{h.version}, "
                f"dtype={h.dtype}, shape={h.shape}, "
                f"fields={sorted(self.payload)})")


# ---------------------------------------------------------------------------
# Payload integrity (crc32 checksums, stamped by `Codec.pack`)
# ---------------------------------------------------------------------------

def payload_crc32(payload: Mapping[str, Any]) -> int:
    """crc32 over the payload's canonical byte stream: sorted field names
    with each field's dtype, shape and raw bytes.  Covering the metadata
    too means a corrupted npz that swaps/reshapes a field — not just one
    that flips data bytes — also fails verification."""
    crc = 0
    for k in sorted(payload):
        # repro-lint: allow[host-sync] checksumming is a host/storage op
        arr = np.ascontiguousarray(np.asarray(jax.device_get(payload[k])))
        meta = f"{k}:{arr.dtype.str}:{arr.shape};".encode()
        crc = zlib.crc32(arr.tobytes(), zlib.crc32(meta, crc))
    return crc & 0xFFFFFFFF


def stamp_checksum(c: "Container") -> "Container":
    """Record the payload crc32 in the header (storage-form containers;
    every `pack` implementation ends with this)."""
    return c.replace(header=c.header.with_params(
        checksum=payload_crc32(c.payload)))


def verify_container(c: "Container") -> bool:
    """True when the payload matches the header checksum.  Containers
    without a checksum param (pre-checksum writers, device-form headers)
    verify trivially — absence of evidence is not corruption."""
    want = c.header.param("checksum")
    return want is None or payload_crc32(c.payload) == int(want)


def check_container(c: "Container") -> None:
    """`verify_container`, but raising `ChecksumError` with the mismatch
    detail — the restore-path spelling."""
    want = c.header.param("checksum")
    if want is None:
        return
    got = payload_crc32(c.payload)
    if got != int(want):
        raise ChecksumError(
            f"container payload checksum mismatch for codec "
            f"{c.header.codec!r} shape {c.header.shape}: header says "
            f"{int(want):#010x}, payload hashes to {got:#010x}")


# ---------------------------------------------------------------------------
# Shard reassembly (payload-space concatenation)
# ---------------------------------------------------------------------------

def concat_containers(parts, axis: int, field_axes: Mapping[str, Any]
                      ) -> Container:
    """Merge axis-sharded containers of one codec into a single container
    without decoding: each payload field is concatenated along the axis
    `field_axes` maps it to (None = shared/replicated field, taken from
    the first part).  Headers must agree except for ``shape[axis]``; the
    merged header sums that dim.  This is the elastic-restore wire path:
    what moves between hosts is the codec's compressed payload, never the
    decoded array."""
    h0 = parts[0].header
    # per-part checksums necessarily differ (different bytes) and do not
    # describe the merged payload — exclude them from the compatibility
    # check and drop them from the merged header
    def _cmp(h):
        return tuple((k, v) for k, v in h.params if k != "checksum")
    for p in parts[1:]:
        if p.header.codec != h0.codec or _cmp(p.header) != _cmp(h0):
            raise ValueError(f"cannot concat containers with differing "
                             f"codec/params: {p.header} vs {h0}")
    h0 = h0.without_params("checksum")
    shape = list(h0.shape)
    shape[axis] = sum(int(p.header.shape[axis]) for p in parts)
    payload: Dict[str, Any] = {}
    for field, fa in field_axes.items():
        vals = [p.payload[field] for p in parts]
        if fa is None:
            payload[field] = vals[0]
        elif all(isinstance(v, np.ndarray) for v in vals):
            payload[field] = np.concatenate(vals, axis=fa)
        else:
            payload[field] = jax.numpy.concatenate(
                [jax.numpy.asarray(v) for v in vals], axis=fa)
    return Container(dataclasses.replace(h0, shape=tuple(shape)), payload)


# ---------------------------------------------------------------------------
# Host / storage view
# ---------------------------------------------------------------------------

def to_arrays(c: Container) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    """(header-json, {field: numpy array}) — the npz/storage form."""
    # repro-lint: allow[host-sync] to_arrays() is the npz/storage boundary
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in c.payload.items()}
    return c.header.to_json(), arrays


def from_arrays(header, arrays: Mapping[str, Any]) -> Container:
    """Rebuild a container from `to_arrays` output (header json or Header)."""
    h = header if isinstance(header, Header) else Header.from_json(header)
    return Container(h, dict(arrays))
