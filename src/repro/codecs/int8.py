"""Narrow-integer PREQUANT codecs (the paper's d° = round(d/(2·eb)) with
scale-derived bounds) — the canonical home of the int8/int16 quantization
math every integer surface shares:

  * `Int8Codec` ("int8" / "int16"): one scale per tensor.  The gradient
    pod-compression path (`core.gradient`) and per-tensor checkpoint
    leaves use this.
  * `BlockInt8Codec` ("int8-block"): blockwise scales along one axis.
    The KV cache (seq axis), the FSDP weight gather (feature axis) and
    the MoE all-to-all wire format are all instances of this codec.

The effective absolute error bound of either codec is scale/2 per
element, recorded by construction (scale lives in the payload because it
is data-dependent; axis/block/bits are static header params).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .base import Codec, register
from .container import Container

_QDTYPES = {8: jnp.int8, 16: jnp.int16}


def qmax_of(bits: int) -> int:
    return 2 ** (bits - 1) - 1


# ---------------------------------------------------------------------------
# Shared quantization math (single implementation; every integer surface
# in the repo routes through these).
# ---------------------------------------------------------------------------

def quantize(x: jax.Array, qmax: float, qdtype,
             scale: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric quantization.  `scale` overrides the derived
    amax/qmax scale (shared-scale collectives pass a pre-reduced one)."""
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)) / qmax, 1e-30)
    q = jnp.clip(jnp.rint(xf / scale), -qmax, qmax).astype(qdtype)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array,
               dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _split(x: jax.Array, axis: int, block: int) -> jax.Array:
    s = x.shape[axis]
    assert s % block == 0, (x.shape, axis, block)
    return x.reshape(x.shape[:axis] + (s // block, block)
                     + x.shape[axis + 1:])


def _merge(xb: jax.Array, axis: int) -> jax.Array:
    return xb.reshape(xb.shape[:axis]
                      + (xb.shape[axis] * xb.shape[axis + 1],)
                      + xb.shape[axis + 2:])


def block_quantize(x: jax.Array, axis: int, block: int,
                   qmax: float = 127.0) -> Tuple[jax.Array, jax.Array]:
    """Blockwise int8 quantization along `axis` (length must divide into
    `block`-sized groups).  Returns (q int8 of x.shape, scale f32 of
    x.shape with the `axis` dim shrunk to n_blocks)."""
    axis = axis % x.ndim
    xb = _split(x, axis, block)
    amax = jnp.max(jnp.abs(xb), axis=axis + 1, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-30).astype(jnp.float32)
    q = jnp.clip(jnp.rint(xb.astype(jnp.float32) / scale), -qmax, qmax
                 ).astype(jnp.int8)
    return _merge(q, axis), jnp.squeeze(scale, axis + 1)


def block_dequantize(q: jax.Array, scale: jax.Array, axis: int, block: int,
                     dtype=jnp.float32) -> jax.Array:
    axis = axis % q.ndim
    qb = _split(q, axis, block)
    x = qb.astype(jnp.float32) * jnp.expand_dims(scale, axis + 1)
    return _merge(x.astype(dtype), axis)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Int8Codec(Codec):
    """Per-tensor narrow-int codec ("int8" / "int16" by `bits`)."""
    bits: int = 8
    version = 1

    @property
    def name(self) -> str:
        return f"int{self.bits}"

    @property
    def qmax(self) -> int:
        return qmax_of(self.bits)

    @property
    def qdtype(self):
        return _QDTYPES[self.bits]

    def encode(self, x, *, cfg=None) -> Container:
        q, scale = quantize(x, float(self.qmax), self.qdtype)
        return Container(self._header(x, bits=self.bits),
                         {"q": q, "scale": scale})

    def decode(self, c: Container, *, like=None) -> jax.Array:
        c = self.unpack(c)
        y = dequantize(c.payload["q"], c.payload["scale"])
        return self._finish(y, c.header, like)

    # -- sharded encode: split-stable because the scale is pinned globally
    def shard_axis(self, shape, nshards: int):
        from repro.dist.sharding import even_shard_axis
        return even_shard_axis(shape, nshards)

    def encode_parts(self, x, axis: int, nshards: int):
        """Per-slice containers that decode bit-identically to a whole-
        tensor encode: the per-tensor scale is derived once from the full
        tensor and pinned for every slice (each part stores a copy)."""
        xf = jnp.asarray(x).astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)) / float(self.qmax), 1e-30)
        step = x.shape[axis] // nshards
        idx = [slice(None)] * x.ndim
        parts = []
        for h in range(nshards):
            idx[axis] = slice(h * step, (h + 1) * step)
            sl = jnp.asarray(x)[tuple(idx)]
            q, _ = quantize(sl, float(self.qmax), self.qdtype, scale=scale)
            parts.append(Container(self._header(sl, bits=self.bits),
                                   {"q": q, "scale": scale}))
        return parts

    def payload_axes(self, axis: int):
        return {"q": axis, "scale": None}       # scale is the shared pin


@dataclasses.dataclass(frozen=True)
class BlockInt8Codec(Codec):
    """Blockwise int8 codec: one f32 scale per `block` elements along
    `axis`.  KV caches use (axis=seq, block=128); FSDP weight gathers and
    the MoE all-to-all use (axis=-1, block=feature-block)."""
    axis: int = -1
    block: int = 128
    name = "int8-block"
    version = 1

    def encode(self, x, *, cfg=None) -> Container:
        axis = self.axis % x.ndim
        q, scale = block_quantize(x, axis, self.block)
        return Container(self._header(x, axis=axis, block=self.block),
                         {"q": q, "scale": scale})

    def decode(self, c: Container, *, like=None) -> jax.Array:
        c = self.unpack(c)
        y = block_dequantize(c.payload["q"], c.payload["scale"],
                             int(c.header.param("axis")),
                             int(c.header.param("block")))
        return self._finish(y, c.header, like)

    # -- sharded encode: split-stable as long as no scale block straddles
    # a slice boundary (block amaxes are local to each slice then)
    def shard_axis(self, shape, nshards: int):
        from repro.dist.sharding import even_shard_axis
        qaxis = self.axis % len(shape) if shape else None
        if qaxis is None or int(shape[qaxis]) % self.block != 0:
            return None                  # whole-tensor encode would assert
        best = None
        for i, s in enumerate(shape):
            aligned = self.block if i == qaxis else 1
            if even_shard_axis((s,), nshards, multiple_of=aligned) == 0:
                if best is None or int(s) > int(shape[best]):
                    best = i
        return best

    def payload_axes(self, axis: int):
        # scale mirrors the source rank (quantized axis shrunk /block),
        # so the concat axis index is the same for both fields
        return {"q": axis, "scale": axis}


register("int8", lambda **kw: Int8Codec(bits=8, **kw))
register("int16", lambda **kw: Int8Codec(bits=16, **kw))
register("int8-block", lambda **kw: BlockInt8Codec(**kw))
