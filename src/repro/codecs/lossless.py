"""Identity codec: raw arrays behind the same `Codec` contract.

Exists so every checkpoint leaf — compressed or not — goes through one
container format, and so non-native dtypes survive storage: npz writes
bfloat16 but loads it back as raw void bytes, so `pack` bitcasts any
non-builtin dtype to a same-width unsigned view and `unpack` restores it
from the header's recorded dtype.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .base import Codec, register
from .container import Container, stamp_checksum

_UINT_OF = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


@dataclasses.dataclass(frozen=True)
class LosslessCodec(Codec):
    name = "lossless"
    version = 1

    def encode(self, x, *, cfg=None) -> Container:
        return Container(self._header(x), {"data": jnp.asarray(x)})

    def decode(self, c: Container, *, like=None) -> jax.Array:
        c = self.unpack(c)
        return self._finish(jnp.asarray(c.payload["data"]), c.header, like)

    def pack(self, c: Container) -> Container:
        if c.header.param("packed"):
            return c
        # repro-lint: allow[host-sync] pack() IS the device->storage boundary
        arr = np.asarray(jax.device_get(c.payload["data"]))
        if arr.dtype.kind not in "biufc":          # e.g. ml_dtypes bfloat16
            arr = arr.view(_UINT_OF[arr.dtype.itemsize])
        return stamp_checksum(
            Container(c.header.with_params(packed=True), {"data": arr}))

    def unpack(self, c: Container) -> Container:
        if not c.header.param("packed"):
            return c
        arr = np.asarray(c.payload["data"])
        want = np.dtype(c.header.dtype)
        if arr.dtype != want and arr.dtype.itemsize == want.itemsize:
            arr = arr.view(want)                   # undo the storage bitcast
        return Container(
            c.header.with_params(packed=False).without_params("checksum"),
            {"data": jnp.asarray(arr)})

    # -- sharded encode: identity is trivially split-stable
    def shard_axis(self, shape, nshards: int):
        from repro.dist.sharding import even_shard_axis
        return even_shard_axis(shape, nshards)

    def payload_axes(self, axis: int):
        return {"data": axis}


register("lossless", lambda **kw: LosslessCodec(**kw))
