"""The `Codec` protocol and the string-keyed codec registry.

Every compression surface in the repo implements one contract:

    encode(x, *, cfg=None)      -> Container        (device pytree + header)
    decode(container, *, like)  -> jax.Array        (header-honoring inverse)
    pack(container)             -> Container        (host/storage form)
    unpack(container)           -> Container        (back to device form)

`decode` needs nothing but the container — dtype, shape and every codec
parameter ride in the header.  `like` optionally overrides the output
dtype/shape (elastic restore).  `pack` defaults to pulling the payload to
host numpy; codecs with a denser storage form (cuSZ's per-chunk word
packing) override it, and `decode` transparently unpacks packed input.

Registry: `get("cusz")`, `get("int8")`, `get("int8-block", axis=2)`, ...
Construction kwargs configure the codec instance; encode/decode stay
config-free so a codec object is a static, hashable policy.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .container import (ChecksumError, Container, Header, check_container,
                        make_header, stamp_checksum, verify_container)


class Codec:
    """Base class: subclasses set `name`/`version`, implement encode/decode.

    Instances must be cheap, immutable and hashable (frozen dataclasses):
    they are used as static jit cache keys by consumers.
    """

    name: str = "?"
    version: int = 1
    #: Sharded-encode capability declaration, checked statically by
    #: repro-lint (R3): a codec either overrides `shard_axis` +
    #: `payload_axes` (split-stable along some axis) or sets
    #: ``shardable = False`` to opt out explicitly — the checkpoint
    #: planner then keeps each leaf whole on one owner shard.
    shardable: bool = True

    # -- required -----------------------------------------------------------
    def encode(self, x, *, cfg=None) -> Container:
        raise NotImplementedError

    def decode(self, c: Container, *, like=None) -> jax.Array:
        raise NotImplementedError

    # -- storage form (override when a denser packing exists) ---------------
    def pack(self, c: Container) -> Container:
        """Host/storage form: numpy payload, `packed=True` plus a payload
        crc32 (``checksum``) in the header."""
        if c.header.param("packed"):
            return c
        # repro-lint: allow[host-sync] pack() IS the device->storage boundary
        payload = {k: np.asarray(jax.device_get(v))
                   for k, v in c.payload.items()}
        return stamp_checksum(
            Container(c.header.with_params(packed=True), payload))

    def unpack(self, c: Container) -> Container:
        """Inverse of `pack`: device arrays, storage-only params dropped
        (``checksum`` must not leak into device headers, which serve as
        static jit cache keys)."""
        if not c.header.param("packed"):
            return c
        payload = {k: jnp.asarray(v) for k, v in c.payload.items()}
        return Container(
            c.header.with_params(packed=False).without_params("checksum"),
            payload)

    # -- shared helpers -----------------------------------------------------
    def _header(self, x, **params) -> Header:
        return make_header(self.name, self.version, x, **params)

    def _finish(self, y: jax.Array, header: Header, like) -> jax.Array:
        """Cast/reshape decode output per the header (or `like` override)."""
        if like is not None:
            return y.reshape(tuple(like.shape)).astype(like.dtype)
        return y.reshape(header.shape).astype(np.dtype(header.dtype))

    def stored_nbytes(self, c: Container) -> int:
        """Bytes this container occupies in storage form."""
        return self.pack(c).nbytes

    def valid(self, c: Container) -> bool:
        """Whether this (device-form) container decodes faithfully.
        Codecs with capacity limits override (cuSZ: outlier overflow)."""
        return True

    # -- sharded encode (the per-host checkpoint write path) ----------------
    #
    # A codec is *split-stable* along an axis when encoding each slice
    # independently decodes to exactly what encoding the whole tensor
    # would — so a sharded save is bit-identical to a single-file save.
    # Elementwise codecs (lossless, int8 with a pinned global scale,
    # int8-block with block-aligned splits) qualify; chunked-transform
    # codecs (cusz, zfp: prediction/blocking crosses slice boundaries)
    # do not and return None, which makes the checkpoint planner assign
    # the whole leaf to one owner shard instead of splitting it.

    def shard_axis(self, shape, nshards: int):
        """Axis to split a `shape` tensor over `nshards` hosts, or None
        when this codec cannot split it without changing the decode."""
        return None

    def encode_parts(self, x, axis: int, nshards: int):
        """Encode `x` as `nshards` independent slice containers along
        `axis`.  Must be bit-equivalent to `encode(x)` on decode; codecs
        with cross-slice state (per-tensor scales) override to pin it."""
        step = x.shape[axis] // nshards
        idx = [slice(None)] * x.ndim
        parts = []
        for h in range(nshards):
            idx[axis] = slice(h * step, (h + 1) * step)
            parts.append(self.encode(x[tuple(idx)]))
        return parts

    def payload_axes(self, axis: int):
        """Per-field concat axis for reassembling slice containers along
        source `axis` in payload space (`container.concat_containers`),
        or None when payload-space merge is unsupported — the loader
        then decodes each part and concatenates values."""
        return None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[..., Codec]] = {}
_DEFAULTS: Dict[str, Codec] = {}      # cache for kwarg-less lookups


def register(name: str, factory: Callable[..., Codec]) -> None:
    """Register a codec factory under a string key.  `factory(**kwargs)`
    must return a configured `Codec` instance."""
    _FACTORIES[name] = factory
    _DEFAULTS.pop(name, None)


def get(name: str, **kwargs) -> Codec:
    """Look up a configured codec: `get("cusz", eb=1e-4, eb_mode="valrel")`.
    Without kwargs the default-configured instance is cached and shared."""
    if name not in _FACTORIES:
        raise KeyError(f"unknown codec {name!r}; registered: {names()}")
    if not kwargs:
        if name not in _DEFAULTS:
            _DEFAULTS[name] = _FACTORIES[name]()
        return _DEFAULTS[name]
    return _FACTORIES[name](**kwargs)


def names() -> List[str]:
    return sorted(_FACTORIES)


def get_block_codec(name: str, *, axis: int, block: int) -> Codec:
    """Look up a codec that quantizes blockwise along one axis (the wire/
    cache format the KV cache and the a2a reshard need).  Raises a clear
    error for registry ids that don't take axis/block configuration."""
    try:
        return get(name, axis=axis, block=block)
    except TypeError:
        raise ValueError(
            f"codec {name!r} is not a blockwise wire codec: it must accept "
            f"axis=/block= configuration (e.g. 'int8-block')") from None


def decode(c: Container, *, like=None, verify: bool = False,
           **codec_kwargs) -> jax.Array:
    """Decode a container by its own header — the codec id, version, dtype
    and shape all come from the container; nothing else is required.
    `codec_kwargs` configure the decode-side codec (e.g. kernel_impl).

    ``verify=True`` checks the payload against the header's crc32 before
    decoding and raises `ChecksumError` on mismatch — the restore paths
    (checkpoint load, wire arrival) opt in; hot device-side paths skip
    the host-side hash."""
    if verify:
        check_container(c)
    codec = get(c.header.codec, **codec_kwargs)
    if c.header.version > codec.version:
        raise ValueError(
            f"container written by {c.header.codec} v{c.header.version}, "
            f"but installed codec is v{codec.version}")
    return codec.decode(c, like=like)
