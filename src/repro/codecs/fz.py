"""FZ ("fz"): Lorenzo prediction + fused bit-plane shuffle with
zero-plane elision (FZ-GPU, arXiv 2304.12557), behind the `Codec`
protocol.

Where "cusz" pays for a histogram, a device codebook build and a
scatter-heavy Huffman deflate, fz's lossless stage is a single fused
kernel pass (zigzag map + per-chunk bitshuffle) plus a cheap nonzero
reduction — the wire/eviction throughput class.  The decode needs no
host-side prep at all (no codebook or max-length readback), so arrival
paths stay free of host syncs.

The codec composes the staged pipeline's dict surface directly
(`staged_compress` / `staged_decompress` / `StagedPipeline` pack/unpack)
— no blob NamedTuple involved, demonstrating the second supported codec
shape on top of the stage registries.

Defaults target the KV-wire operating point: valrel 1e-2 bound,
outlier_frac=1.0 (no capacity overflow on activation-scale data) and a
512-symbol chunk so the plane elision granularity matches head-dim-sized
slabs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import compressor as CZ

from .base import Codec, register
from .container import Container, stamp_checksum


@dataclasses.dataclass(frozen=True)
class FzCodec(Codec):
    cfg: CZ.CompressorConfig = CZ.CompressorConfig(
        eb=1e-2, eb_mode="valrel", chunk_size=512, outlier_frac=1.0,
        encoder="bitshuffle")
    name = "fz"
    version = 1
    # Lorenzo prediction crosses slice boundaries (same reason as cusz).
    shardable = False

    @staticmethod
    def make(cfg: Optional[CZ.CompressorConfig] = None, **kw) -> "FzCodec":
        if cfg is None:
            kw.setdefault("eb", 1e-2)
            kw.setdefault("eb_mode", "valrel")
            kw.setdefault("chunk_size", 512)
            kw.setdefault("outlier_frac", 1.0)
            kw.setdefault("encoder", "bitshuffle")
            cfg = CZ.CompressorConfig(**kw)
        elif kw:
            cfg = dataclasses.replace(cfg, **kw)
        if cfg.encoder != "bitshuffle":
            cfg = dataclasses.replace(cfg, encoder="bitshuffle")
        return FzCodec(cfg=cfg)

    def _pipe(self, cfg: CZ.CompressorConfig) -> CZ.StagedPipeline:
        return CZ.StagedPipeline.from_cfg(cfg)

    # -- protocol -----------------------------------------------------------
    def encode(self, x, *, cfg: Optional[CZ.CompressorConfig] = None
               ) -> Container:
        c = cfg if cfg is not None else self.cfg
        x32 = jnp.asarray(x, jnp.float32) \
            if jnp.asarray(x).dtype != jnp.float32 else jnp.asarray(x)
        payload, eb = CZ.staged_compress(x32, c)
        extra = {} if c.predictor == "lorenzo" else {"predictor": c.predictor}
        header = self._header(
            x, eb=float(eb), nbins=int(c.nbins), chunk_size=int(c.chunk_size),
            block=tuple(c.block_for(x32.ndim)),
            outlier_frac=float(c.outlier_frac), **extra)
        return Container(header, payload)

    def decode(self, c: Container, *, like=None) -> jax.Array:
        c = self.unpack(c)
        h = c.header
        cfg = self._decode_cfg(h)
        payload = {k: jnp.asarray(v) for k, v in c.payload.items()}
        y = CZ.staged_decompress(payload, cfg, float(h.param("eb")), h.shape)
        return self._finish(y, h, like)

    # -- storage form: zero-plane elision happens here ----------------------
    def pack(self, c: Container) -> Container:
        if c.header.param("packed"):
            return c
        packed = self._pipe(self._decode_cfg(c.header)).pack(dict(c.payload))
        return stamp_checksum(Container(c.header.with_params(packed=True),
                                        packed))

    def unpack(self, c: Container) -> Container:
        if not c.header.param("packed"):
            return c
        h = c.header
        cfg = self._decode_cfg(h)
        payload = self._pipe(cfg).unpack(dict(c.payload), cfg, h.shape)
        return Container(
            h.with_params(packed=False).without_params("checksum"), payload)

    def valid(self, c: Container) -> bool:
        """False when the sparse outlier store overflowed its capacity."""
        if c.header.param("packed"):
            return True                       # pack() is post-validation
        return self._pipe(self._decode_cfg(c.header)).valid(dict(c.payload))

    # -- helpers ------------------------------------------------------------
    def _decode_cfg(self, h) -> CZ.CompressorConfig:
        return CZ.CompressorConfig(
            eb=float(h.param("eb")), eb_mode="abs",
            nbins=int(h.param("nbins")),
            chunk_size=int(h.param("chunk_size")),
            block=tuple(h.param("block")),
            outlier_frac=float(h.param("outlier_frac")),
            predictor=str(h.param("predictor", "lorenzo")),
            encoder="bitshuffle",
            kernel_impl=self.cfg.kernel_impl)


register("fz", FzCodec.make)
