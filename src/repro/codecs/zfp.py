"""cuZFP-like fixed-rate codec behind the `Codec` protocol.

Wraps `core.zfp_like`'s split transform halves: encode stores the
plane-truncated negabinary coefficients + per-block exponents; decode
inverts.  >3D inputs are treated as a batch of 3D fields (paper: QMCPACK)
exactly like `zfp_like.compress_decompress`.

The payload arrays are kept at 32-bit lane width (the fixed-rate
truncation is a bitmask, not a bit-packer — documented simplification,
DESIGN.md §6), so `stored_nbytes` reports the *logical* fixed-rate size:
`planes` bits per coefficient + 16 bits per block of header, matching the
achieved-bitrate accounting the quality benchmarks use.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zfp_like as Z
from repro.core.dualquant import block_merge, block_split, pad_to_blocks

from .base import Codec, register
from .container import Container


@dataclasses.dataclass(frozen=True)
class ZfpCodec(Codec):
    rate_bits: float = 12.0
    name = "zfp"
    version = 1
    # 4^d transform blocks span slice boundaries after padding; splitting
    # changes block alignment and thus the decode, so no sharded encode.
    shardable = False

    @property
    def planes(self) -> int:
        return max(1, int(round(self.rate_bits)))

    def encode(self, x, *, cfg=None) -> Container:
        xf = jnp.asarray(x, jnp.float32)
        nd = min(xf.ndim, 3)
        if xf.ndim > 3:
            lead = int(np.prod(xf.shape[:-3]))
            xr = xf.reshape((lead,) + xf.shape[-3:])
            xb = block_split(pad_to_blocks(xr, (1, 4, 4, 4)), (1, 4, 4, 4))
            xb = jnp.squeeze(xb, axis=-4)          # drop the size-1 block dim
        else:
            xb = block_split(pad_to_blocks(xf, (4,) * nd), (4,) * nd)
        u, e = Z.encode_blocks(xb, self.planes, nd)
        return Container(self._header(x, planes=self.planes, nd=nd),
                         {"u": u, "e": e})

    def decode(self, c: Container, *, like=None) -> jax.Array:
        c = self.unpack(c)
        h = c.header
        nd = int(h.param("nd"))
        rec = Z.decode_blocks(jnp.asarray(c.payload["u"]),
                              jnp.asarray(c.payload["e"]), nd)
        shape = h.shape
        if len(shape) > 3:
            lead = int(np.prod(shape[:-3]))
            rec = jnp.expand_dims(rec, axis=-4)    # restore size-1 block dim
            full = block_merge(rec, (1, 4, 4, 4))
            y = full[tuple(slice(0, s)
                           for s in (lead,) + shape[-3:])].reshape(shape)
        else:
            full = block_merge(rec, (4,) * nd)
            y = full[tuple(slice(0, s) for s in shape)]
        return self._finish(y, h, like)

    def stored_nbytes(self, c: Container) -> int:
        u = c.payload["u"]
        planes = int(c.header.param("planes"))
        nd = int(c.header.param("nd"))
        nblocks = int(np.prod(u.shape[:-nd]))
        bits = planes * int(np.prod(u.shape)) + 16 * nblocks
        return -(-bits // 8)

    def achieved_bitrate(self, c: Container) -> float:
        """Bits per source value at the stored fixed rate."""
        nd = int(c.header.param("nd"))
        return int(c.header.param("planes")) + 16.0 / (4 ** nd)


register("zfp", lambda **kw: ZfpCodec(**kw))
