"""The cuSZ pipeline (dual-quant + canonical Huffman) behind the `Codec`
protocol.

`encode` resolves the error bound (valrel -> abs) on the host, runs the
jitted pipeline (kernel dispatch policy threaded via
`CompressorConfig.kernel_impl` / the ambient `kernels.dispatch` policy),
and records every decode-side parameter in the header: the resolved abs
eb, nbins, chunk size, the resolved Lorenzo block and the outlier
capacity fraction.  The source dtype/shape ride in the header too, so a
bf16 tensor comes back as bf16 — the historical `(packed, eb)` +
caller-side shape/dtype plumbing is gone.

`pack` switches the payload to the per-chunk word-packed host form
(`compressor.pack_blob`); `decode` accepts either form.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import compressor as CZ

from .base import Codec, register
from .container import Container, stamp_checksum


@dataclasses.dataclass(frozen=True)
class CuszCodec(Codec):
    cfg: CZ.CompressorConfig = CZ.CompressorConfig()
    name = "cusz"
    # v2: payload carries the per-subchunk gap arrays (gap_bits/gap_syms)
    # + sub_size in the header, enabling the parallel two-phase inflate;
    # gap-less v1 containers still decode via the sequential path
    version = 2
    # Lorenzo prediction crosses slice boundaries: encoding slices
    # independently changes the decode, so sharded saves keep each
    # leaf whole on one owner shard.
    shardable = False

    @staticmethod
    def make(cfg: Optional[CZ.CompressorConfig] = None, **kw) -> "CuszCodec":
        if cfg is None:
            cfg = CZ.CompressorConfig(**kw)
        elif kw:
            cfg = dataclasses.replace(cfg, **kw)
        return CuszCodec(cfg=cfg)

    # -- protocol -----------------------------------------------------------
    def encode(self, x, *, cfg: Optional[CZ.CompressorConfig] = None
               ) -> Container:
        c = cfg if cfg is not None else self.cfg
        x32 = jnp.asarray(x, jnp.float32) \
            if jnp.asarray(x).dtype != jnp.float32 else jnp.asarray(x)
        blob, eb = CZ.compress(x32, c)
        # "predictor" is recorded only when non-default so lorenzo headers
        # stay bit-identical to every container written before stages
        extra = {} if c.predictor == "lorenzo" else {"predictor": c.predictor}
        header = self._header(
            x, eb=float(eb), nbins=int(c.nbins), chunk_size=int(c.chunk_size),
            sub_size=int(c.sub_size), block=tuple(c.block_for(x32.ndim)),
            outlier_frac=float(c.outlier_frac), **extra)
        return Container(header, _blob_payload(blob))

    def decode(self, c: Container, *, like=None) -> jax.Array:
        c = self.unpack(c)
        h = c.header
        cfg = self._decode_cfg(h)
        blob = _payload_blob(c.payload, asarray=True)
        y = CZ.decompress(blob, cfg, float(h.param("eb")), h.shape)
        return self._finish(y, h, like)

    # -- storage form: per-chunk word packing -------------------------------
    def pack(self, c: Container) -> Container:
        if c.header.param("packed"):
            return c
        blob = _payload_blob(c.payload)
        return stamp_checksum(Container(c.header.with_params(packed=True),
                                        CZ.pack_blob(blob)))

    def unpack(self, c: Container) -> Container:
        if not c.header.param("packed"):
            return c
        blob = CZ.unpack_blob(dict(c.payload))
        return Container(
            c.header.with_params(packed=False).without_params("checksum"),
            _blob_payload(blob))

    def valid(self, c: Container) -> bool:
        """False when the sparse outlier store overflowed its capacity
        (the blob would decode lossily beyond the bound)."""
        if c.header.param("packed"):
            return True                       # pack() is post-validation
        # repro-lint: allow[host-sync] one scalar readback per validity check
        n_out = int(jax.device_get(c.payload["n_outliers"]))
        return n_out <= int(c.payload["out_idx"].shape[0])

    # -- helpers ------------------------------------------------------------
    def _decode_cfg(self, h) -> CZ.CompressorConfig:
        return CZ.CompressorConfig(
            eb=float(h.param("eb")), eb_mode="abs",
            nbins=int(h.param("nbins")),
            chunk_size=int(h.param("chunk_size")),
            # v1 headers predate the gap arrays; the default is inert
            # there (a gap-less blob decodes sequentially regardless)
            sub_size=int(h.param("sub_size", 128)),
            block=tuple(h.param("block")),
            outlier_frac=float(h.param("outlier_frac")),
            predictor=str(h.param("predictor", "lorenzo")),
            kernel_impl=self.cfg.kernel_impl)


def _blob_payload(blob: CZ.CompressedBlob) -> dict:
    """Blob -> payload dict; None fields (gap-less v1 blobs) are omitted
    so the payload stays an arrays-only mapping."""
    return {f: v for f, v in zip(CZ.CompressedBlob._fields, blob)
            if v is not None}


def _payload_blob(payload, asarray: bool = False) -> CZ.CompressedBlob:
    """Payload dict -> blob; gap fields absent on v1 payloads stay None."""
    conv = jnp.asarray if asarray else (lambda v: v)
    return CZ.CompressedBlob(**{
        f: conv(payload[f]) if f in payload else None
        for f in CZ.CompressedBlob._fields})


register("cusz", CuszCodec.make)
