"""`repro.codecs` — one codec API for every compression surface.

    from repro import codecs

    c = codecs.get("cusz", eb=1e-4, eb_mode="valrel").encode(x)
    y = codecs.decode(c)                  # the container is self-describing

Registered codecs:

    "cusz"        full dual-quant + canonical-Huffman pipeline (error-
                  bounded; kernel dispatch via `kernel_impl=`)
    "cusz-i"      cuSZ-i: multi-level interpolation predictor + the same
                  Huffman encoder (higher ratio on smooth fields)
    "fz"          FZ-GPU: Lorenzo predictor + fused bitshuffle encoder
                  with zero-plane elision (wire/eviction throughput class)
    "int8"        per-tensor symmetric int8 (eb = scale/2)
    "int16"       per-tensor symmetric int16
    "int8-block"  blockwise int8 along one axis (KV cache / FSDP weight
                  gather / MoE all-to-all wire format)
    "zfp"         cuZFP-like fixed-rate block transform (baseline)
    "lossless"    identity (raw arrays; bitcast-safe for bf16 storage)

Every codec produces a versioned, self-describing `Container` (payload
pytree + static header with codec id/version/dtype/shape/params);
`pack`/`unpack` switch between the device form and the host storage form,
and `to_arrays`/`from_arrays` bridge to npz-style field dicts.
"""
from .base import (Codec, decode, get, get_block_codec,  # noqa: F401
                   names, register)
from .container import (CONTAINER_FORMAT, ChecksumError,  # noqa: F401
                        Container, Header, check_container,
                        concat_containers, from_arrays, make_header,
                        payload_crc32, stamp_checksum, to_arrays,
                        verify_container)

# importing the implementation modules populates the registry
from . import cusz as cusz                # noqa: F401
from . import cusz_interp as cusz_interp  # noqa: F401
from . import fz as fz                    # noqa: F401
from . import int8 as int8                # noqa: F401
from . import lossless as lossless        # noqa: F401
from . import zfp as zfp                  # noqa: F401

__all__ = ["Codec", "Container", "Header", "CONTAINER_FORMAT",
           "ChecksumError", "check_container", "payload_crc32",
           "stamp_checksum", "verify_container",
           "decode", "get", "get_block_codec", "names", "register",
           "to_arrays", "from_arrays", "make_header", "concat_containers",
           "cusz", "cusz_interp", "fz", "int8", "lossless", "zfp"]
