"""cuSZ-i ("cusz-i"): the interpolation predictor composed with the
canonical-Huffman encoder, behind the same `Codec` protocol.

This codec is the staged pipeline's poster child: it is `CuszCodec`
verbatim with `CompressorConfig.predictor` flipped to "interp" — every
container/pack/valid path is inherited, because the blob surface is
stage-generic (the interp anchor grid rides in the blob's optional
`anchor` field).  On smooth fields the multi-level cubic interpolation
leaves far smaller residuals than blocked Lorenzo, which concentrates
the quant-code histogram and buys ratio at the same error bound
(arXiv 2312.05492).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import compressor as CZ

from .base import register
from .cusz import CuszCodec


@dataclasses.dataclass(frozen=True)
class CuszInterpCodec(CuszCodec):
    cfg: CZ.CompressorConfig = CZ.CompressorConfig(predictor="interp")
    name = "cusz-i"
    version = 1
    # Interpolation levels span the whole tensor (even/odd lifting across
    # every axis): slice-independent encodes change the decode, so
    # sharded saves keep each leaf whole on one owner shard.
    shardable = False

    @staticmethod
    def make(cfg: Optional[CZ.CompressorConfig] = None,
             **kw) -> "CuszInterpCodec":
        if cfg is None:
            kw.setdefault("predictor", "interp")
            cfg = CZ.CompressorConfig(**kw)
        elif kw:
            cfg = dataclasses.replace(cfg, **kw)
        if cfg.predictor != "interp":
            cfg = dataclasses.replace(cfg, predictor="interp")
        return CuszInterpCodec(cfg=cfg)


register("cusz-i", CuszInterpCodec.make)
