"""qwen3-4b — dense, GQA kv=8, qk_norm.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936, head_dim=128,
    pattern=("attn+mlp",), qk_norm=True, tie_embeddings=True,
)
