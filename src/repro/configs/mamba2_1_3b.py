"""mamba2-1.3b — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, head_dim=64,
    pattern=("mamba",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, chunk=128),
    sub_quadratic=True, tie_embeddings=True,
)
