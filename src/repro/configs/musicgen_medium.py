"""musicgen-medium — decoder-only over EnCodec tokens (STUB frontend:
precomputed frame embeddings added to token embeds).
[arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", n_layers=48, d_model=1536, n_heads=24,
    n_kv_heads=24, d_ff=6144, vocab=2048, head_dim=64,
    pattern=("attn+mlp",),
    add_frame_embeds=True,
)
