"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=0, vocab=163840, head_dim=128,
    pattern=("attn+moe",),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408),
)
