"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer.  [arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

# period of 8: one attention layer per 8 (1:7), MoE on odd positions
_PATTERN = ("mamba+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
            "attn+mlp", "mamba+moe", "mamba+mlp", "mamba+moe")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=24576, vocab=65536, head_dim=128,
    pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, chunk=128),
    sub_quadratic=True,
)
