"""The assigned input-shape set (same 4 shapes for every LM arch).

``train_*`` lowers train_step; ``prefill_*`` lowers the prefill forward;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
cache of seq_len).  ``long_500k`` runs only for sub-quadratic archs
(SSM/hybrid) — skips are recorded per arch in DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    needs_sub_quadratic: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           needs_sub_quadratic=True),
}


def applicable(shape: ShapeSpec, cfg) -> bool:
    if shape.needs_sub_quadratic and not cfg.sub_quadratic:
        return False
    return True
