"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, MoEConfig, MLAConfig, SSMConfig

from . import (mamba2_1_3b, moonshot_v1_16b_a3b, deepseek_v2_236b,
               jamba_1_5_large_398b, phi_3_vision_4_2b, qwen3_32b, qwen3_4b,
               granite_34b, qwen2_5_3b, musicgen_medium)
from .shapes import SHAPES, ShapeSpec, applicable  # noqa: F401

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (mamba2_1_3b, moonshot_v1_16b_a3b, deepseek_v2_236b,
              jamba_1_5_large_398b, phi_3_vision_4_2b, qwen3_32b, qwen3_4b,
              granite_34b, qwen2_5_3b, musicgen_medium)
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(name: str, n_periods: int = 2) -> ModelConfig:
    """Small same-family config for CPU smoke tests: few layers, narrow
    width, few experts, tiny vocab — the structure (pattern, MoE/MLA/SSM
    machinery, qk_norm/bias, stubs) is preserved."""
    cfg = get(name)
    d = 64
    n_heads = max(2, min(4, cfg.n_heads)) if cfg.n_heads else 0
    n_kv = 1 if cfg.n_kv_heads == 1 else (2 if cfg.n_kv_heads else 0)
    changes = dict(
        n_layers=len(cfg.pattern) * n_periods,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        n_prepend_embeds=8 if cfg.n_prepend_embeds else 0,
    )
    if cfg.moe is not None:
        # capacity_factor 8: no token dropping at smoke-test sizes, so
        # teacher-forced forward and step-decode agree exactly
        changes["moe"] = MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                   n_shared=min(cfg.moe.n_shared, 1),
                                   capacity_factor=8.0)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                   qk_nope_dim=16, qk_rope_dim=8,
                                   v_head_dim=16)
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(d_state=16, head_dim=8, expand=2,
                                   conv_kernel=4, chunk=16)
    return dataclasses.replace(cfg, **changes)
