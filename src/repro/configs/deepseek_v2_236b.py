"""deepseek-v2-236b — MLA (kv_lora=512) + MoE 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
    n_kv_heads=128, d_ff=0, vocab=102400, head_dim=128,
    pattern=("attn+moe",),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536, n_shared=2),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
)
