"""Forward-compatibility shims for older jax releases.

The codebase (and its tests) target the jax>=0.5 mesh surface:

  * ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)``
  * ``jax.sharding.AbstractMesh(axis_sizes, axis_names)`` (two positional
    arguments instead of 0.4.x's single ``shape_tuple``)

On a 0.4.x install (this container ships 0.4.37) those names/signatures
do not exist yet, so every mesh constructor would die with
``AttributeError``/``TypeError`` before any model code runs.  Importing
``repro`` applies the patches below exactly once; on a new-enough jax
this module is a no-op.  All axes are semantically ``Auto`` (the SPMD
partitioner decides), which is also 0.4.x's only behavior, so dropping
``axis_types`` loses nothing.

This module also hosts ``warn_once``, the process-wide deprecation
helper: Python's own per-location warning dedup resets whenever the
filter stack changes (pytest installs ``always``), so shims that should
warn exactly once per process keep their own seen-set here.
"""
from __future__ import annotations

import enum
import functools
import warnings
from typing import Set

import jax
from jax import sharding as _sharding

_WARNED: Set[str] = set()


def warn_once(key: str, message: str, *, category=DeprecationWarning,
              stacklevel: int = 3) -> None:
    """Emit `message` the first time `key` is seen in this process.

    Deliberately immune to warning-filter resets: deprecation shims on
    hot paths (per-gradient, per-KV-block) must not spam once per call
    under pytest's ``always`` filter.
    """
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, category, stacklevel=stacklevel)


def _patch_axis_type() -> None:
    if hasattr(_sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _sharding.AxisType = AxisType


def _patch_make_mesh() -> None:
    try:
        import inspect
        if "axis_types" in inspect.signature(jax.make_mesh).parameters:
            return
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        return
    real = jax.make_mesh

    @functools.wraps(real)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types            # 0.4.x: every axis is implicitly Auto
        return real(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _patch_abstract_mesh() -> None:
    real = _sharding.AbstractMesh
    try:                          # new-style signature already supported?
        real((1,), ("x",))
        return
    except TypeError:
        pass
    except Exception:             # pragma: no cover - unexpected semantics
        return

    class AbstractMesh(real):     # type: ignore[misc,valid-type]
        """0.4.x AbstractMesh accepting the >=0.5 (sizes, names) form."""

        def __init__(self, axis_sizes, axis_names=None, *, axis_types=None):
            del axis_types
            if axis_names is not None:
                axis_sizes = tuple(zip(axis_names, axis_sizes))
            super().__init__(tuple(axis_sizes))

    _sharding.AbstractMesh = AbstractMesh


def install() -> None:
    _patch_axis_type()
    _patch_make_mesh()
    _patch_abstract_mesh()
