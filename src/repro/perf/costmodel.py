"""Analytic roofline cost model per (arch × shape × mesh) cell.

Why analytic: XLA's HloCostAnalysis counts each while-loop body ONCE, so
with scanned layers + microbatch scans the compiled artifact's
cost_analysis() under-reports FLOPs/bytes by the loop trip counts (verified
in EXPERIMENTS.md §Dry-run).  The dry-run therefore supplies compile proof,
per-device memory, and the collective op inventory; the three roofline
*terms* come from this model, which is exact-by-construction for the code
in repro.models (every einsum below mirrors one in the model).

All quantities are per-chip per-step.  Hardware constants per the
assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro import configs
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

BYTES_P = 2          # params consumed in bf16
BYTES_MASTER = 4     # fp32 master
BYTES_ACT = 2


@dataclasses.dataclass
class CellCost:
    flops: float                 # per chip
    hbm_bytes: float             # per chip
    coll_bytes: float            # per chip (ICI)
    breakdown: Dict[str, float]

    def terms(self):
        t = {"compute_s": self.flops / PEAK_FLOPS,
             "memory_s": self.hbm_bytes / HBM_BW,
             "collective_s": self.coll_bytes / ICI_BW}
        dom = max(t, key=t.get)
        return dict(t, dominant=dom, bound_s=t[dom])


def _attn_fwd_flops(cfg, B, Sq, Sk, causal=True):
    """scores + AV for every attention layer (GQA or MLA q/k dims)."""
    n_attn = sum(1 for k in cfg.layer_kinds() if k.startswith("attn"))
    if cfg.mla:
        qk = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
        vd = cfg.mla.v_head_dim
    else:
        qk = vd = cfg.head_dim
    eff = 0.5 if (causal and Sq == Sk) else 1.0
    per_layer = 2.0 * B * Sq * Sk * cfg.n_heads * (qk + vd) * eff
    return n_attn * per_layer


def _ssd_fwd_flops(cfg, B, S):
    if cfg.ssm is None:
        return 0.0
    s = cfg.ssm
    n_m = sum(1 for k in cfg.layer_kinds() if k.startswith("mamba"))
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    Q, N, P = s.chunk, s.d_state, s.head_dim
    per_tok = 2 * Q * N + 2 * Q * d_in + 8 * d_in * N   # cb, scores@x, states
    return n_m * B * S * per_tok


def _moe_waste_factor(cfg):
    """Dense-capacity dispatch computes E·cap slots = topk·cf·T token-slots
    (dropped-or-not), so MoE expert flops carry a capacity_factor excess."""
    return cfg.moe.capacity_factor if cfg.moe else 1.0


def _param_bytes(cfg):
    return cfg.param_count() * BYTES_P


def _tp_reduces_per_stack(cfg):
    """One row-parallel all-reduce per matmul block: attn->1, mamba->1,
    mlp/moe->1 (per layer, fwd; bwd doubled by the caller's 2x factor)."""
    n = 0
    for kind in cfg.layer_kinds():
        n += 1                                  # attn or mamba mixer
        if kind.endswith("+mlp") or kind.endswith("+moe"):
            n += 1
    return n


def _active_matmul_flops(cfg, tokens):
    n_active = cfg.active_param_count()
    if cfg.moe:
        moe_layers = sum(1 for k in cfg.layer_kinds() if k.endswith("+moe"))
        d = cfg.d_model
        moe_active = 3 * d * cfg.moe.d_ff * cfg.moe.top_k * moe_layers
        n_active = n_active + moe_active * (_moe_waste_factor(cfg) - 1.0)
    return 2.0 * n_active * tokens


def cell_cost(arch: str, shape_name: str, multi_pod: bool,
              microbatches: int = 1, grad_compress: str = "none",
              accum_bytes: int = 4, weight_compress: str = "none",
              kv_compress: bool = False, a2a_compress: str = "none") -> CellCost:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    npods = 2 if multi_pod else 1
    DP, TP = 16, 16
    chips = npods * DP * TP
    B, S = shape.global_batch, shape.seq_len
    P_all = cfg.param_count()
    br: Dict[str, float] = {}

    if shape.kind == "train":
        tokens = B * S
        mm = 3.0 * _active_matmul_flops(cfg, tokens)          # fwd+bwd(2x)
        at = 3.0 * _attn_fwd_flops(cfg, B, S, S)
        sd = 3.0 * _ssd_fwd_flops(cfg, B, S)
        rematf = (mm + at + sd) / 3.0                          # fwd recompute
        flops = (mm + at + sd + rematf) / chips
        br["flops_matmul"] = mm / chips
        br["flops_attn"] = at / chips
        br["flops_ssd"] = sd / chips
        br["flops_remat"] = rematf / chips

        # HBM: weights touched per microbatch (gathered bf16 / TP shard),
        # optimizer state r/w, gradient r/w, remat'd activations
        w_read = 2 * microbatches * P_all * BYTES_P / TP       # fwd+bwd
        opt_rw = P_all * (BYTES_MASTER * 2 + 2 * 2 * 2) / chips
        grad_rw = 2 * microbatches * P_all * accum_bytes / chips
        act = 12.0 * (tokens / (DP * npods)) * cfg.d_model * BYTES_ACT \
            * cfg.n_layers / TP
        hbm = w_read + opt_rw + grad_rw + act
        br.update(hbm_weights=w_read, hbm_opt=opt_rw, hbm_grads=grad_rw,
                  hbm_acts=act)

        # collectives: FSDP gathers (fwd+bwd per microbatch), grad
        # reduce-scatter over data, TP activation all-reduces, MoE a2a,
        # cross-pod grad all-reduce (fp32 or narrow int)
        # weight_compress='int8': the gather moves int8+1/128 scales
        wbytes = (1.0 + 4.0 / 128) if weight_compress == "int8" else BYTES_P
        fsdp = 2 * microbatches * P_all * wbytes / TP
        gsync = P_all * accum_bytes / TP
        tok_loc = tokens / (DP * npods) / microbatches
        n_tp_ar = _tp_reduces_per_stack(cfg)
        tp_ar = 2.0 * microbatches * n_tp_ar * tok_loc * cfg.d_model * BYTES_ACT
        a2a = 0.0
        if cfg.moe:
            moe_layers = sum(1 for k in cfg.layer_kinds()
                             if k.endswith("+moe"))
            a2a_bytes = (1.0 + 4.0 / 128) if a2a_compress == "int8" \
                else BYTES_ACT
            a2a = 3 * 2 * microbatches * moe_layers * tok_loc \
                * cfg.moe.top_k * cfg.d_model * a2a_bytes
        pod = 0.0
        if multi_pod:
            gbytes = {"none": 4, "int16": 2, "int8": 1}[grad_compress]
            pod = 2.0 * P_all * gbytes / (DP * TP)
        coll = fsdp + gsync + tp_ar + a2a + pod
        br.update(coll_fsdp=fsdp, coll_gradsync=gsync, coll_tp=tp_ar,
                  coll_moe_a2a=a2a, coll_pod=pod)
        return CellCost(flops, hbm, coll, br)

    if shape.kind == "prefill":
        tokens = B * S
        flops_g = _active_matmul_flops(cfg, tokens) \
            + _attn_fwd_flops(cfg, B, S, S) + _ssd_fwd_flops(cfg, B, S)
        flops = flops_g / chips
        w_read = P_all * BYTES_P / TP
        act = 6.0 * (tokens / (DP * npods)) * cfg.d_model * BYTES_ACT \
            * cfg.n_layers / TP
        cache_w = _cache_bytes(cfg, B, S) / chips
        hbm = w_read + act + cache_w
        fsdp = P_all * BYTES_P / TP
        tok_loc = tokens / (DP * npods)
        tp_ar = _tp_reduces_per_stack(cfg) * tok_loc * cfg.d_model * BYTES_ACT
        a2a = 0.0
        if cfg.moe:
            moe_layers = sum(1 for k in cfg.layer_kinds() if k.endswith("+moe"))
            a2a = 2 * moe_layers * tok_loc * cfg.moe.top_k * cfg.d_model \
                * BYTES_ACT
        coll = fsdp + tp_ar + a2a
        br.update(hbm_weights=w_read, hbm_acts=act, hbm_cache=cache_w,
                  coll_fsdp=fsdp, coll_tp=tp_ar, coll_moe_a2a=a2a)
        return CellCost(flops, hbm, coll, br)

    # decode: one token per slot against an S-long cache
    flops_g = _active_matmul_flops(cfg, B)
    n_attn = sum(1 for k in cfg.layer_kinds() if k.startswith("attn"))
    if cfg.mla:
        m = cfg.mla
        # latent up-projection of the whole cache per step (MLA tradeoff)
        flops_g += 2.0 * B * S * m.kv_lora_rank \
            * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim) * n_attn
        flops_g += _attn_fwd_flops(cfg, B, 1, S, causal=False)
    else:
        flops_g += _attn_fwd_flops(cfg, B, 1, S, causal=False)
    if cfg.ssm:
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        n_m = sum(1 for k in cfg.layer_kinds() if k.startswith("mamba"))
        flops_g += 4.0 * B * d_in * s.d_state * n_m
    flops = flops_g / chips
    w_read = P_all * BYTES_P / TP
    # int8 KV cache (+ per-SEQ_BLOCK fp32 scales) halves the cache reads
    kv_factor = (0.5 + 4.0 / (2 * 128)) if kv_compress else 1.0
    cache = _cache_bytes(cfg, B, S) * kv_factor / chips
    hbm = w_read + cache
    coll = _decode_coll(cfg, B)
    br.update(hbm_weights=w_read, hbm_cache=cache, coll=coll)
    return CellCost(flops, hbm, coll, br)


def _cache_bytes(cfg, B, S):
    total = 0.0
    for k in cfg.layer_kinds():
        if k.startswith("attn"):
            if cfg.mla:
                total += B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim)
            else:
                total += 2 * B * S * cfg.n_kv_heads * cfg.head_dim
        elif cfg.ssm:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            total += B * (d_in // s.head_dim) * s.d_state * s.head_dim * 2
    return total * BYTES_ACT


def _decode_coll(cfg, B):
    # TP all-reduces on the [B,1,D] residual per matmul block
    return _tp_reduces_per_stack(cfg) * B * cfg.d_model * BYTES_ACT


def summarize(arch, shape_name, multi_pod, **kw):
    c = cell_cost(arch, shape_name, multi_pod, **kw)
    return {"flops_per_chip": c.flops, "hbm_bytes_per_chip": c.hbm_bytes,
            "coll_bytes_per_chip": c.coll_bytes, **c.terms(),
            "breakdown": c.breakdown}
