from . import costmodel  # noqa: F401
