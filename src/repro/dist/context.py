"""Mesh/spec context: sharding hints that are no-ops off-mesh.

Model/train code calls ``constrain(...)``/``constrain_like_params(...)``
unconditionally; whether those become
``jax.lax.with_sharding_constraint`` or identity is decided by the
dynamically-scoped context installed by the launcher/tests:

    with use_mesh(mesh), use_param_specs(specs):
        step = jax.jit(make_train_step(cfg, tcfg))
        ...

All managers restore the previous state on exit (including on exception),
so contexts nest.  State is process-global by design — the single-
controller launcher traces one program at a time; the checkpoint
background thread never traces.

Spec mini-language for ``constrain``: each element is a mesh axis name, a
tuple of axis names, ``None`` (replicated), or the placeholder ``"dp"``
which expands to the current data-parallel axes — ``("pod", "data")`` on
a multi-pod mesh, overridable via ``dp_axes_override`` (the train step
pins ``("data",)`` inside its ``vmap(..., spmd_axis_name="pod")`` region,
where the pod dim is carried by the vmap, not the array).  Any dim whose
size does not divide its axes falls back to replicated rather than
erroring, mirroring ``sharding.param_specs``.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import dp_axes as _mesh_dp_axes

_mesh_stack: List[Any] = []
_spec_stack: List[Any] = []
_dp_override_stack: List[Tuple[str, ...]] = []
_weight_compress_stack: List[Optional[str]] = []   # armed codec names
_a2a_compress_stack: List[Optional[str]] = []
_restore_compress_stack: List[Optional[str]] = []
_kv_reshard_stack: List[Optional[str]] = []
_kv_evict_stack: List[Optional[str]] = []


def _is_spec(x) -> bool:
    return isinstance(x, P)


@contextmanager
def _pushed(stack: List[Any], value: Any):
    stack.append(value)
    try:
        yield value
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# mesh + param specs
# ---------------------------------------------------------------------------

def use_mesh(mesh):
    """Install ``mesh`` as the current mesh for the dynamic extent."""
    return _pushed(_mesh_stack, mesh)


def current_mesh():
    return _mesh_stack[-1] if _mesh_stack else None


def use_param_specs(specs):
    """Install the parameter PartitionSpec pytree (from
    ``sharding.param_specs``) consulted by ``constrain_like_params`` and
    the int8 weight-gather hook."""
    return _pushed(_spec_stack, specs)


def current_param_specs():
    return _spec_stack[-1] if _spec_stack else None


def dp_axes_override(axes: Tuple[str, ...]):
    """Override what ``"dp"`` resolves to (inside vmapped pod regions)."""
    return _pushed(_dp_override_stack, tuple(axes))


def current_dp_axes() -> Optional[Tuple[str, ...]]:
    if _dp_override_stack:
        return _dp_override_stack[-1]
    mesh = current_mesh()
    return _mesh_dp_axes(mesh) if mesh is not None else None


# ---------------------------------------------------------------------------
# constraints
# ---------------------------------------------------------------------------

def _resolve_spec(spec_elems, shape, mesh) -> P:
    mesh_shape = dict(mesh.shape)
    resolved: list = []
    for el in spec_elems:
        if el == "dp":
            axes = current_dp_axes() or ()
            axes = tuple(a for a in axes if a in mesh_shape)
            el = axes if axes else None
        resolved.append(el)
    for i, el in enumerate(resolved):
        if el is None:
            continue
        if i >= len(shape):
            resolved[i] = None            # over-rank element: replicate
            continue
        axes = tuple(a for a in (el if isinstance(el, (tuple, list))
                                 else (el,)) if a in mesh_shape)
        size = math.prod(int(mesh_shape[a]) for a in axes)
        if not axes or shape[i] % size != 0:
            resolved[i] = None            # divisibility fallback: replicate
        else:                             # axes absent from the mesh dropped
            resolved[i] = axes if isinstance(el, (tuple, list)) else el
    while resolved and resolved[-1] is None:
        resolved.pop()
    return P(*resolved)


def constrain(x, *spec_elems):
    """``with_sharding_constraint`` under the current mesh; identity when
    off-mesh.  ``spec_elems`` use the module's spec mini-language."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _resolve_spec(spec_elems, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_like_params(tree, lead_axis: Optional[str] = None):
    """Constrain a param-shaped pytree (gradients, accumulators) with the
    installed param specs.  ``lead_axis`` prepends a mesh axis for trees
    with one extra leading dim (the per-pod gradient stack).  No-op when
    either the mesh or the specs are absent."""
    mesh = current_mesh()
    specs = current_param_specs()
    if mesh is None or specs is None:
        return tree

    def one(leaf, spec):
        elems = tuple(spec)
        if lead_axis is not None:
            elems = (lead_axis,) + elems
        resolved = _resolve_spec(elems, tuple(leaf.shape), mesh)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, resolved))

    return jax.tree_util.tree_map(one, tree, specs)


# ---------------------------------------------------------------------------
# compression hooks.  Each hook arms a *codec* (a `repro.codecs` registry
# name); passing True selects the default integer codec, False/None/"none"
# disarms.  The consuming sites (`weights.gather_dequant_tree`,
# `moe._compressed_reshard`) pull the armed codec from here, so the wire
# format is a registry choice, not a hardcoded quantizer.
# ---------------------------------------------------------------------------

_DEFAULT_WIRE_CODEC = "int8-block"


def _codec_name(active) -> Optional[str]:
    if active is True:
        return _DEFAULT_WIRE_CODEC
    if not active or active == "none":
        return None
    # legacy mode string: "int8" has always meant blockwise-int8 on the
    # wire (TrainConfig.a2a_compress / weight_compress), not the
    # per-tensor "int8" codec
    if active == "int8":
        return _DEFAULT_WIRE_CODEC
    from repro import codecs
    name = str(active)
    if name not in codecs.names():
        raise ValueError(f"unknown compression codec {name!r}; "
                         f"registered: {codecs.names()}")
    return name


def use_weight_compress(active):
    """Arm the FSDP weight-gather compression hook (read via
    ``weight_gather_info`` inside the model's period scan).  `active`:
    bool or a codec registry name ("int8-block"/"int8")."""
    return _pushed(_weight_compress_stack, _codec_name(active))


def use_a2a_compress(active):
    """Arm compressed MoE dispatch/combine resharding (read via
    ``a2a_compress_active``/``a2a_codec`` inside ``moe_forward``).
    `active`: bool or a codec registry name."""
    return _pushed(_a2a_compress_stack, _codec_name(active))


def a2a_compress_active() -> bool:
    return bool(_a2a_compress_stack and _a2a_compress_stack[-1]
                and current_mesh() is not None)


def a2a_codec() -> Optional[str]:
    """Registry name of the armed all-to-all wire codec (None = off)."""
    return _a2a_compress_stack[-1] if a2a_compress_active() else None


def weight_compress_codec() -> Optional[str]:
    """Registry name of the armed weight-gather codec (None = off)."""
    if not (_weight_compress_stack and _weight_compress_stack[-1]):
        return None
    return _weight_compress_stack[-1]


def use_restore_compress(active):
    """Arm the elastic-restore wire codec: during ``load_checkpoint``,
    raw (lossless-stored) float leaves are re-encoded through this
    blockwise codec for the host->device reshard move, the same
    s8-on-the-wire trick the MoE all-to-all uses.  Lossy (eb = scale/2);
    stored-compressed leaves already move as containers and are never
    re-encoded.  `active`: bool or a codec registry name."""
    name = _codec_name(active)
    if name is not None:
        # arm-time validation, matching the serve/a2a hooks: a
        # non-blockwise id must fail here, not mid-restore
        from repro import codecs
        codecs.get_block_codec(name, axis=0, block=8)
    return _pushed(_restore_compress_stack, name)


def restore_codec() -> Optional[str]:
    """Registry name of the armed elastic-restore wire codec (None = off,
    the default: restore is bit-exact w.r.t. the stored containers)."""
    return _restore_compress_stack[-1] if _restore_compress_stack else None


def use_kv_reshard_compress(active):
    """Arm the prefill->decode KV-cache reshard wire codec: the serve
    engine's ``encode_handoff`` moves per-SEQ_BLOCK cache slabs across
    the mesh boundary as this codec's Containers instead of raw bf16.
    `active`: bool (True = "int8-block"; False/"none" = an explicit
    disarm, which the handoff resolves to the "lossless" raw-bytes wire)
    or a registry name — a blockwise wire codec ("int8-block", adopted
    directly as the in-memory QuantKV on the decode side) or a
    whole-slab wire ("cusz", "fz", "lossless").  Validated at arm time
    like the a2a/restore hooks: an id that is neither blockwise-
    configurable nor one of the whole-slab wire codecs fails here, not
    mid-handoff."""
    name = _codec_name(active)
    if name is not None and name not in ("cusz", "fz", "lossless"):
        from repro import codecs
        codecs.get_block_codec(name, axis=0, block=8)
    return _pushed(_kv_reshard_stack, name)


def kv_reshard_codec() -> Optional[str]:
    """Registry name of the armed prefill->decode reshard wire codec.
    None = nothing armed (the handoff falls back to its "int8-block"
    default).  An *explicit* disarm (``use_kv_reshard_compress(False)``)
    resolves to "lossless": unlike the a2a/weight hooks, the handoff
    always needs some wire format, so "off" means raw bytes — never a
    silent fall-through to a lossy codec."""
    if not _kv_reshard_stack:
        return None
    return _kv_reshard_stack[-1] or "lossless"


def use_kv_evict_codec(active):
    """Arm the paged-pool eviction codec: when the serve pool
    (``repro.serve.pool.PagedKVPool``) pushes cold pages to host, they
    cross as this codec's Containers.  `active`: bool (True =
    "int8-block" payload pass-through, bit-exact restore; False/"none" =
    an explicit disarm, which the pool resolves to "int8-block" — cold
    pages always need *some* host form, and the lossless-payload one is
    the conservative default) or a registry name — "int8-block",
    "cusz"/"fz" (recompressed, higher ratio, restore re-quantizes under
    the codec's bound) or "lossless" (raw dequantized values).
    Validated at arm time like the kv-reshard/a2a/restore hooks."""
    name = _codec_name(active)
    if name is not None and name not in ("cusz", "fz", "lossless"):
        from repro import codecs
        codecs.get_block_codec(name, axis=0, block=8)
    return _pushed(_kv_evict_stack, name)


def kv_evict_codec() -> Optional[str]:
    """Registry name of the armed pool-eviction codec.  None = nothing
    armed (the pool falls back to its own default).  An explicit disarm
    resolves to "int8-block": eviction always needs a host form, so
    "off" means the bit-exact payload pack — never a silent lossy
    fall-through."""
    if not _kv_evict_stack:
        return None
    return _kv_evict_stack[-1] or "int8-block"


def resolve_sharding(mesh, shape, *spec_elems) -> NamedSharding:
    """Public spec-mini-language resolver for host-side placement
    (``jax.device_put`` / ``out_shardings``): same semantics as
    ``constrain`` — ``"dp"`` expansion, absent-axis dropping and per-dim
    divisibility fallback — but returns the ``NamedSharding`` instead of
    constraining a traced value.  The serve reshard uses this to place
    adopted cache payloads under the decode mesh."""
    return NamedSharding(mesh, _resolve_spec(spec_elems, tuple(shape), mesh))


def _drop_lead(spec: P) -> P:
    elems = tuple(spec)
    return P(*elems[1:]) if elems else P()


def weight_gather_info():
    """When int8 weight compression is armed on-mesh with param specs
    installed, returns ``(specs_tuple, mesh)`` where ``specs_tuple``
    aligns with ``tuple(params["layers"])`` as seen inside the period
    scan (leading period dim stripped from every leaf spec).  Otherwise
    None — the model then runs the plain path."""
    if not (_weight_compress_stack and _weight_compress_stack[-1]):
        return None
    mesh = current_mesh()
    specs = current_param_specs()
    if mesh is None or specs is None:
        return None
    try:
        layer_specs = specs["layers"]
    except (TypeError, KeyError):
        return None
    specs_tuple = tuple(
        jax.tree_util.tree_map(_drop_lead, ls, is_leaf=_is_spec)
        for ls in layer_specs)
    return specs_tuple, mesh
