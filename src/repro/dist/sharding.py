"""Sharding rules: pytree -> PartitionSpec pytree.

One rule engine covers params, optimizer moments (which mirror the param
tree under ``AdamWState.m/.v``, including int8 ``QTensor`` leaves whose
``q``/``scale`` inherit the parent weight's rule) and gradients.  Rules
are keyed on the *nearest recognized trailing name* in the tree path plus
the leaf shape, so structurally-mirrored trees get identical specs
(``test_opt_state_specs_follow_params``).

Tensor-parallel axis is ``"model"`` (attention heads / MoE experts / MLP
ff); the divisibility fallback is per-leaf: a dim that does not divide the
mesh axis is left replicated (granite-34b MQA: ``wk`` with kv=1 heads
replicates while ``wq`` with 48 heads shards).  ``fsdp=True`` additionally
shards the largest remaining dim over ``"data"`` (ZeRO-3 layout; the int8
weight-gather in ``repro.core.weights`` keys off that ``"data"`` entry).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# tiny / numerically sensitive leaves stay replicated
_SKIP_SUBSTR = ("norm",)

# preferred model-sharded dim per trailing param name (negative indices so
# the rule transfers to QTensor ``scale`` leaves whose last dim shrinks)
_MODEL_DIM = {
    # GQA/MQA attention: shard heads
    "wq": -2, "wk": -2, "wv": -2, "bq": -2, "bk": -2, "bv": -2, "wo": -3,
    # MLA: shard heads of the up-projections; latent projections replicate
    "wq_b": -2, "wk_b": -2, "wv_b": -2, "wq_a": -1, "wkv_a": None,
    # dense MLP: shard ff
    "w_up": -1, "w_gate": -1, "w_down": -2,
    # MoE router: shard experts
    "router": -1,
    # Mamba2: shard the expanded inner dim
    "in_proj": -1, "out_proj": -2,
    "conv_w": None, "conv_b": None, "A_log": None, "D": None,
    "dt_bias": None,
    # embeddings: shard vocab (sharding d_model breaks the SPMD gather
    # partitioning inside the microbatch scan); lm_head shards vocab too
    "embed": 0, "lm_head": -1,
}
# 4D (stacked) MoE expert weights shard the expert dim over 'model' (EP)
_MOE_EXPERT_LEAVES = ("w_up", "w_gate", "w_down", "router")


def _key_name(k) -> str:
    return str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _axis_size(mesh_shape, axis) -> int:
    return int(mesh_shape.get(axis, 1))


def _model_dim(names: Sequence[str], ndim: int) -> Optional[int]:
    """Preferred 'model' dim for a leaf, or None (replicate)."""
    if any(s in n for n in names for s in _SKIP_SUBSTR):
        return None
    known = next((n for n in reversed(names) if n in _MODEL_DIM), None)
    if known is None:
        return None
    if known in _MOE_EXPERT_LEAVES and "moe" in names and \
            "shared" not in names and ndim >= 4:
        return 1                          # [nP, E, ...]: expert parallelism
    d = _MODEL_DIM[known]
    if d is None or not (-ndim <= d < ndim):
        return None
    return d % ndim


def _leaf_spec(path, leaf, mesh_shape, fsdp: bool) -> P:
    names = [_key_name(k) for k in path]
    shape = tuple(leaf.shape)
    ndim = len(shape)
    if ndim == 0:
        return P()
    assign: list = [None] * ndim
    msz = _axis_size(mesh_shape, "model")
    md = _model_dim(names, ndim)
    if md is not None and shape[md] % msz == 0:
        assign[md] = "model"
    if fsdp and not any(s in n for n in names for s in _SKIP_SUBSTR):
        dsz = _axis_size(mesh_shape, "data")
        in_layers = "layers" in names
        cands = [j for j in range(ndim)
                 if assign[j] is None and shape[j] % dsz == 0
                 and shape[j] >= dsz and not (in_layers and j == 0)]
        if cands and leaf.size >= 4096:
            j = max(cands, key=lambda j: shape[j])
            assign[j] = "data"
    while assign and assign[-1] is None:
        assign.pop()
    return P(*assign)


def param_specs(tree: Any, mesh, *, fsdp: bool = False) -> Any:
    """PartitionSpec pytree mirroring ``tree`` (params / opt state / any
    structurally-similar pytree of arrays or ShapeDtypeStructs)."""
    mesh_shape = dict(mesh.shape)

    def one(path, leaf):
        return _leaf_spec(path, leaf, mesh_shape, fsdp)

    return jax.tree_util.tree_map_with_path(one, tree)


def param_shardings(tree: Any, mesh, *, fsdp: bool = False) -> Any:
    """NamedSharding pytree for ``jax.device_put`` / ``in_shardings``."""
    specs = param_specs(tree, mesh, fsdp=fsdp)
    return jax.tree_util.tree_map(lambda sp: NamedSharding(mesh, sp), specs,
                                  is_leaf=_is_spec)


def even_shard_axis(shape: Sequence[int], nshards: int,
                    multiple_of: int = 1) -> Optional[int]:
    """Largest dim splittable into `nshards` equal slices whose lengths
    stay a multiple of `multiple_of` (codec block alignment), or None.
    The per-host checkpoint writer uses this to plan tensor splits."""
    if nshards <= 1:
        return None
    best = None
    for i, s in enumerate(shape):
        s = int(s)
        if s % nshards == 0 and (s // nshards) % multiple_of == 0 and s > 0:
            if best is None or s > int(shape[best]):
                best = i
    return best


def dp_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that carry data parallelism for the batch dim."""
    return tuple(a for a in ("pod", "data") if a in dict(mesh.shape))


def batch_spec(mesh, podded: bool = False) -> P:
    """Global-batch PartitionSpec: [B, S] over the dp axes, or the
    compressed-gradient layout [npods, B/npods, S]."""
    if podded:
        return P("pod", "data", None)
    axes = dp_axes(mesh)
    return P(axes if axes else None, None)
