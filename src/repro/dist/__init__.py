"""Distribution substrate: mesh/spec context, sharding rules, fault
tolerance.  ``context`` is a no-op off-mesh so the same model/train code
runs on one CPU device and on the production pod meshes."""
from repro.dist import chaos, context, fault, sharding  # noqa: F401
