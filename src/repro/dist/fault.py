"""Fault-tolerance primitives for the training loop.

Host-side (never traced): the trainer calls these between steps on
concrete values.  ``StragglerDetector`` keeps an EMA of step wall-time
and flags steps that exceed ``threshold``x the EMA after a warmup;
``loss_is_bad`` is the NaN/Inf guard feeding the restore-last-good path.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class StragglerDetector:
    """Flag abnormally slow steps against an EMA baseline.

    The first ``warmup`` observations only establish the baseline and are
    never flagged.  The warmup baseline is the **median** of the warmup
    window, not an EMA over it: a straggler landing *during* warmup
    (steps 2..warmup) must not be folded into the baseline, or it would
    inflate it and suppress all later detection.  After warmup a flagged
    step does not poison the baseline either (its duration is excluded
    from the EMA), so a single straggler recovers immediately on the next
    normal step.
    """

    def __init__(self, threshold: float = 2.0, warmup: int = 5,
                 alpha: float = 0.2):
        assert threshold > 1.0, threshold
        self.threshold = float(threshold)
        self.warmup = int(warmup)
        self.alpha = float(alpha)
        self.ema: Optional[float] = None
        self.n_observed = 0
        self.n_flagged = 0
        self._warmup_durations: list = []

    def observe(self, step: int, duration_s: float) -> bool:
        """Record one step's wall-time; returns True iff it straggled."""
        duration_s = float(duration_s)
        self.n_observed += 1
        if self.ema is None or self.n_observed <= self.warmup:
            # warmup: outlier-robust baseline (median of the window)
            self._warmup_durations.append(duration_s)
            self.ema = float(np.median(self._warmup_durations))
            return False
        if self.ema <= 1e-12:
            # degenerate ~0 baseline (coarse timers): reseed instead of
            # flagging, or every later step would flag with the EMA frozen
            self.ema = duration_s
            return False
        slow = duration_s > self.threshold * self.ema
        if slow:
            self.n_flagged += 1
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * duration_s
        return bool(slow)


def loss_is_bad(loss) -> bool:
    """True when the (concrete, scalar) loss is NaN/Inf."""
    return not bool(np.isfinite(np.asarray(loss)))
