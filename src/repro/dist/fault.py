"""Fault detection *and mitigation* for the training loop.

Host-side (never traced): the trainer calls these between steps on
concrete values.

  * ``StragglerDetector`` — flags steps that exceed ``threshold``x an
    EMA baseline (median-of-warmup seeded).  Grown per-host: pass
    ``host=`` to ``observe`` to keep one independent baseline per host,
    ``reset(host)`` to re-warm a recovered host's state, and read
    ``penalty(host)`` — a decaying flag score — instead of the raw
    cumulative ``n_flagged`` when deciding whether a host is *currently*
    misbehaving (the stale-EMA-penalty fix).
  * ``MitigationPolicy`` — consumes the detection and acts on it:
    rebalances work shares away from flagged hosts (proportional
    control toward ``target_ratio`` of the healthy-host median),
    excludes a persistently-flagged host/pod outright, restores shares
    (and resets the detector) once a host runs clean again, and
    skip-and-logs steps whose loss is NaN/Inf.  Every action lands in a
    structured ``events`` log.
  * ``loss_is_bad`` — the NaN/Inf guard feeding the restore-last-good
    path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


class StragglerDetector:
    """Flag abnormally slow steps against an EMA baseline.

    The first ``warmup`` observations only establish the baseline and are
    never flagged.  The warmup baseline is the **median** of the warmup
    window, not an EMA over it: a straggler landing *during* warmup
    (steps 2..warmup) must not be folded into the baseline, or it would
    inflate it and suppress all later detection.  After warmup a flagged
    step does not poison the baseline either (its duration is excluded
    from the EMA), so a single straggler recovers immediately on the next
    normal step.

    ``penalty`` decays by ``penalty_decay`` on every clean step and bumps
    by 1 on every flagged one — a recency-weighted misbehavior score,
    unlike the monotone telemetry counter ``n_flagged``.
    """

    def __init__(self, threshold: float = 2.0, warmup: int = 5,
                 alpha: float = 0.2, penalty_decay: float = 0.5):
        assert threshold > 1.0, threshold
        assert 0.0 <= penalty_decay < 1.0, penalty_decay
        self.threshold = float(threshold)
        self.warmup = int(warmup)
        self.alpha = float(alpha)
        self.penalty_decay = float(penalty_decay)
        self._hosts: Dict[Any, "StragglerDetector"] = {}
        self.reset()

    def reset(self, host=None) -> None:
        """Re-warm detection state.  ``reset()`` clears this detector
        (and every per-host child); ``reset(host)`` clears only that
        host's baseline/penalty — the recovered-host API the mitigation
        policy calls so stale EMA state stops penalizing it."""
        if host is not None:
            self._hosts.pop(host, None)
            return
        self.ema: Optional[float] = None
        self.n_observed = 0
        self.n_flagged = 0
        self.penalty = 0.0
        self.consecutive_flags = 0
        self._warmup_durations: list = []
        self._hosts.clear()

    def host(self, host) -> "StragglerDetector":
        """The per-host child detector (created on first observation)."""
        if host not in self._hosts:
            self._hosts[host] = StragglerDetector(
                self.threshold, self.warmup, self.alpha, self.penalty_decay)
        return self._hosts[host]

    def observe(self, step: int, duration_s: float, host=None) -> bool:
        """Record one step's wall-time; returns True iff it straggled.
        With ``host=`` the observation goes to that host's independent
        baseline (the multi-host form the mitigation policy uses)."""
        if host is not None:
            return self.host(host).observe(step, duration_s)
        duration_s = float(duration_s)
        self.n_observed += 1
        if self.ema is None or self.n_observed <= self.warmup:
            # warmup: outlier-robust baseline (median of the window)
            self._warmup_durations.append(duration_s)
            self.ema = float(np.median(self._warmup_durations))
            return False
        if self.ema <= 1e-12:
            # degenerate ~0 baseline (coarse timers): reseed instead of
            # flagging, or every later step would flag with the EMA frozen
            self.ema = duration_s
            return False
        slow = duration_s > self.threshold * self.ema
        if slow:
            self.n_flagged += 1
            self.penalty += 1.0
            self.consecutive_flags += 1
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * duration_s
            self.penalty *= self.penalty_decay
            self.consecutive_flags = 0
        return bool(slow)


def loss_is_bad(loss) -> bool:
    """True when the (concrete, scalar) loss is NaN/Inf."""
    return not bool(np.isfinite(np.asarray(loss)))


# ---------------------------------------------------------------------------
# Mitigation: act on the detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MitigationConfig:
    """Knobs for `MitigationPolicy` (see the README Resilience section).

    Rebalancing is proportional control: a detected host's work share is
    scaled by ``target_ratio * median(healthy) / duration`` each step it
    runs hot, so its modeled step time converges geometrically onto
    ``target_ratio``x the healthy median.  A host flagged
    ``exclude_after`` consecutive times *while already at the
    ``min_share`` floor* is excluded outright (share 0) — the
    persistently-bad-pod case where rebalancing cannot help."""
    threshold: float = 2.0           # StragglerDetector flag ratio
    warmup: int = 3                  # baseline steps per host
    alpha: float = 0.2               # baseline EMA weight
    penalty_decay: float = 0.5       # per-clean-step flag-score decay
    target_ratio: float = 1.1        # rebalance until within this of peers
    min_share: float = 0.01          # share floor before exclusion
    exclude_after: int = 3           # consecutive floor-flags -> exclude
    recover_after: int = 3           # clean steps before share restore
    restore_factor: float = 1.5      # share restore multiplier per step


class MitigationPolicy:
    """Turn per-host straggler flags into work-share decisions.

    ``observe(step, host_durations)`` updates the per-host detectors and
    ``shares`` (a simplex over hosts: each host's fraction of the global
    microbatch work).  ``shares`` starts uniform; the trainer feeds it to
    its data/microbatch assignment (and, under chaos, to the straggler
    simulation — see `dist.chaos`).  ``on_bad_loss`` is the skip-and-log
    guard for NaN/Inf losses.  Every decision appends a structured event
    to ``events``.
    """

    def __init__(self, nhosts: int,
                 cfg: MitigationConfig = MitigationConfig()):
        assert nhosts >= 1, nhosts
        self.nhosts = int(nhosts)
        self.cfg = cfg
        self.detector = StragglerDetector(
            threshold=cfg.threshold, warmup=cfg.warmup, alpha=cfg.alpha,
            penalty_decay=cfg.penalty_decay)
        self.shares = np.full(self.nhosts, 1.0 / self.nhosts)
        self.excluded: set = set()
        self.events: List[Dict[str, Any]] = []
        self.n_skipped = 0
        self._clean = np.zeros(self.nhosts, np.int64)
        self._consec = np.zeros(self.nhosts, np.int64)
        self._penalty = np.zeros(self.nhosts, np.float64)

    # -- loss guard ---------------------------------------------------------

    def on_bad_loss(self, step: int, loss) -> bool:
        """True when this step's loss is NaN/Inf — the trainer then skips
        the update (restoring last-good state) instead of training on
        garbage; the skip is logged as a structured event."""
        if not loss_is_bad(loss):
            return False
        self.n_skipped += 1
        # repro-lint: allow[host-sync] loss is a concrete host scalar here
        # (the trainer calls this between steps, never under trace)
        self.events.append({"kind": "skip-step", "step": int(step),
                            "loss": repr(np.asarray(loss).item()
                                         if np.asarray(loss).ndim == 0
                                         else loss)})
        return True

    # -- straggler mitigation ----------------------------------------------

    def observe(self, step: int, host_durations: Sequence[float]
                ) -> Dict[str, Any]:
        """Feed one step's per-host wall times; returns a step report
        ``{flags, shares, excluded}`` after updating the policy state.

        A host flags when it straggles *temporally* (its own EMA
        baseline, via the per-host `StragglerDetector`) **or**
        *relatively* (``threshold``x the active-host median this step) —
        the relative leg catches a host that has been slow since step 0,
        which its own baseline can never flag."""
        cfg = self.cfg
        durs = np.asarray(host_durations, np.float64)
        assert durs.shape == (self.nhosts,), (durs.shape, self.nhosts)
        uniform = 1.0 / self.nhosts
        flags = [False] * self.nhosts
        active = [h for h in range(self.nhosts) if h not in self.excluded]
        med = float(np.median(durs[active])) if active else 0.0
        for h in active:
            temporal = self.detector.observe(step, durs[h], host=h)
            relative = med > 0 and durs[h] > cfg.threshold * med
            flags[h] = bool(temporal or relative)
            if flags[h]:
                self._penalty[h] += 1.0
                self._consec[h] += 1
                self._clean[h] = 0
            else:
                self._penalty[h] *= cfg.penalty_decay
                self._consec[h] = 0
                self._clean[h] += 1
            if med <= 0:
                continue
            # proportional control, symmetric: scale the share by
            # target_ratio * med / dur each step.  Downward it shrinks a
            # hot host toward the target; upward it restores a cooled
            # host only as far as the model predicts stays under target
            # (rate-capped by restore_factor), so there is no blind
            # probe overshoot and the share settles at a fixed point.
            m = cfg.target_ratio * med / max(durs[h], 1e-12)
            if m < 1.0:
                at_floor = self.shares[h] <= cfg.min_share * 1.001
                if flags[h] and at_floor \
                        and self._consec[h] >= cfg.exclude_after:
                    self.excluded.add(h)
                    self.shares[h] = 0.0
                    self.events.append({
                        "kind": "exclude-host", "step": step, "host": h,
                        "penalty": round(float(self._penalty[h]), 3)})
                    continue
                new = max(cfg.min_share, self.shares[h] * m)
                if new < self.shares[h]:
                    self.events.append({"kind": "rebalance", "step": step,
                                        "host": h,
                                        "share": round(float(new), 5),
                                        "ratio": round(durs[h] / med, 3)})
                self.shares[h] = new
            elif (self.shares[h] < uniform * 0.999
                    and self._penalty[h] < 0.25
                    and self._clean[h] >= cfg.recover_after):
                self.shares[h] = min(uniform,
                                     self.shares[h]
                                     * min(m, cfg.restore_factor))
                if self.shares[h] >= uniform * 0.999:
                    self.detector.reset(h)
                    self.events.append({"kind": "host-recovered",
                                        "step": step, "host": h})
        total = float(self.shares.sum())
        if total > 0:
            self.shares = self.shares / total
        if (not self.excluded
                and np.all(np.abs(self.shares - uniform) < 1e-3)
                and np.all(self._penalty < 0.25)):
            # fully recovered: snap renormalization drift to exact uniform
            self.shares = np.full(self.nhosts, uniform)
        return {"step": int(step), "flags": flags,
                "shares": [round(float(s), 5) for s in self.shares],
                "excluded": sorted(self.excluded)}

    def reset(self, host: int) -> None:
        """Forgive a host entirely: re-admit it at the uniform share with
        fresh detection state (operator override / post-repair)."""
        self.excluded.discard(host)
        self.detector.reset(host)
        self._clean[host] = 0
        self._consec[host] = 0
        self._penalty[host] = 0.0
        self.shares[host] = 1.0 / self.nhosts
        self.shares = self.shares / self.shares.sum()
        self.events.append({"kind": "host-reset", "host": int(host)})
