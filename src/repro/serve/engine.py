"""Serving engine: prefill + batched synchronized decode with optional
cuSZ-compressed KV cache."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core import kvcache as KVC


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    s_max: int = 2048
    compressed_kv: bool = False
    kv_codec: str = "int8-block"     # registry id of the in-memory KV codec
    temperature: float = 0.0         # 0 = greedy
    compute_dtype: object = jnp.bfloat16


def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            scfg: ServeConfig, extra=None):
    """Run the prompt through the parallel forward, build decode caches.
    Returns (last_logits [B,V], DecodeCaches, prompt_len)."""
    logits, caches = M.forward(params, cfg, tokens, extra,
                               compute_dtype=scfg.compute_dtype,
                               collect_caches=True)
    B, S = tokens.shape
    S_total = S + cfg.n_prepend_embeds
    entries = []
    for kind, c in zip(cfg.pattern, caches):
        if kind.startswith("attn"):
            if cfg.mla:
                ext = jnp.zeros(c.shape[:2] + (scfg.s_max - S_total,)
                                + c.shape[3:], c.dtype)
                entries.append(jnp.concatenate([c, ext], axis=2))
            else:
                k, v = c
                kv_codec = (codecs.get_block_codec(scfg.kv_codec, axis=2,
                                                   block=KVC.SEQ_BLOCK)
                            if scfg.compressed_kv else None)

                def extend(x):
                    ext = jnp.zeros(x.shape[:2] + (scfg.s_max - S_total,)
                                    + x.shape[3:], x.dtype)
                    full = jnp.concatenate([x, ext], axis=2)
                    if kv_codec is not None:
                        # registry codec produces the container; the
                        # decode-step hot path keeps its payload as the
                        # in-memory QuantKV cache format
                        cont = kv_codec.encode(full)
                        return KVC.QuantKV(cont.payload["q"],
                                           cont.payload["scale"])
                    return full
                entries.append((extend(k), extend(v)))
        else:
            entries.append(c)        # MambaState carries over directly
    return logits[:, -1, :], M.DecodeCaches(tuple(entries)), S_total


def make_serve_step(cfg: ModelConfig, scfg: ServeConfig):
    """Jittable one-token decode for a synchronized batch."""

    def step(params, token, caches, cache_len):
        return M.decode_step(params, cfg, token, caches, cache_len,
                             compute_dtype=scfg.compute_dtype,
                             compressed_kv=scfg.compressed_kv)

    return step


def generate(params, cfg: ModelConfig, prompt: jax.Array, n_new: int,
             scfg: ServeConfig, extra=None, key=None):
    """Greedy/temperature generation for a batch of equal-length prompts.
    Returns [B, n_new] int32."""
    step_fn = jax.jit(make_serve_step(cfg, scfg))
    last_logits, caches, plen = prefill(params, cfg, prompt, scfg, extra)
    B = prompt.shape[0]
    outs = []
    key = key if key is not None else jax.random.PRNGKey(0)

    def pick(logits, k):
        if scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / scfg.temperature
                                      ).astype(jnp.int32)

    key, k0 = jax.random.split(key)
    tok = pick(last_logits, k0)[:, None]
    for i in range(n_new):
        outs.append(tok[:, 0])
        logits, caches = step_fn(params, tok, caches,
                                 jnp.int32(plen + i))
        key, ki = jax.random.split(key)
        tok = pick(logits[:, 0, :], ki)[:, None]
    return jnp.stack(outs, axis=1)
