"""Serving engine: prefill + batched synchronized decode with optional
cuSZ-compressed KV cache, split into disaggregation-ready phases:

  1. **prefill** — run the prompt through the parallel forward under the
     *prefill* mesh/shardings and build the decode caches (optionally
     already in the in-memory QuantKV compressed format).
  2. **handoff** — ``encode_handoff`` turns every cache tensor into
     per-SEQ_BLOCK-slab registry Containers (`int8-block` wire by
     default, `cusz` for the host-offload leg); the Containers — never
     decoded f32 — are what crosses the prefill->decode mesh boundary.
  3. **reshard** — ``reshard_caches`` adopts the containers under the
     *decode* mesh: int8-block payloads become the in-memory QuantKV
     cache directly (zero re-quantization round trip), other wires
     decode/quantize jitted with the decode mesh's shardings.
  4. **decode** — ``decode_tokens`` runs the jitted one-token step (one
     compiled executable per ``(cfg, scfg)``, cached across calls).

``generate`` composes 1+4 for the single-mesh path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.dist import context as dist_ctx
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core import kvcache as KVC


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    s_max: int = 2048
    compressed_kv: bool = False
    kv_codec: str = "int8-block"     # registry id of the in-memory KV codec
    temperature: float = 0.0         # 0 = greedy
    compute_dtype: object = jnp.bfloat16


#: seq axis of every prefill cache entry ([n_periods, B, S, ...])
HANDOFF_SEQ_AXIS = 2


# ---------------------------------------------------------------------------
# Phase 1: prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            scfg: ServeConfig, extra=None):
    """Run the prompt through the parallel forward, build decode caches.
    Returns (last_logits [B,V], DecodeCaches, prompt_len)."""
    logits, caches = M.forward(params, cfg, tokens, extra,
                               compute_dtype=scfg.compute_dtype,
                               collect_caches=True)
    B, S = tokens.shape
    S_total = S + cfg.n_prepend_embeds
    kv_codec = (codecs.get_block_codec(scfg.kv_codec,
                                       axis=HANDOFF_SEQ_AXIS,
                                       block=KVC.SEQ_BLOCK)
                if scfg.compressed_kv else None)

    def extend(x):
        """Pad the seq axis to s_max; under compressed_kv the full buffer
        becomes the registry codec's payload, kept as the in-memory
        QuantKV format the decode-step hot path indexes directly."""
        ext = jnp.zeros(x.shape[:2] + (scfg.s_max - S_total,)
                        + x.shape[3:], x.dtype)
        full = jnp.concatenate([x, ext], axis=HANDOFF_SEQ_AXIS)
        if kv_codec is not None:
            cont = kv_codec.encode(full)
            return KVC.QuantKV(cont.payload["q"], cont.payload["scale"])
        return full

    entries = []
    for kind, c in zip(cfg.pattern, caches):
        if kind.startswith("attn"):
            if cfg.mla:
                # the MLA latent cache goes through the same block codec
                # as GQA K/V — compressed_kv is honored, not ignored
                entries.append(extend(c))
            else:
                k, v = c
                entries.append((extend(k), extend(v)))
        else:
            entries.append(c)        # MambaState carries over directly
    return logits[:, -1, :], M.DecodeCaches(tuple(entries)), S_total


# ---------------------------------------------------------------------------
# Phase 4: decode (jitted step, cached per config)
# ---------------------------------------------------------------------------

#: traces per (cfg, scfg) key — regression guard that `generate` reuses
#: the compiled step across calls instead of re-jitting every invocation
STEP_TRACES: Dict[Any, int] = {}


def make_serve_step(cfg: ModelConfig, scfg: ServeConfig):
    """Jittable one-token decode for a synchronized batch."""

    def step(params, token, caches, cache_len):
        # body runs only while tracing, so this counts (re)traces
        STEP_TRACES[(cfg, scfg)] = STEP_TRACES.get((cfg, scfg), 0) + 1
        return M.decode_step(params, cfg, token, caches, cache_len,
                             compute_dtype=scfg.compute_dtype,
                             compressed_kv=scfg.compressed_kv)

    return step


@functools.lru_cache(maxsize=None)
def get_serve_step(cfg: ModelConfig, scfg: ServeConfig):
    """The jitted serve step for `(cfg, scfg)`.  Cached: repeated
    `generate` calls reuse one compiled executable instead of discarding
    it per invocation (configs are frozen dataclasses, so the key is a
    stable hash)."""
    return jax.jit(make_serve_step(cfg, scfg))


def pick_token(logits, k, scfg: ServeConfig):
    """Greedy / temperature sampling from [B, V] logits -> [B] int32.
    Shared by `decode_tokens` and the continuous-batching scheduler's
    per-slot step."""
    if scfg.temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(k, logits / scfg.temperature
                                  ).astype(jnp.int32)


_pick = pick_token


def decode_tokens(params, cfg: ModelConfig, scfg: ServeConfig,
                  last_logits: jax.Array, caches: M.DecodeCaches,
                  plen: int, n_new: int, key=None):
    """Synchronized-batch decode loop from prefilled (or resharded)
    caches.  Returns [B, n_new] int32."""
    step_fn = get_serve_step(cfg, scfg)
    key = key if key is not None else jax.random.PRNGKey(0)
    key, k0 = jax.random.split(key)
    tok = _pick(last_logits, k0, scfg)[:, None]
    outs = []
    for i in range(n_new):
        outs.append(tok[:, 0])
        logits, caches = step_fn(params, tok, caches, jnp.int32(plen + i))
        key, ki = jax.random.split(key)
        tok = _pick(logits[:, 0, :], ki, scfg)[:, None]
    return jnp.stack(outs, axis=1)


def generate(params, cfg: ModelConfig, prompt: jax.Array, n_new: int,
             scfg: ServeConfig, extra=None, key=None):
    """Greedy/temperature generation for a batch of equal-length prompts
    (single-mesh path: prefill and decode share placement).
    Returns [B, n_new] int32."""
    last_logits, caches, plen = prefill(params, cfg, prompt, scfg, extra)
    return decode_tokens(params, cfg, scfg, last_logits, caches, plen,
                         n_new, key=key)


# ---------------------------------------------------------------------------
# Phases 2+3: compressed prefill->decode handoff across the serve reshard
# ---------------------------------------------------------------------------

class KVHandoff(NamedTuple):
    """Everything that crosses the prefill->decode mesh boundary: per
    pattern entry, a tuple of per-tensor Container tuples (attn K/V and
    MLA latents as per-seq-slab wire containers; Mamba/SSD state as
    lossless containers).  No decoded f32 rides here."""
    kinds: Tuple[str, ...]           # per entry: "kv" | "mla" | "state"
    entries: Tuple[Any, ...]
    plen: int
    wire: str


#: telemetry of the most recent encode_handoff / reshard_caches call
LAST_HANDOFF_STATS: Dict[str, Any] = {}
LAST_RESHARD_STATS: Dict[str, Any] = {}


def encode_handoff(caches: M.DecodeCaches, cfg: ModelConfig,
                   scfg: ServeConfig, *, plen: int,
                   wire: Optional[str] = None,
                   nslabs: Optional[int] = None,
                   wire_cfg: Optional[dict] = None) -> KVHandoff:
    """Phase 2: encode the prefill caches into wire Containers.

    `plen` (the prefill length, as returned by ``prefill``) rides in the
    handoff so the decode side resumes from the right position without
    out-of-band metadata.  `wire` resolution: explicit arg > the armed
    ``dist.context.use_kv_reshard_compress`` hook (an explicit disarm
    resolves to "lossless") > "int8-block".  Cache tensors are sliced
    into per-SEQ_BLOCK seq slabs (`nslabs` overrides the slab count) and
    each slab is packed to its host storage form — the container
    payloads are the bytes that move.  Updates ``LAST_HANDOFF_STATS``
    with the wire accounting."""
    wire = wire or dist_ctx.kv_reshard_codec() or "int8-block"
    item = np.dtype(jnp.bfloat16).itemsize
    # reset at call START, not return: back-to-back sessions must never
    # read the previous call's wire accounting, and a failed handoff
    # leaves partial (not stale-successful) stats behind
    LAST_HANDOFF_STATS.clear()
    LAST_HANDOFF_STATS.update(
        {"wire": wire, "tensors": 0, "containers": 0,
         "wire_bytes": 0, "raw_bf16_bytes": 0, "lossless_fallback": 0})
    stats = LAST_HANDOFF_STATS

    def account(parts, raw_bytes):
        stats["tensors"] += 1
        stats["containers"] += len(parts)
        stats["wire_bytes"] += KVC.kv_wire_nbytes(parts)
        stats["raw_bf16_bytes"] += raw_bytes
        return parts

    def ship(x):
        n = x.q.size if isinstance(x, KVC.QuantKV) else x.size
        parts = KVC.kv_wire_encode(
            x, HANDOFF_SEQ_AXIS, wire=wire, nslabs=nslabs,
            source_dtype=scfg.compute_dtype, wire_cfg=wire_cfg)
        if wire != "lossless":
            # slabs the wire codec could not represent faithfully were
            # re-encoded raw by kv_wire_encode (graceful degradation)
            stats["lossless_fallback"] += sum(
                1 for p in parts if p.header.codec == "lossless")
        return account(parts, int(n) * item)

    lossless = codecs.get("lossless")

    def ship_state(x):
        # recurrent state has no seq axis and stays lossless; its raw
        # baseline is its actual bytes, not the bf16 K/V equivalent
        return account((lossless.pack(lossless.encode(x)),),
                       int(x.size) * np.dtype(x.dtype).itemsize)

    kinds, entries = [], []
    for kind, c in zip(cfg.pattern, caches.entries):
        if kind.startswith("attn"):
            if cfg.mla:
                kinds.append("mla")
                entries.append((ship(c),))
            else:
                kinds.append("kv")
                entries.append((ship(c[0]), ship(c[1])))
        else:
            kinds.append("state")
            entries.append(tuple(ship_state(x) for x in c))
    return KVHandoff(tuple(kinds), tuple(entries), int(plen), wire)


# jitted decode/quantize caches: one compile per (codec/placement)
# signature, not one per cache tensor per reshard.  Bounded LRU: an
# elastic fleet resharding onto fresh decode meshes must not accumulate
# executables (and pinned Mesh objects) for every retired placement.

@functools.lru_cache(maxsize=64)
def _jitted_wire_decode(codec, shape, dtype_name, shd):
    like = jax.ShapeDtypeStruct(shape, np.dtype(dtype_name))
    fn = lambda c: codec.decode(c, like=like)              # noqa: E731
    return jax.jit(fn, out_shardings=shd) if shd is not None else jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _jitted_kv_quantize(shape, dtype_name, out_shd):
    fn = lambda x: KVC.kv_quantize(x, HANDOFF_SEQ_AXIS)    # noqa: E731
    return (jax.jit(fn, out_shardings=out_shd)
            if out_shd is not None else jax.jit(fn))


def reshard_caches(handoff: KVHandoff, cfg: ModelConfig, scfg: ServeConfig,
                   *, mesh=None) -> M.DecodeCaches:
    """Phase 3: adopt the handoff Containers as decode caches under the
    *decode* mesh (default: the ambient ``dist.context`` mesh; None =
    single-device).

    int8-block wire + compressed decode target: the payload (q + block
    scales) IS the in-memory QuantKV format — it is concatenated in
    payload space and placed directly, with **no f32 round trip and no
    re-quantization**.  Any other combination decodes (and, for a
    compressed target, re-quantizes) jitted with the decode mesh's
    shardings as out_shardings.  Updates ``LAST_RESHARD_STATS``."""
    mesh = mesh if mesh is not None else dist_ctx.current_mesh()
    # reset at call start (same contract as LAST_HANDOFF_STATS)
    LAST_RESHARD_STATS.clear()
    LAST_RESHARD_STATS.update({"tensors": 0, "adopted_quantkv": 0,
                               "decoded": 0})
    stats = LAST_RESHARD_STATS

    def put(x, *spec):
        if mesh is None:
            return jnp.asarray(x)
        return jax.device_put(
            x, dist_ctx.resolve_sharding(mesh, x.shape, *spec))

    def shd(shape, *spec):
        return (dist_ctx.resolve_sharding(mesh, shape, *spec)
                if mesh is not None else None)

    def arrive(parts):
        """One cache tensor's wire containers -> its decode-side form."""
        stats["tensors"] += 1
        # a slab that failed wire-codec validation arrives as "lossless";
        # adoption/payload-concat need a homogeneous wire, so any mix
        # routes through the per-part decode path (kv_wire_restore reads
        # each part's own header)
        part_codecs = {p.header.codec for p in parts}
        wire_name = (parts[0].header.codec if len(part_codecs) == 1
                     else "mixed")
        full_shape = list(KVC.kv_slab_shape(parts[0]))
        full_shape[HANDOFF_SEQ_AXIS] = sum(
            int(KVC.kv_slab_shape(p)[HANDOFF_SEQ_AXIS]) for p in parts)
        full_shape = tuple(full_shape)
        if scfg.compressed_kv:
            if wire_name == "int8-block":
                # zero-round-trip adoption: q/scale payloads become the
                # QuantKV cache as-is
                qkv = KVC.kv_wire_adopt(parts, HANDOFF_SEQ_AXIS)
                stats["adopted_quantkv"] += 1
                return KVC.QuantKV(put(qkv.q, None, "data", "model"),
                                   put(qkv.scale, None, "data", "model"))
            # lossy/raw wire: restore (host/any-device) then quantize
            # jitted under the decode mesh's shardings
            full = KVC.kv_wire_restore(parts, HANDOFF_SEQ_AXIS,
                                       dtype=scfg.compute_dtype)
            stats["decoded"] += 1
            out_shd = None
            if mesh is not None:
                sc_shape = list(full_shape)
                sc_shape[HANDOFF_SEQ_AXIS] //= KVC.SEQ_BLOCK
                out_shd = KVC.QuantKV(
                    shd(full_shape, None, "data", "model"),
                    shd(tuple(sc_shape), None, "data", "model"))
            full = put(full, None, "data", "model")
            return _jitted_kv_quantize(full_shape, full.dtype.name,
                                       out_shd)(full)
        # dense decode target
        stats["decoded"] += 1
        if wire_name == "int8-block":
            codec = codecs.get_block_codec("int8-block",
                                           axis=HANDOFF_SEQ_AXIS,
                                           block=KVC.SEQ_BLOCK)
            unpacked = [codec.unpack(p) for p in parts]
            merged = (unpacked[0] if len(unpacked) == 1 else
                      codecs.concat_containers(
                          unpacked, HANDOFF_SEQ_AXIS,
                          codec.payload_axes(HANDOFF_SEQ_AXIS)))
            return _jitted_wire_decode(
                codec, full_shape, np.dtype(scfg.compute_dtype).name,
                shd(full_shape, None, "data", "model"))(merged)
        full = KVC.kv_wire_restore(parts, HANDOFF_SEQ_AXIS,
                                   dtype=scfg.compute_dtype)
        return put(full, None, "data", "model")

    entries = []
    for kind, entry in zip(handoff.kinds, handoff.entries):
        if kind == "kv":
            entries.append((arrive(entry[0]), arrive(entry[1])))
        elif kind == "mla":
            entries.append(arrive(entry[0]))
        else:                        # "state": lossless whole tensors
            from repro.models import ssm as ssm_mod
            vals = []
            for parts in entry:
                stats["tensors"] += 1
                stats["decoded"] += 1
                vals.append(put(codecs.decode(parts[0]), None, "data"))
            entries.append(ssm_mod.MambaState(*vals))
    return M.DecodeCaches(tuple(entries))
