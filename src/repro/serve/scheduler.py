"""Continuous-batching scheduler over the paged compressed-KV pool.

Requests arrive over time; the scheduler keeps a fixed-width batch of
decode *slots* hot and refills slots the moment a sequence retires —
instead of the engine's synchronized waves, where the whole batch waits
for its slowest member.  The decode step is one vmapped executable that
compiles exactly ONCE per ``(cfg, scfg, schedcfg)`` across arbitrary
admission/retire churn: batch composition changes by *writing buffers*
(adopting pool pages into a slot), never by changing traced shapes.

Lifecycle of a request:

  admit   — prefill (B=1) under the pool-occupancy budget, slice the
            prefilled cache into SEQ_BLOCK pages (`kv_page_slice`
            payload-space — bit-identical to the whole-tensor PR-5
            path), park them in the `PagedKVPool`, adopt them into a
            free decode slot.
  decode  — every step runs all live slots through the vmapped step
            (per-slot cache_len, so ragged positions coexist); a slot
            crossing a SEQ_BLOCK boundary reserves its next pool page.
  retire  — on EOS or max_new: flush the slot back to its pages,
            release them, free the slot for the next admission.
  preempt — when admission needs pages the free list can't provide:
            first evict cold *parked* pages to host through the pool's
            eviction codec, then flush + evict the most recently
            admitted running sequence and requeue it at the front
            (it resumes from its pages — no re-prefill).

``run_static`` is the ablation baseline: the same machinery restricted
to wave admission (only admit when the batch is empty), which is the
engine's synchronized-batch behavior on the same pool budget.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvcache as KVC
from repro.models import model as M
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.serve import engine as E
from repro.serve.pool import PagedKVPool, PoolExhausted


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Static knobs of one scheduler instance (frozen: rides in the jit
    cache key next to ``ModelConfig`` / ``ServeConfig``)."""
    max_batch: int = 4               # decode slots
    pool_pages: int = 64             # device page budget (shared)
    admit_frac: float = 1.0          # admit only below this occupancy
    evict_codec: Optional[str] = None  # pool eviction codec (None=resolve)
    continuous: bool = True          # False = wave (static) admission
    eos_id: int = -1                 # -1: never fires (synthetic load)
    preempt: bool = True             # allow preemption-by-eviction


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Any                      # [plen] int32 (host or device)
    max_new: int
    arrival: int = 0                 # decode-step index of arrival


# ---------------------------------------------------------------------------
# the batched decode step: vmapped per-slot M.decode_step, compiled once
# ---------------------------------------------------------------------------

#: traces per (cfg, scfg, max_batch) — the compile-exactly-once guard:
#: admission/retire churn must never re-trace the batched step
BATCH_STEP_TRACES: Dict[Any, int] = {}


def make_batch_step(cfg: ModelConfig, scfg: E.ServeConfig,
                    max_batch: int):
    """One-token decode for `max_batch` ragged slots: vmap over the
    batch axis with a PER-SLOT cache_len, so each lane attends to its
    own prefix while retired/empty lanes run harmlessly at len 0."""

    def batch_step(params, tokens, caches, lens, key):
        # body runs only while tracing, so this counts (re)traces
        k = (cfg, scfg, max_batch)
        BATCH_STEP_TRACES[k] = BATCH_STEP_TRACES.get(k, 0) + 1

        def one(tok, entries, clen, kk):
            # M.decode_step wants [B,1] token / batch-axis-1 caches;
            # run it at B=1 per lane under vmap
            c1 = jax.tree_util.tree_map(lambda x: jnp.expand_dims(x, 1),
                                        entries)
            logits, nc = M.decode_step(
                params, cfg, tok[None, :], M.DecodeCaches(c1), clen,
                compute_dtype=scfg.compute_dtype,
                compressed_kv=scfg.compressed_kv)
            nt = E.pick_token(logits[:, -1, :], kk, scfg)[0]
            return nt, jax.tree_util.tree_map(lambda x: jnp.squeeze(x, 1),
                                              nc.entries)

        keys = jax.random.split(key, tokens.shape[0])
        nt, entries = jax.vmap(one, in_axes=(0, 1, 0, 0),
                               out_axes=(0, 1))(tokens, caches.entries,
                                                lens, keys)
        return nt, M.DecodeCaches(entries)

    return batch_step


@functools.lru_cache(maxsize=None)
def get_batch_step(cfg: ModelConfig, scfg: E.ServeConfig,
                   max_batch: int):
    """The jitted batched step for `(cfg, scfg, max_batch)` — cached so
    a scheduler's whole run (and repeated runs at one config, including
    pool-size ablations) shares one compiled executable.  Pool knobs are
    deliberately NOT part of the key: the step never sees them."""
    return jax.jit(make_batch_step(cfg, scfg, max_batch))


# ---------------------------------------------------------------------------
# slot <-> pool page movement (eager buffer writes; shapes never change)
# ---------------------------------------------------------------------------

def _leaf_paths(cfg: ModelConfig):
    """Per pattern entry: "kv" | "mla" | "state" (pool pages carry the
    attn leaves; recurrent state is an unpaged per-sequence sidecar)."""
    return ["mla" if cfg.mla else "kv" if kind.startswith("attn")
            else "state" for kind in cfg.pattern]


def _attn_leaves(cfg: ModelConfig, entries) -> List[KVC.QuantKV]:
    out = []
    for kind, e in zip(_leaf_paths(cfg), entries):
        if kind == "kv":
            out.extend(e)
        elif kind == "mla":
            out.append(e)
    return out


def _state_entries(cfg: ModelConfig, entries):
    return [e for kind, e in zip(_leaf_paths(cfg), entries)
            if kind == "state"]


def _rebuild_entries(cfg: ModelConfig, attn_leaves, states):
    ai, si, entries = 0, 0, []
    for kind in _leaf_paths(cfg):
        if kind == "kv":
            entries.append((attn_leaves[ai], attn_leaves[ai + 1]))
            ai += 2
        elif kind == "mla":
            entries.append(attn_leaves[ai])
            ai += 1
        else:
            entries.append(states[si])
            si += 1
    return tuple(entries)


def _adopt_slot(buf: KVC.QuantKV, page_slabs: List[KVC.QuantKV],
                slot: int, seq_axis: int) -> KVC.QuantKV:
    """Write a sequence's pages into decode-slot `slot` of a batched
    buffer ([nP, max_batch, s_max, ...]).  The tail past the written
    pages is reset to the zero/SCALE_FLOOR extension pattern — the same
    bits `prefill` produces for the padded region — so slot reuse never
    leaks a previous occupant and adoption stays bit-identical to the
    whole-tensor path."""
    n = len(page_slabs)
    q_rows = jnp.concatenate([s.q[:, 0] for s in page_slabs],
                             axis=seq_axis - 1) if n else None
    sc_rows = jnp.concatenate([s.scale[:, 0] for s in page_slabs],
                              axis=seq_axis - 1) if n else None
    q_slot = jnp.zeros(buf.q.shape[:1] + buf.q.shape[2:], buf.q.dtype)
    sc_slot = jnp.full(buf.scale.shape[:1] + buf.scale.shape[2:],
                       KVC.SCALE_FLOOR, buf.scale.dtype)
    if n:
        q_slot = jax.lax.dynamic_update_slice_in_dim(
            q_slot, q_rows, 0, seq_axis - 1)
        sc_slot = jax.lax.dynamic_update_slice_in_dim(
            sc_slot, sc_rows, 0, seq_axis - 1)
    return KVC.QuantKV(buf.q.at[:, slot].set(q_slot),
                       buf.scale.at[:, slot].set(sc_slot))


def _flush_slot(buf: KVC.QuantKV, slot: int, n_pages: int,
                seq_axis: int) -> List[KVC.QuantKV]:
    """Read `n_pages` page slabs back out of decode-slot `slot` (inverse
    of `_adopt_slot`; keeps the pool batch axis of width 1)."""
    one = KVC.QuantKV(buf.q[:, slot:slot + 1],
                      buf.scale[:, slot:slot + 1])
    return [KVC.kv_page_slice(one, seq_axis, i) for i in range(n_pages)]


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

class ContinuousScheduler:
    """Drives the batched decode step over a shared `PagedKVPool`."""

    def __init__(self, params, cfg: ModelConfig, scfg: E.ServeConfig,
                 schedcfg: SchedulerConfig, *, key=None):
        if not scfg.compressed_kv:
            raise ValueError(
                "the paged pool stores int8-block pages; build the "
                "ServeConfig with compressed_kv=True")
        if scfg.s_max % KVC.SEQ_BLOCK:
            raise ValueError(f"s_max must be a multiple of "
                             f"{KVC.SEQ_BLOCK}, got {scfg.s_max}")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.schedcfg = schedcfg
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.pool = PagedKVPool(schedcfg.pool_pages,
                                evict_codec=schedcfg.evict_codec,
                                source_dtype=scfg.compute_dtype,
                                seq_axis=E.HANDOFF_SEQ_AXIS)
        self.seq_axis = E.HANDOFF_SEQ_AXIS
        self.step_fn = get_batch_step(cfg, scfg, schedcfg.max_batch)
        B = schedcfg.max_batch
        self.caches = M.init_caches(cfg, B, scfg.s_max,
                                    dtype=scfg.compute_dtype,
                                    compressed_kv=True)
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lens = np.zeros((B,), np.int32)      # host mirror of cache_len
        self.slots: List[Optional[Dict[str, Any]]] = [None] * B
        self.queue: List[Request] = []
        self.finished: Dict[int, Dict[str, Any]] = {}
        #: per-sequence recurrent-state sidecar (hybrid archs): MambaState
        #: has no seq axis, so it bypasses the pool and parks per-sid
        self.states: Dict[int, List[Any]] = {}
        #: preempted-but-not-yet-resumed progress, keyed by rid
        self._suspended: Dict[int, Dict[str, Any]] = {}
        self._admit_counter = 0
        self.n_steps = 0
        self.preemptions = 0
        self.occupancy_samples: List[float] = []

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_pages(self, req: Request):
        """Prefill one request (B=1) and slice its caches into pool page
        slabs.  Returns (page_slabs_per_page, states, first_token,
        plen)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        last, caches, plen = E.prefill(self.params, self.cfg, prompt,
                                       self.scfg)
        self.key, k0 = jax.random.split(self.key)
        t0 = int(E.pick_token(last, k0, self.scfg)[0])  # repro-lint: allow[host-sync] admission needs the first sampled token on host to seed the slot

        leaves = _attn_leaves(self.cfg, caches.entries)
        n_pages = KVC.kv_page_count(plen)
        pages = [tuple(KVC.kv_page_slice(lv, self.seq_axis, i)
                       for lv in leaves) for i in range(n_pages)]
        return pages, _state_entries(self.cfg, caches.entries), t0, plen

    def _reclaim(self, need: int, protect) -> int:
        """Free >= `need` device pages: cold *parked* pages first, then
        preemption of the most recently admitted running sequence.
        Running sequences are never cold-evicted directly — their pool
        pages are reservations whose authoritative content lives in the
        decode buffers until a flush — only `_preempt` (flush first)
        takes pages away from them."""
        running = {s["rid"] for s in self.slots if s is not None}
        freed = self.pool.evict_cold(need, exclude=set(protect) | running)
        while freed < need and self.schedcfg.preempt:
            victim = self._pick_victim(protect)
            if victim is None:
                break
            freed += self._preempt(victim)
        return freed

    def _pick_victim(self, protect) -> Optional[int]:
        running = [(s["admit_order"], i)
                   for i, s in enumerate(self.slots)
                   if s is not None and s["rid"] not in protect]
        if not running:
            return None
        return max(running)[1]       # most recently admitted loses

    def _preempt(self, slot: int) -> int:
        """Flush a running sequence to its pages, evict them all, and
        requeue it at the FRONT (it resumes exactly where it stopped —
        its generated tokens and position ride in the requeued state)."""
        s = self.slots[slot]
        self._flush_to_pool(slot)
        freed = self.pool.evict_sequence(s["rid"])
        req = Request(rid=s["rid"], prompt=s["req"].prompt,
                      max_new=s["req"].max_new, arrival=s["req"].arrival)
        self.queue.insert(0, req)
        self._suspended[s["rid"]] = {
            "generated": s["generated"], "plen": s["plen"],
            "next_token": s["next_token"], "t_submit": s["t_submit"]}
        self.slots[slot] = None
        self.lens[slot] = 0
        self.preemptions += 1
        return freed

    def _flush_to_pool(self, slot: int) -> None:
        """Write a running slot's cache content back into its reserved
        pool pages (content lives in the decode buffers while running;
        the pool holds reservations)."""
        s = self.slots[slot]
        leaves = _attn_leaves(self.cfg, self.caches.entries)
        n_pages = self.pool.n_pages_of(s["rid"])
        per_leaf = [_flush_slot(lv, slot, n_pages, self.seq_axis)
                    for lv in leaves]
        for i in range(n_pages):
            self.pool.write_page(s["rid"], i,
                                 tuple(pl[i] for pl in per_leaf))
        self.states[s["rid"]] = [
            jax.tree_util.tree_map(lambda x: x[:, slot:slot + 1], st)
            for st in _state_entries(self.cfg, self.caches.entries)]

    def _admit_into(self, slot: int, req: Request, now: int) -> bool:
        """Try to admit one request into a free slot.  Returns False if
        the pool cannot cover its pages even after reclaim."""
        sc = self.schedcfg
        suspended = self._suspended.pop(req.rid, None)
        if suspended is not None:
            # resumed preemptee: pages already exist (possibly on host)
            need = self.pool.n_pages_of(req.rid) \
                - self.pool.n_resident(req.rid)
            if need > self.pool.free_pages:
                self._reclaim(need - self.pool.free_pages, {req.rid})
            try:
                self.pool.ensure_resident(req.rid)
            except PoolExhausted:
                self._suspended[req.rid] = suspended
                self.queue.insert(0, req)
                return False
            pages = self.pool.read_pages(req.rid)
            state = self.states.get(req.rid)
            plen = suspended["plen"]
            generated = suspended["generated"]
            t_next = suspended["next_token"]
            t_submit = suspended["t_submit"]
        else:
            n_pages = KVC.kv_page_count(len(req.prompt))
            budget = int(sc.admit_frac * self.pool.n_pages)
            if self.pool.used_pages + n_pages > budget:
                need = self.pool.used_pages + n_pages - budget
                if self._reclaim(need, set()) < need \
                        and self.pool.free_pages < n_pages:
                    return False
            page_slabs, state, t_next, plen = self._prefill_pages(req)
            try:
                self.pool.register(req.rid)
                for p in page_slabs:
                    self.pool.append_page(req.rid, p)
            except PoolExhausted:
                self.pool.release(req.rid)
                return False
            pages = self.pool.read_pages(req.rid)
            generated = []
            t_submit = now
        # adopt pages into the decode buffers at `slot`
        leaves = _attn_leaves(self.cfg, self.caches.entries)
        new_leaves = [
            _adopt_slot(lv, [pg[j] for pg in pages], slot, self.seq_axis)
            for j, lv in enumerate(leaves)]
        states = _state_entries(self.cfg, self.caches.entries)
        if state:
            # prefill may carry the conv state at compute_dtype while the
            # batched buffer keeps the init_caches dtype — cast at adopt
            states = [jax.tree_util.tree_map(
                lambda full, one: full.at[:, slot].set(
                    one[:, 0].astype(full.dtype)),
                full_st, one_st)
                for full_st, one_st in zip(states, state)]
        self.caches = M.DecodeCaches(
            _rebuild_entries(self.cfg, new_leaves, states))
        self.tokens = self.tokens.at[slot, 0].set(jnp.int32(t_next))
        self.lens[slot] = plen + len(generated)
        self.slots[slot] = {
            "rid": req.rid, "req": req, "plen": plen,
            "generated": list(generated), "next_token": int(t_next),
            "admit_order": self._next_admit_order(),
            "t_submit": t_submit}
        self.pool.touch(req.rid)
        return True

    def _next_admit_order(self) -> int:
        self._admit_counter += 1
        return self._admit_counter

    def _admit(self, now: int) -> None:
        sc = self.schedcfg
        if not sc.continuous and any(s is not None for s in self.slots):
            return                   # wave mode: only refill empty batch
        for slot in range(sc.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            if not self._ready(req, now):
                break                # FIFO: later arrivals wait too
            self.queue.pop(0)
            if not self._admit_into(slot, req, now):
                if self.queue and self.queue[0].rid == req.rid:
                    break            # resume path requeued it itself
                self.queue.insert(0, req)
                break

    def _ready(self, req: Request, now: int) -> bool:
        return req.arrival <= now

    # -- the decode loop ---------------------------------------------------

    def _grow_pages(self) -> None:
        """Reserve the next pool page for any slot crossing a SEQ_BLOCK
        boundary this step (before the step writes position `lens`)."""
        for slot, s in enumerate(self.slots):
            if s is None:
                continue
            need = KVC.kv_page_count(int(self.lens[slot]) + 1)
            while self.pool.n_pages_of(s["rid"]) < need:
                try:
                    self.pool.append_page(s["rid"])
                except PoolExhausted:
                    # growth may preempt a *different* running sequence
                    # (most recent admit) but never the grower itself
                    if self._reclaim(1, {s["rid"]}) < 1:
                        raise RuntimeError(
                            f"pool too small: {self.pool.n_pages} pages "
                            f"cannot hold the running batch") from None
                    self.pool.append_page(s["rid"])

    def _step(self) -> None:
        self._grow_pages()
        self.key, k = jax.random.split(self.key)
        nt, self.caches = self.step_fn(
            self.params, self.tokens, self.caches,
            jnp.asarray(self.lens), k)
        self.n_steps += 1
        nt_host = np.asarray(jax.device_get(nt))  # repro-lint: allow[host-sync] scheduler control flow (retire/admit) branches on the sampled tokens
        for slot, s in enumerate(self.slots):
            if s is None:
                continue
            s["generated"].append(s["next_token"])
            s["next_token"] = int(nt_host[slot])
            self.lens[slot] += 1
            self.pool.touch(s["rid"])
        self.tokens = jnp.asarray(nt_host[:, None])

    def _retire(self, now: int) -> None:
        sc = self.schedcfg
        for slot, s in enumerate(self.slots):
            if s is None:
                continue
            done = len(s["generated"]) >= s["req"].max_new or (
                sc.eos_id >= 0 and s["generated"]
                and s["generated"][-1] == sc.eos_id)
            if not done:
                continue
            self.finished[s["rid"]] = {
                "rid": s["rid"], "tokens": list(s["generated"]),
                "plen": s["plen"], "t_submit": s["t_submit"],
                "t_finish": now}
            self.pool.release(s["rid"])
            self.states.pop(s["rid"], None)
            self.slots[slot] = None
            self.lens[slot] = 0

    def live(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def run(self, requests: List[Request],
            max_steps: Optional[int] = None) -> Dict[int, Dict[str, Any]]:
        """Drive the loop until every request finishes (or `max_steps`).
        Returns {rid: {tokens, plen, t_submit, t_finish}} with times in
        decode-step units."""
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(r)
        now = 0
        limit = max_steps if max_steps is not None else \
            _default_step_limit(requests, self.schedcfg)
        while (self.queue or self.live()) and now < limit:
            self._admit(now)
            if not self.live():
                # nothing running and nothing admissible yet: advance
                # time to the next arrival instead of spinning
                if self.queue and not self._ready(self.queue[0], now):
                    now += 1
                    continue
                if self.queue:
                    raise RuntimeError(
                        "pool too small: cannot admit "
                        f"request {self.queue[0].rid} into an empty batch")
                break
            self._step()
            now += 1
            self._retire(now)
            self.occupancy_samples.append(self.pool.occupancy)
        if self.queue or self.live():
            raise RuntimeError(
                f"step limit {limit} hit with {len(self.queue)} queued / "
                f"{self.live()} running sequences")
        return dict(self.finished)


def _default_step_limit(requests: List[Request],
                        sc: SchedulerConfig) -> int:
    total = sum(r.max_new for r in requests)
    last = max((r.arrival for r in requests), default=0)
    return 4 * (total + last) + 64


def run_static(params, cfg: ModelConfig, scfg: E.ServeConfig,
               schedcfg: SchedulerConfig, requests: List[Request],
               **kw) -> Tuple[Dict[int, Dict[str, Any]],
                              "ContinuousScheduler"]:
    """Wave-admission ablation: same pool, same step, admit only into an
    empty batch."""
    sc = dataclasses.replace(schedcfg, continuous=False)
    sched = ContinuousScheduler(params, cfg, scfg, sc, **kw)
    return sched.run(requests), sched


def run_continuous(params, cfg: ModelConfig, scfg: E.ServeConfig,
                   schedcfg: SchedulerConfig, requests: List[Request],
                   **kw) -> Tuple[Dict[int, Dict[str, Any]],
                                  "ContinuousScheduler"]:
    sc = dataclasses.replace(schedcfg, continuous=True)
    sched = ContinuousScheduler(params, cfg, scfg, sc, **kw)
    return sched.run(requests), sched
