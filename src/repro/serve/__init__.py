from . import engine  # noqa: F401
from . import pool  # noqa: F401
from . import scheduler  # noqa: F401
