"""Shared paged KV pool: int8-block-resident slab pages + cold eviction.

The continuous-batching serve layer parks every sequence's KV cache here
as *pages* — SEQ_BLOCK-aligned seq slabs in the in-memory QuantKV
payload form (``repro.core.kvcache.kv_page_slice``).  Because a page IS
the ``"int8-block"`` codec payload, adopting pages back into a decode
slot is pure payload-space movement: bit-identical to the PR-5
whole-tensor adopt path, zero re-quantization, zero f32 round trip.

Three jobs live here:

* **free-list page allocator** — ``n_pages`` device pages, allocated /
  freed as integer page ids; exhaustion raises `PoolExhausted` (the
  scheduler answers with eviction or preemption).
* **per-sequence page tables** — ordered pages per sequence id, each
  resident (device slabs) or evicted (host Containers), plus
  last-touch ordering for cold-first reclaim.
* **eviction / restore** — cold pages cross to host through a wire
  codec: ``"int8-block"`` packs the payload (bit-exact restore),
  ``"cusz"``/``"fz"`` re-compress the dequantized slab (higher ratio;
  restore decodes + re-quantizes under the codec's bound via a jitted,
  signature-cached path), ``"lossless"`` ships raw dequantized values.
  Codec resolution: explicit arg > the armed
  ``dist.context.use_kv_evict_codec`` hook > "cusz".

Accounting is exact by construction and asserted by the property suite:
``free + used == n_pages`` always, no page id is ever live twice, and
``used`` equals the number of resident pages across all tables.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import codecs
from repro.core import kvcache as KVC
from repro.dist import context as dist_ctx

#: seq axis of every cache slab ([n_periods, B, S, ...]) — the engine's
#: handoff layout, which pages inherit
PAGE_SEQ_AXIS = 2

#: eviction codecs the pool accepts beyond blockwise-configurable ones
_WHOLE_SLAB_CODECS = KVC.WHOLE_SLAB_WIRES


class PoolExhausted(RuntimeError):
    """No free device pages; the caller must evict or preempt first."""


class _Page:
    """One page of one sequence: resident (device slabs) xor evicted
    (host containers) xor reserved (neither, content pending flush)."""

    __slots__ = ("pid", "slabs", "host")

    def __init__(self, pid: Optional[int]):
        self.pid = pid                    # device page id; None = evicted
        self.slabs: Optional[Tuple[KVC.QuantKV, ...]] = None
        self.host: Optional[Tuple[Tuple, ...]] = None

    @property
    def resident(self) -> bool:
        return self.pid is not None


@functools.lru_cache(maxsize=64)
def _jitted_requantize(shape, dtype_name, seq_axis):
    """Jitted blockwise requantize for one restored-slab signature (the
    restore leg is the latency-pressured consumer: it runs while a
    resumed sequence waits for its decode slot).  The codec *decode*
    stays on host — cusz's Huffman blob lengths are host values — but
    the quantize that follows is one executable per shape signature."""
    fn = lambda x: KVC.kv_quantize(x, seq_axis)            # noqa: E731
    return jax.jit(fn)


def _evict_slab(slab: KVC.QuantKV, seq_axis: int, codec: str,
                source_dtype, codec_cfg: Optional[dict]) -> Tuple:
    return KVC.kv_page_encode(slab, seq_axis, codec=codec,
                              source_dtype=source_dtype,
                              codec_cfg=codec_cfg)


def _restore_slab(parts: Sequence, seq_axis: int,
                  source_dtype) -> KVC.QuantKV:
    if all(p.header.codec == "int8-block" for p in parts):
        return KVC.kv_page_adopt(parts, seq_axis)
    # a cusz-evicted slab may have degraded to "lossless" (validity
    # fallback in kv_page_encode); kv_wire_restore reads each part's own
    # header, then the jitted requantize rebuilds the in-memory page
    full = KVC.kv_wire_restore(parts, seq_axis, dtype=source_dtype)
    return _jitted_requantize(full.shape, full.dtype.name, seq_axis)(full)


class PagedKVPool:
    """Fixed-budget device page pool with per-sequence page tables."""

    def __init__(self, n_pages: int, *, evict_codec: Optional[str] = None,
                 evict_cfg: Optional[dict] = None,
                 source_dtype=jnp.bfloat16,
                 seq_axis: int = PAGE_SEQ_AXIS):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        evict_codec = (evict_codec or dist_ctx.kv_evict_codec()
                       or "cusz")
        if evict_codec not in _WHOLE_SLAB_CODECS:
            # same arm-time validation as the context hook: a blockwise
            # id must configure, anything else fails here, not mid-evict
            codecs.get_block_codec(evict_codec, axis=seq_axis,
                                   block=KVC.SEQ_BLOCK)
        self.n_pages = int(n_pages)
        self.evict_codec = evict_codec
        self.evict_cfg = evict_cfg
        self.source_dtype = source_dtype
        self.seq_axis = seq_axis
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._tables: Dict[Any, List[_Page]] = {}
        self._touch: Dict[Any, int] = {}
        self._clock = 0
        # counters (monotonic unless noted)
        self.evicted_pages = 0
        self.restored_pages = 0
        self.peak_used = 0
        self.host_bytes = 0               # current, not monotonic

    # -- allocator ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.used_pages / self.n_pages

    def _alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"all {self.n_pages} pool pages allocated; evict or "
                f"preempt before admitting more cache blocks")
        pid = self._free.pop()
        self.peak_used = max(self.peak_used, self.used_pages)
        return pid

    def _release_pid(self, pid: int) -> None:
        assert pid not in self._free, f"double free of page {pid}"
        self._free.append(pid)

    # -- page tables --------------------------------------------------------

    def register(self, sid) -> None:
        if sid in self._tables:
            raise ValueError(f"sequence {sid!r} already registered")
        self._tables[sid] = []
        self.touch(sid)

    def release(self, sid) -> int:
        """Drop a sequence: free its resident pages, forget host copies.
        Returns the number of device pages returned to the free list."""
        freed = 0
        for page in self._tables.pop(sid):
            if page.resident:
                self._release_pid(page.pid)
                freed += 1
            elif page.host is not None:
                self.host_bytes -= _host_nbytes(page.host)
        self._touch.pop(sid, None)
        return freed

    def has(self, sid) -> bool:
        return sid in self._tables

    def sequences(self):
        return list(self._tables)

    def n_pages_of(self, sid) -> int:
        return len(self._tables[sid])

    def n_resident(self, sid) -> int:
        return sum(1 for p in self._tables[sid] if p.resident)

    def touch(self, sid) -> None:
        self._clock += 1
        self._touch[sid] = self._clock

    def append_page(self, sid,
                    slabs: Optional[Tuple[KVC.QuantKV, ...]] = None) -> int:
        """Grow a sequence by one device page (content optional: a
        running sequence reserves the page now, flushes slabs later)."""
        pid = self._alloc()
        page = _Page(pid)
        page.slabs = tuple(slabs) if slabs is not None else None
        self._tables[sid].append(page)
        self.touch(sid)
        return pid

    def write_page(self, sid, idx: int,
                   slabs: Tuple[KVC.QuantKV, ...]) -> None:
        page = self._tables[sid][idx]
        if not page.resident:
            raise ValueError(
                f"page {idx} of {sid!r} is evicted; restore before writing")
        page.slabs = tuple(slabs)
        page.host = None

    def read_pages(self, sid) -> List[Tuple[KVC.QuantKV, ...]]:
        """All page contents of a sequence (must be fully resident)."""
        out = []
        for i, page in enumerate(self._tables[sid]):
            if not page.resident or page.slabs is None:
                raise ValueError(
                    f"page {i} of {sid!r} is not resident with content; "
                    f"call ensure_resident first")
            out.append(page.slabs)
        self.touch(sid)
        return out

    # -- eviction / restore -------------------------------------------------

    def evict_page(self, sid, idx: int) -> bool:
        """Push one resident page to host through the eviction codec and
        return its device page to the free list.  Returns False when the
        page is already on host."""
        page = self._tables[sid][idx]
        if not page.resident:
            return False
        if page.slabs is None:
            raise ValueError(
                f"page {idx} of {sid!r} is reserved but unwritten; flush "
                f"the decode slot before evicting a running sequence")
        page.host = tuple(
            _evict_slab(s, self.seq_axis, self.evict_codec,
                        self.source_dtype, self.evict_cfg)
            for s in page.slabs)
        page.slabs = None
        self._release_pid(page.pid)
        page.pid = None
        self.evicted_pages += 1
        self.host_bytes += _host_nbytes(page.host)
        return True

    def evict_sequence(self, sid) -> int:
        """Evict every resident page of a sequence; returns count."""
        return sum(self.evict_page(sid, i)
                   for i in range(len(self._tables[sid])))

    def restore_page(self, sid, idx: int) -> bool:
        """Bring one evicted page back: allocate a device page and run
        the jitted decode(+requantize) restore.  Returns False when the
        page is already resident.  Raises `PoolExhausted` when no page
        is free — the caller reclaims and retries."""
        page = self._tables[sid][idx]
        if page.resident:
            return False
        assert page.host is not None, (sid, idx)
        pid = self._alloc()
        page.slabs = tuple(
            _restore_slab(parts, self.seq_axis, self.source_dtype)
            for parts in page.host)
        self.host_bytes -= _host_nbytes(page.host)
        page.host = None
        page.pid = pid
        self.restored_pages += 1
        return True

    def ensure_resident(self, sid) -> int:
        """Restore every evicted page of a sequence; returns count."""
        n = 0
        for i, page in enumerate(self._tables[sid]):
            if not page.resident:
                self.restore_page(sid, i)
                n += 1
        self.touch(sid)
        return n

    def evict_cold(self, n: int, exclude=()) -> int:
        """Reclaim up to `n` device pages by evicting pages of the
        coldest (least recently touched) non-excluded sequences first.
        Returns how many pages were actually freed."""
        exclude = set(exclude)
        freed = 0
        order = sorted((s for s in self._tables if s not in exclude),
                       key=lambda s: self._touch.get(s, 0))
        for sid in order:
            for i, page in enumerate(self._tables[sid]):
                if freed >= n:
                    return freed
                if page.resident and page.slabs is not None:
                    self.evict_page(sid, i)
                    freed += 1
        return freed

    # -- accounting ---------------------------------------------------------

    def device_pids(self):
        """Set of live device page ids across all tables (test hook)."""
        return {p.pid for t in self._tables.values()
                for p in t if p.resident}

    def stats(self) -> Dict[str, Any]:
        host_pages = sum(1 for t in self._tables.values()
                         for p in t if p.host is not None)
        return {"n_pages": self.n_pages, "used": self.used_pages,
                "free": self.free_pages, "occupancy": self.occupancy,
                "peak_used": self.peak_used, "host_pages": host_pages,
                "host_bytes": self.host_bytes,
                "evicted_pages": self.evicted_pages,
                "restored_pages": self.restored_pages,
                "evict_codec": self.evict_codec,
                "sequences": len(self._tables)}


def _host_nbytes(host: Tuple[Tuple, ...]) -> int:
    return sum(KVC.kv_wire_nbytes(parts) for parts in host)
