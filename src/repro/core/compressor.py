"""End-to-end cuSZ pipeline: dual-quant -> outliers -> Huffman -> blob.

Every hot stage routes through the `repro.kernels` ops layer, so the
same pipeline runs the XLA reference impls, the interpret-mode Pallas
kernels (CI parity), or the compiled Pallas kernels (TPU/GPU), selected
by the dispatch policy: `CompressorConfig.kernel_impl`, overridden by
the `REPRO_KERNEL_IMPL` env var or a `kernels.dispatch.kernel_policy`
context.  The policy is resolved to a static `PipelinePolicy` outside
jit, so each policy gets its own compiled executable.

The forward dual-quant is ONE fused op (PREQUANT + Lorenzo delta +
POSTQUANT in a single blocked kernel invocation): the compressor never
materializes the int32 delta tree between separate stage dispatches —
outliers are extracted from the fused op's outputs directly (code 0 is
reserved for outliers, in-cap codes are >= 1 by construction).

`compress` / `decompress` are jittable for fixed (shape, config,
policy); the blob is a pytree of device arrays so it can live on-device
(e.g. checkpoint write path) or be pulled to host for storage.

Compressed-size accounting matches the paper's: Huffman bitstream (word
aligned per chunk) + sparse outliers + codebook (bitlengths suffice to
rebuild the canonical book) + the per-subchunk gap arrays that make the
decode parallel (Rivera et al., arXiv 2201.09118) + O(1) header.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.deflate import ops as deflate_ops
from repro.kernels.encode import ops as encode_ops
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.inflate import ops as inflate_ops
from repro.kernels.lorenzo import ops as lorenzo_ops

from . import dualquant as dq
from . import huffman as hf


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    eb: float = 1e-4                 # absolute error bound (see eb_mode)
    eb_mode: str = "abs"             # "abs" | "valrel" (relative to range)
    nbins: int = 1024                # quantization bins (paper default)
    chunk_size: int = 4096           # Huffman deflate chunk (symbols)
    sub_size: int = 128              # gap-array subchunk (symbols); the
    #   parallel decode unit — must divide chunk_size
    block: Optional[Tuple[int, ...]] = None   # Lorenzo block; None = paper default
    outlier_frac: float = 0.10       # sparse outlier capacity fraction
    use_tpu_blocks: bool = False     # lane-aligned blocks (beyond-paper)
    kernel_impl: Optional[str] = None  # dispatch default: "auto" | "jax" |
    #   "pallas" | "pallas-interpret"; None defers to the ambient policy

    def block_for(self, ndim: int) -> Tuple[int, ...]:
        if self.block is not None:
            return self.block
        table = dq.TPU_BLOCKS if self.use_tpu_blocks else dq.DEFAULT_BLOCKS
        if ndim <= 3:
            return table[ndim]
        # >3D (e.g. QMCPACK 4D): block the trailing 3 dims (paper treats
        # the leading dim as a batch of 3D fields)
        return (1,) * (ndim - 3) + table[3]


class CompressedBlob(NamedTuple):
    words: jax.Array         # [nc, chunk] uint32 deflated bitstream
    bits_used: jax.Array     # [nc] int32
    n_valid: jax.Array       # [nc] int32 symbols per chunk
    lengths: jax.Array       # [k] int32 codeword bitlengths (rebuilds book)
    out_idx: jax.Array       # [cap] int32 outlier flat indices (-1 fill)
    out_val: jax.Array       # [cap] int32 outlier deltas
    n_outliers: jax.Array    # scalar int32
    max_len: jax.Array       # scalar int32 practical max codeword length
    # gap arrays (None on format-v1 blobs, which decode sequentially):
    gap_bits: Optional[jax.Array] = None   # [nc, n_sub] int32 bit offset at
    #   every sub_size-symbol boundary (phase-1 of the two-phase decode)
    gap_syms: Optional[jax.Array] = None   # [nc, n_sub] int32 valid symbols
    #   before each boundary


@jax.jit
def _eb_stats(data: jax.Array) -> jax.Array:
    """min, max, max|d| as ONE fused reduction -> one [3] device array.
    One dispatch + one device_get per compress call (the previous form
    issued two separate blocking reductions)."""
    f = data.astype(jnp.float32)
    return jnp.stack([jnp.min(f), jnp.max(f), jnp.max(jnp.abs(f))])


def resolve_eb(cfg: CompressorConfig, data) -> float:
    # repro-lint: allow[host-sync] single fused 3-stat reduction; the eb
    # must be a host float (jit cache key) before compression starts
    dmin, dmax, amax = (float(v) for v in
                        np.asarray(jax.device_get(_eb_stats(data))))
    if cfg.eb_mode == "abs":
        eb = float(cfg.eb)
    else:
        rng = dmax - dmin
        eb = float(cfg.eb) * (rng if rng > 0 else 1.0)
    # fp32/int32 domain guard (paper stores d° in FP for the same reason):
    # d° = d/(2eb) must stay within exact-integer float32/int32 range,
    # otherwise the bound is unrepresentable in fp32 to begin with.
    if amax > 0 and amax / (2 * eb) >= 2 ** 23:
        raise ValueError(
            f"error bound {eb:g} is below float32 resolution for data with "
            f"max |d|={amax:g} (d° would exceed 2^23); choose eb >= "
            f"{amax / 2 ** 24:g}")
    return eb


def _shape_meta(shape, cfg):
    ndim = len(shape)
    block = cfg.block_for(ndim)
    pshape = dq.padded_shape(shape, block)
    n = int(np.prod(pshape))
    cap = max(16, int(n * cfg.outlier_frac))
    return ndim, block, pshape, n, cap


@partial(jax.jit, static_argnames=("cfg", "eb", "pp"))
def _compress_impl(data: jax.Array, cfg: CompressorConfig, eb: float,
                   pp: dispatch.PipelinePolicy) -> CompressedBlob:
    ndim, block, pshape, n, cap = _shape_meta(data.shape, cfg)
    xb = dq.block_split(dq.pad_to_blocks(data, block), block)
    # fused PREQUANT + ℓ-delta + POSTQUANT: one blocked kernel invocation
    codes, delta = lorenzo_ops.dualquant_blocks(
        xb, eb, cfg.nbins, **pp.dualquant.as_kwargs())
    # code 0 <=> outlier (in-cap codes are >= 1), so the fused outputs
    # feed outlier extraction directly — no recomputed in_cap tree
    oidx, oval, n_out = dq.extract_outliers(
        delta.reshape(-1), (codes != 0).reshape(-1), cap)
    hist = hist_ops.histogram(codes, cfg.nbins, **pp.histogram.as_kwargs())
    lengths = hf.codeword_lengths(hist)
    cb = hf.canonical_codebook(lengths)
    cw, bw = encode_ops.encode(codes, cb, **pp.encode.as_kwargs())
    words, bits, gap_bits, gap_syms = deflate_ops.deflate(
        cw, bw, cfg.chunk_size, cfg.sub_size, **pp.deflate.as_kwargs())
    nc = words.shape[0]
    n_sym = codes.size
    n_valid = jnp.minimum(
        jnp.full((nc,), cfg.chunk_size, jnp.int32),
        jnp.maximum(n_sym - jnp.arange(nc, dtype=jnp.int32) * cfg.chunk_size, 0))
    return CompressedBlob(words, bits, n_valid, lengths, oidx, oval,
                          n_out, cb.max_len, gap_bits, gap_syms)


def compress(data: jax.Array, cfg: CompressorConfig) -> Tuple[CompressedBlob, float]:
    """Returns (blob, resolved_abs_eb)."""
    eb = resolve_eb(cfg, data)
    pp = dispatch.pipeline_policy(cfg.kernel_impl)
    return _compress_impl(data, cfg, eb, pp), eb


@partial(jax.jit, static_argnames=("cfg", "eb", "shape", "max_len_static",
                                   "pp"))
def _decompress_impl(blob: CompressedBlob, table: hf.DecodeTable,
                     cfg: CompressorConfig, eb: float,
                     shape: Tuple[int, ...], max_len_static: int,
                     pp: dispatch.PipelinePolicy) -> jax.Array:
    ndim, block, pshape, n, cap = _shape_meta(shape, cfg)
    codes = inflate_ops.inflate(blob.words, blob.bits_used, blob.n_valid,
                                table, max_len_static, gaps=blob.gap_bits,
                                **pp.inflate.as_kwargs()).reshape(-1)[:n]
    delta = dq.codes_to_delta(codes, cfg.nbins)
    delta = dq.scatter_outliers(delta, blob.out_idx, blob.out_val)
    nb = tuple(p // b for p, b in zip(pshape, block))
    delta = delta.reshape(nb + tuple(block))
    recon = lorenzo_ops.reverse_blocks(delta, eb, **pp.reverse.as_kwargs())
    full = dq.block_merge(recon, block)
    return full[tuple(slice(0, s) for s in shape)]


def decompress(blob: CompressedBlob, cfg: CompressorConfig, eb: float,
               shape: Tuple[int, ...]) -> jax.Array:
    # repro-lint: allow[host-sync] max_len picks the LUT-vs-bitscan decode
    # variant, a static jit arg; one scalar readback per decompress call
    max_len = int(jax.device_get(blob.max_len))
    # bucket the static max length (8/12/16/32) so decode compiles once
    # per bucket, not once per field's exact max codeword length
    ml_b = hf.bucket_max_len(max(1, max_len))
    # decode tables built OUTSIDE the jitted decode, cached per codebook:
    # the LUT scatter+cummax no longer re-runs on every restore
    table = hf.decode_table(blob.lengths, ml_b)
    pp = dispatch.pipeline_policy(cfg.kernel_impl)
    return _decompress_impl(blob, table, cfg, eb, shape, ml_b, pp)


# ---------------------------------------------------------------------------
# Size accounting / ratio
# ---------------------------------------------------------------------------

HEADER_BYTES = 64


def compressed_bytes(blob: CompressedBlob, nbins: int) -> int:
    # repro-lint: allow[host-sync] ratio reporting is a host-side metric
    bits = np.asarray(jax.device_get(blob.bits_used), dtype=np.int64)
    stream = int(np.sum((bits + 31) // 32) * 4)
    n_out = int(jax.device_get(blob.n_outliers))  # repro-lint: allow[host-sync] ratio reporting

    outliers = n_out * 8                       # (idx, delta) int32 pairs
    book = nbins                               # 1 B bitlength per symbol
    gaps = 0
    if blob.gap_bits is not None:              # 4 B bit + 2 B symbol offset
        gaps = blob.gap_bits.size * 4 + blob.gap_syms.size * 2
    return stream + outliers + book + gaps + HEADER_BYTES


def compression_ratio(data: jax.Array, blob: CompressedBlob, nbins: int) -> float:
    raw = data.size * data.dtype.itemsize
    return raw / compressed_bytes(blob, nbins)


def roundtrip(data: jax.Array, cfg: CompressorConfig):
    """compress -> decompress; returns (recon, blob, eb, ratio)."""
    blob, eb = compress(data, cfg)
    recon = decompress(blob, cfg, eb, tuple(data.shape))
    return recon, blob, eb, compression_ratio(data, blob, cfg.nbins)


# ---------------------------------------------------------------------------
# Host-side packing for storage: keep only the used words per chunk (the
# device blob keeps a dense [nc, chunk] buffer for fixed shapes; storing
# that verbatim would waste the saved ratio).  Fully vectorized: packing
# a many-chunk blob is O(1) NumPy calls, not O(nc) host iterations.
# ---------------------------------------------------------------------------

def _packed_coords(bits: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(chunk_id, in-chunk column) of every used word, packed order."""
    nwords = (bits + 31) // 32                       # [nc]
    chunk_ids = np.repeat(np.arange(bits.shape[0]), nwords)
    starts = np.cumsum(nwords) - nwords              # packed offset per chunk
    cols = np.arange(int(nwords.sum())) - np.repeat(starts, nwords)
    return chunk_ids, cols


def pack_blob(blob: CompressedBlob) -> dict:
    # repro-lint: allow[host-sync] pack_blob() is the storage boundary
    b = jax.device_get(blob)
    words = np.asarray(b.words)
    bits = np.asarray(b.bits_used, dtype=np.int64)
    chunk_ids, cols = _packed_coords(bits)
    packed = words[chunk_ids, cols]                  # one fancy-index gather
    n_out = int(b.n_outliers)
    d = {
        "words_packed": packed.astype(np.uint32),
        "bits_used": np.asarray(b.bits_used, np.int32),
        "n_valid": np.asarray(b.n_valid, np.int32),
        "lengths": np.asarray(b.lengths, np.uint8),
        "out_idx": np.asarray(b.out_idx[:n_out], np.int32),
        "out_val": np.asarray(b.out_val[:n_out], np.int32),
        "max_len": np.asarray(b.max_len, np.int32),
        "chunk_words": np.int32(words.shape[1]),
        "out_capacity": np.int32(b.out_idx.shape[0]),
    }
    if b.gap_bits is not None:
        d["gap_bits"] = np.asarray(b.gap_bits, np.int32)
        # symbol offsets are < chunk_size; u16 when that fits (default
        # chunks easily do), else full i32
        sdt = np.uint16 if words.shape[1] <= (1 << 16) else np.int32
        d["gap_syms"] = np.asarray(b.gap_syms).astype(sdt)
    return d


def packed_nbytes(d: dict) -> int:
    return sum(np.asarray(v).nbytes for v in d.values())


def unpack_blob(d: dict) -> CompressedBlob:
    bits = np.asarray(d["bits_used"], np.int64)
    nc = bits.shape[0]
    cw = int(d["chunk_words"])
    words = np.zeros((nc, cw), np.uint32)
    chunk_ids, cols = _packed_coords(bits)
    words[chunk_ids, cols] = np.asarray(d["words_packed"], np.uint32)
    cap = int(d["out_capacity"])
    oi = np.full((cap,), 2 ** 31 - 1, np.int32)
    ov = np.zeros((cap,), np.int32)
    n_out = len(d["out_idx"])
    oi[:n_out] = d["out_idx"]
    ov[:n_out] = d["out_val"]
    gb = d.get("gap_bits")           # absent on format-v1 payloads
    gs = d.get("gap_syms")
    return CompressedBlob(
        jnp.asarray(words), jnp.asarray(d["bits_used"]),
        jnp.asarray(d["n_valid"]),
        jnp.asarray(np.asarray(d["lengths"], np.int32)),
        jnp.asarray(oi), jnp.asarray(ov),
        jnp.asarray(np.int32(n_out)), jnp.asarray(d["max_len"]),
        None if gb is None else jnp.asarray(np.asarray(gb, np.int32)),
        None if gs is None else jnp.asarray(np.asarray(gs, np.int32)))
