"""End-to-end compression pipeline as a staged composition:
one `Predictor` + one `Encoder` (see `repro.core.stages`).

`CompressorConfig.predictor` / `.encoder` pick the stages by registry id
("lorenzo"+"huffman" is the paper's cuSZ pipeline and the default; the
"interp" predictor and "bitshuffle" encoder compose into the cusz-i and
fz codecs with no pipeline changes).  Every hot stage routes through the
`repro.kernels` ops layer, so the same pipeline runs the XLA reference
impls, the interpret-mode Pallas kernels (CI parity), or the compiled
Pallas kernels (TPU/GPU), selected by the dispatch policy:
`CompressorConfig.kernel_impl`, overridden by the `REPRO_KERNEL_IMPL`
env var or a `kernels.dispatch.kernel_policy` context.  The policy is
resolved to a static `PipelinePolicy` outside jit, so each policy gets
its own compiled executable.

Two equivalent surfaces:

* The generic dict surface (`StagedPipeline`, `staged_compress` /
  `staged_decompress`): stage payloads are flat dicts of arrays — the
  union of the predictor's and encoder's disjoint key sets — packed and
  unpacked per stage.  Any predictor x encoder composition works here.
* The `CompressedBlob` surface (`compress` / `decompress`, `pack_blob` /
  `unpack_blob`): the historical named-tuple form whose fields are the
  lorenzo/interp + huffman payload keys.  This is the cusz container
  format; it is byte-identical to the pre-staged pipeline (golden-
  fixture tested) and remains the API of the ratio/throughput tooling.

`compress` / `decompress` are jittable for fixed (shape, config,
policy); payloads are pytrees of device arrays so they can live
on-device (e.g. checkpoint write path) or be pulled to host for storage.

Compressed-size accounting matches the paper's: Huffman bitstream (word
aligned per chunk) + sparse outliers + codebook (bitlengths suffice to
rebuild the canonical book) + the per-subchunk gap arrays that make the
decode parallel (Rivera et al., arXiv 2201.09118) + O(1) header (+ the
interp predictor's anchor grid, when present).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch

from . import dualquant as dq
from . import huffman as hf
from . import stages


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    eb: float = 1e-4                 # absolute error bound (see eb_mode)
    eb_mode: str = "abs"             # "abs" | "valrel" (relative to range)
    nbins: int = 1024                # quantization bins (paper default)
    chunk_size: int = 4096           # encoder chunk (symbols)
    sub_size: int = 128              # gap-array subchunk (symbols); the
    #   parallel decode unit — must divide chunk_size
    block: Optional[Tuple[int, ...]] = None   # Lorenzo block; None = paper default
    outlier_frac: float = 0.10       # sparse outlier capacity fraction
    use_tpu_blocks: bool = False     # lane-aligned blocks (beyond-paper)
    kernel_impl: Optional[str] = None  # dispatch default: "auto" | "jax" |
    #   "pallas" | "pallas-interpret"; None defers to the ambient policy
    predictor: str = "lorenzo"       # stage registry id (core.stages)
    encoder: str = "huffman"         # stage registry id (core.stages)

    def block_for(self, ndim: int) -> Tuple[int, ...]:
        if self.block is not None:
            return self.block
        table = dq.TPU_BLOCKS if self.use_tpu_blocks else dq.DEFAULT_BLOCKS
        if ndim <= 3:
            return table[ndim]
        # >3D (e.g. QMCPACK 4D): block the trailing 3 dims (paper treats
        # the leading dim as a batch of 3D fields)
        return (1,) * (ndim - 3) + table[3]


class CompressedBlob(NamedTuple):
    words: jax.Array         # [nc, chunk] uint32 deflated bitstream
    bits_used: jax.Array     # [nc] int32
    n_valid: jax.Array       # [nc] int32 symbols per chunk
    lengths: jax.Array       # [k] int32 codeword bitlengths (rebuilds book)
    out_idx: jax.Array       # [cap] int32 outlier flat indices (-1 fill)
    out_val: jax.Array       # [cap] int32 outlier deltas
    n_outliers: jax.Array    # scalar int32
    max_len: jax.Array       # scalar int32 practical max codeword length
    # gap arrays (None on format-v1 blobs, which decode sequentially):
    gap_bits: Optional[jax.Array] = None   # [nc, n_sub] int32 bit offset at
    #   every sub_size-symbol boundary (phase-1 of the two-phase decode)
    gap_syms: Optional[jax.Array] = None   # [nc, n_sub] int32 valid symbols
    #   before each boundary
    # interp-predictor anchor grid (None for the lorenzo predictor):
    anchor: Optional[jax.Array] = None     # [n_anchor] int32


@jax.jit
def _eb_stats(data: jax.Array) -> jax.Array:
    """min, max, max|d| as ONE fused reduction -> one [3] device array.
    One dispatch + one device_get per compress call (the previous form
    issued two separate blocking reductions)."""
    f = data.astype(jnp.float32)
    return jnp.stack([jnp.min(f), jnp.max(f), jnp.max(jnp.abs(f))])


def resolve_eb(cfg: CompressorConfig, data) -> float:
    # repro-lint: allow[host-sync] single fused 3-stat reduction; the eb
    # must be a host float (jit cache key) before compression starts
    dmin, dmax, amax = (float(v) for v in
                        np.asarray(jax.device_get(_eb_stats(data))))
    if cfg.eb_mode == "abs":
        eb = float(cfg.eb)
    else:
        rng = dmax - dmin
        eb = float(cfg.eb) * (rng if rng > 0 else 1.0)
    # fp32/int32 domain guard (paper stores d° in FP for the same reason):
    # d° = d/(2eb) must stay within exact-integer float32/int32 range,
    # otherwise the bound is unrepresentable in fp32 to begin with.
    if amax > 0 and amax / (2 * eb) >= 2 ** 23:
        raise ValueError(
            f"error bound {eb:g} is below float32 resolution for data with "
            f"max |d|={amax:g} (d° would exceed 2^23); choose eb >= "
            f"{amax / 2 ** 24:g}")
    return eb


# shared shape metadata now lives with the stage protocols
_shape_meta = stages.shape_meta


# ---------------------------------------------------------------------------
# Generic staged pipeline (dict payloads, any predictor x encoder)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "eb", "pp"))
def _staged_compress_impl(data: jax.Array, cfg: CompressorConfig, eb: float,
                          pp: dispatch.PipelinePolicy) -> dict:
    pred = stages.get_predictor(cfg.predictor)
    enc = stages.get_encoder(cfg.encoder)
    codes, ppay = pred.predict(data, cfg, eb, pp)
    epay = enc.encode(codes, cfg, pp)
    return {**epay, **ppay}


@partial(jax.jit, static_argnames=("cfg", "eb", "shape", "static_meta",
                                   "pp"))
def _staged_decompress_impl(payload: dict, aux, cfg: CompressorConfig,
                            eb: float, shape: Tuple[int, ...],
                            static_meta: Tuple, pp: dispatch.PipelinePolicy
                            ) -> jax.Array:
    pred = stages.get_predictor(cfg.predictor)
    enc = stages.get_encoder(cfg.encoder)
    codes = enc.decode(payload, aux, static_meta, cfg, pp)
    return pred.reconstruct(codes, payload, cfg, eb, shape, pp)


def staged_compress(data: jax.Array, cfg: CompressorConfig
                    ) -> Tuple[dict, float]:
    """Generic staged compress.  Returns (payload dict, resolved abs eb)."""
    eb = resolve_eb(cfg, data)
    pp = dispatch.pipeline_policy(cfg.kernel_impl)
    return _staged_compress_impl(data, cfg, eb, pp), eb


def staged_decompress(payload: dict, cfg: CompressorConfig, eb: float,
                      shape: Tuple[int, ...]) -> jax.Array:
    """Generic staged decompress of a (device-form) payload dict."""
    enc = stages.get_encoder(cfg.encoder)
    static_meta, aux = enc.decode_meta(payload, cfg)
    pp = dispatch.pipeline_policy(cfg.kernel_impl)
    return _staged_decompress_impl(payload, aux, cfg, eb, tuple(shape),
                                   static_meta, pp)


@dataclasses.dataclass(frozen=True)
class StagedPipeline:
    """A concrete predictor + encoder composition with the host-side
    storage/validity surface codecs build on (`codecs.fz` is the
    reference consumer; `codecs.cusz` keeps the CompressedBlob form of
    the same composition for container-format stability)."""
    predictor: stages.Predictor
    encoder: stages.Encoder

    @staticmethod
    def from_cfg(cfg: CompressorConfig) -> "StagedPipeline":
        return StagedPipeline(stages.get_predictor(cfg.predictor),
                              stages.get_encoder(cfg.encoder))

    def compress(self, data: jax.Array, cfg: CompressorConfig
                 ) -> Tuple[dict, float]:
        return staged_compress(data, cfg)

    def decompress(self, payload: dict, cfg: CompressorConfig, eb: float,
                   shape: Tuple[int, ...]) -> jax.Array:
        return staged_decompress(payload, cfg, eb, shape)

    def valid(self, payload: dict) -> bool:
        return self.predictor.valid(payload)

    # -- storage boundary (host) -------------------------------------------
    def pack(self, payload: dict) -> dict:
        # repro-lint: allow[host-sync] pack() is the storage boundary
        host = jax.device_get(payload)
        pkeys = set(self.predictor.payload_keys)
        ppart = {k: v for k, v in host.items() if k in pkeys}
        epart = {k: v for k, v in host.items() if k not in pkeys}
        return {**self.encoder.pack_payload(epart),
                **self.predictor.pack_payload(ppart)}

    def unpack(self, packed: dict, cfg: CompressorConfig,
               shape: Tuple[int, ...]) -> dict:
        n_sym = self.predictor.n_codes(tuple(shape), cfg)
        d = dict(self.encoder.unpack_payload(packed, cfg, n_sym))
        d.update(self.predictor.unpack_payload(packed, cfg, tuple(shape)))
        return {k: jnp.asarray(v) for k, v in d.items()}

    def stored_nbytes(self, packed: dict) -> int:
        return (self.encoder.stored_nbytes(packed)
                + self.predictor.stored_nbytes(packed) + HEADER_BYTES)


# ---------------------------------------------------------------------------
# CompressedBlob surface (cusz container format; bit-identical to the
# pre-staged pipeline)
# ---------------------------------------------------------------------------

def _blob_from_payload(payload: dict) -> CompressedBlob:
    return CompressedBlob(**{f: payload.get(f)
                             for f in CompressedBlob._fields})


@partial(jax.jit, static_argnames=("cfg", "eb", "pp"))
def _compress_impl(data: jax.Array, cfg: CompressorConfig, eb: float,
                   pp: dispatch.PipelinePolicy) -> CompressedBlob:
    return _blob_from_payload(_staged_compress_impl(data, cfg, eb, pp))


def compress(data: jax.Array, cfg: CompressorConfig) -> Tuple[CompressedBlob, float]:
    """Returns (blob, resolved_abs_eb)."""
    if cfg.encoder != "huffman":
        raise ValueError(
            f"the CompressedBlob surface encodes the huffman payload "
            f"layout; encoder {cfg.encoder!r} needs staged_compress()")
    eb = resolve_eb(cfg, data)
    pp = dispatch.pipeline_policy(cfg.kernel_impl)
    return _compress_impl(data, cfg, eb, pp), eb


@partial(jax.jit, static_argnames=("cfg", "eb", "shape", "max_len_static",
                                   "pp"))
def _decompress_impl(blob: CompressedBlob, table: hf.DecodeTable,
                     cfg: CompressorConfig, eb: float,
                     shape: Tuple[int, ...], max_len_static: int,
                     pp: dispatch.PipelinePolicy) -> jax.Array:
    payload = {f: v for f, v in zip(CompressedBlob._fields, blob)
               if v is not None}
    pred = stages.get_predictor(cfg.predictor)
    enc = stages.get_encoder(cfg.encoder)
    codes = enc.decode(payload, table, (max_len_static,), cfg, pp)
    return pred.reconstruct(codes, payload, cfg, eb, shape, pp)


def decompress(blob: CompressedBlob, cfg: CompressorConfig, eb: float,
               shape: Tuple[int, ...]) -> jax.Array:
    enc = stages.get_encoder(cfg.encoder)
    static_meta, table = enc.decode_meta(
        {"max_len": blob.max_len, "lengths": blob.lengths}, cfg)
    pp = dispatch.pipeline_policy(cfg.kernel_impl)
    return _decompress_impl(blob, table, cfg, eb, tuple(shape),
                            static_meta[0], pp)


# ---------------------------------------------------------------------------
# Size accounting / ratio
# ---------------------------------------------------------------------------

HEADER_BYTES = 64


def compressed_bytes(blob: CompressedBlob, nbins: int) -> int:
    # repro-lint: allow[host-sync] ratio reporting is a host-side metric
    bits = np.asarray(jax.device_get(blob.bits_used), dtype=np.int64)
    stream = int(np.sum((bits + 31) // 32) * 4)
    n_out = int(jax.device_get(blob.n_outliers))  # repro-lint: allow[host-sync] ratio reporting

    outliers = n_out * 8                       # (idx, delta) int32 pairs
    book = nbins                               # 1 B bitlength per symbol
    gaps = 0
    if blob.gap_bits is not None:              # 4 B bit + 2 B symbol offset
        gaps = blob.gap_bits.size * 4 + blob.gap_syms.size * 2
    anchor = 0 if blob.anchor is None else blob.anchor.size * 4
    return stream + outliers + book + gaps + anchor + HEADER_BYTES


def compression_ratio(data: jax.Array, blob: CompressedBlob, nbins: int) -> float:
    raw = data.size * data.dtype.itemsize
    return raw / compressed_bytes(blob, nbins)


def roundtrip(data: jax.Array, cfg: CompressorConfig):
    """compress -> decompress; returns (recon, blob, eb, ratio)."""
    blob, eb = compress(data, cfg)
    recon = decompress(blob, cfg, eb, tuple(data.shape))
    return recon, blob, eb, compression_ratio(data, blob, cfg.nbins)


# ---------------------------------------------------------------------------
# Host-side packing for storage: keep only the used words per chunk (the
# device blob keeps a dense [nc, chunk] buffer for fixed shapes; storing
# that verbatim would waste the saved ratio).  Delegated to the stage
# pack/unpack implementations (stages.HuffmanEncoder carries the
# vectorized word packing); output keys are unchanged from the
# pre-staged pipeline, so stored cusz v2 payloads are bit-identical.
# ---------------------------------------------------------------------------

def pack_blob(blob: CompressedBlob) -> dict:
    # repro-lint: allow[host-sync] pack_blob() is the storage boundary
    b = jax.device_get(blob)
    payload = {f: v for f, v in zip(CompressedBlob._fields, b)
               if v is not None}
    d = stages.get_encoder("huffman").pack_payload(payload)
    d.update(stages._pack_outliers(payload))
    if payload.get("anchor") is not None:
        d["anchor"] = np.asarray(payload["anchor"], np.int32)
    return d


def packed_nbytes(d: dict) -> int:
    return sum(np.asarray(v).nbytes for v in d.values())


def unpack_blob(d: dict) -> CompressedBlob:
    enc = stages.get_encoder("huffman").unpack_payload(d, None, None)
    out = stages._unpack_outliers(d)
    payload = {**enc, **out}
    if d.get("anchor") is not None:
        payload["anchor"] = np.asarray(d["anchor"], np.int32)
    return CompressedBlob(**{
        f: (jnp.asarray(payload[f]) if payload.get(f) is not None else None)
        for f in CompressedBlob._fields})
