"""Error-bounded gradient compression for cross-pod all-reduce.

The paper's PREQUANT (d° = round(d/(2·eb))) applied to the distributed-
training collective: gradients are quantized to narrow integers *before*
the reduction, so the all-reduce moves 1-2 B/element instead of 4 and the
HLO collective is integer-typed (visible in the dry-run; see EXPERIMENTS.md
§Perf).  This is a beyond-paper integration of the paper's mechanism.

Layout trick (DESIGN.md §3): the train step computes per-pod gradients with
a leading pod axis (`vmap` over the pod-sharded microbatch dim).  Summing
the *quantized* values over that sharded axis makes XLA emit the integer
all-reduce natively — no shard_map, and the latency-hiding scheduler can
still overlap it with backward compute.

Error bound: with per-tensor scale s = amax·npods/(2^(b-1)-1), each
element's quantization error ≤ s/2, so the reduced mean's error is
≤ s/2 (quantization errors average, worst case bounded by s/2·npods/npods).
`amax` is itself reduced over pods (a tiny fp32 collective) so all pods
share one scale and the integer sum is exact.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import _compat
from repro.dist.context import constrain_like_params


def compressed_psum_mean(grads_podded: Any, mode: str, npods: int) -> Any:
    """grads_podded: pytree with a leading pod axis of size `npods`
    (sharded over the 'pod' mesh axis).  Returns the pod-mean pytree
    without the leading axis.

    mode: 'none' | 'int8' | 'int16' — a `repro.codecs` registry name; the
    quantization math is the registered codec's (`codecs.int8.quantize`
    with the shared cross-pod scale).
    """
    if mode == "none":
        return jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_podded)
    from repro import codecs
    from repro.codecs import int8 as I8

    codec = codecs.get(mode)                            # Int8Codec(bits=…)
    qeff = float(codec.qmax // npods)                   # per-pod level budget

    grads_podded = constrain_like_params(grads_podded, lead_axis="pod")

    def one(g):
        # shared scale: amax over *all* pods (tiny fp32 all-reduce),
        # quantized levels clipped to the per-pod budget qeff
        q, scale = I8.quantize(g, qeff, codec.qdtype)
        # integer sum over the pod-sharded axis -> *narrow* integer
        # all-reduce in HLO.  No overflow: |q| <= floor(qmax/npods) by the
        # shared scale, so the sum stays within the narrow type.
        s = jnp.sum(q, axis=0, dtype=codec.qdtype)
        return s.astype(jnp.float32) * (scale / npods)

    return jax.tree.map(one, grads_podded)


# ---------------------------------------------------------------------------
# DEPRECATED cuSZ gradient-blob entry points.  The codec API replaces the
# `(packed_dict, eb)` out-of-band-metadata plumbing (which also lost the
# source dtype):
#
#     from repro import codecs
#     c = codecs.get("cusz", cfg=cfg).encode(g)     # self-describing
#     g2 = codecs.decode(c)
# ---------------------------------------------------------------------------

def cusz_compress_gradient(g: jax.Array, cfg) -> Tuple[dict, float]:
    """DEPRECATED: use `codecs.get("cusz", cfg=cfg).encode(g)`.

    Returns (packed host blob, resolved eb); decompression needs the same
    cfg parameters back — the replacement Container carries them itself.
    """
    _compat.warn_once(
        "cusz_compress_gradient",
        "cusz_compress_gradient is deprecated; use "
        "repro.codecs.get('cusz', cfg=cfg).encode(g) — the "
        "returned Container is self-describing")
    from repro.core import compressor as CZ

    blob, eb = CZ.compress(g, cfg)
    return CZ.pack_blob(blob), eb


def cusz_decompress_gradient(packed: dict, eb: float, shape, cfg) -> jax.Array:
    """DEPRECATED: use `codecs.decode(container)` (same cfg on both sides
    is no longer the caller's burden)."""
    _compat.warn_once(
        "cusz_decompress_gradient",
        "cusz_decompress_gradient is deprecated; use "
        "repro.codecs.decode(container)")
    from repro.core import compressor as CZ

    return CZ.decompress(CZ.unpack_blob(packed), cfg, eb, tuple(shape))


def quantize_tensor(g: jax.Array, mode: str) -> Tuple[jax.Array, jax.Array]:
    """Standalone PREQUANT of one tensor (used by tests & the checkpoint
    codec fast path).  Returns (q, scale); the math is
    `codecs.int8.quantize` — the registered codec owns it."""
    from repro import codecs
    from repro.codecs import int8 as I8

    codec = codecs.get(mode)
    return I8.quantize(g, float(codec.qmax), codec.qdtype)


def dequantize_tensor(q: jax.Array, scale: jax.Array) -> jax.Array:
    from repro.codecs import int8 as I8

    return I8.dequantize(q, scale)


def error_bound_of(g: jax.Array, mode: str) -> jax.Array:
    """The effective absolute error bound (= scale/2) for a tensor."""
    from repro import codecs

    qmax = float(codecs.get(mode).qmax)
    return jnp.max(jnp.abs(g)) / qmax / 2.0
