"""Error-bounded gradient compression for cross-pod all-reduce.

The paper's PREQUANT (d° = round(d/(2·eb))) applied to the distributed-
training collective: gradients are quantized to narrow integers *before*
the reduction, so the all-reduce moves 1-2 B/element instead of 4 and the
HLO collective is integer-typed (visible in the dry-run; see EXPERIMENTS.md
§Perf).  This is a beyond-paper integration of the paper's mechanism.

Layout trick (DESIGN.md §3): the train step computes per-pod gradients with
a leading pod axis (`vmap` over the pod-sharded microbatch dim).  Summing
the *quantized* values over that sharded axis makes XLA emit the integer
all-reduce natively — no shard_map, and the latency-hiding scheduler can
still overlap it with backward compute.

Error bound: with per-tensor scale s = amax·npods/(2^(b-1)-1), each
element's quantization error ≤ s/2, so the reduced mean's error is
≤ s/2 (quantization errors average, worst case bounded by s/2·npods/npods).
`amax` is itself reduced over pods (a tiny fp32 collective) so all pods
share one scale and the integer sum is exact.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.dist.context import constrain_like_params

_INT_BITS = {"int8": 8, "int16": 16}
_DTYPES = {"int8": jnp.int8, "int16": jnp.int16}


def compressed_psum_mean(grads_podded: Any, mode: str, npods: int) -> Any:
    """grads_podded: pytree with a leading pod axis of size `npods`
    (sharded over the 'pod' mesh axis).  Returns the pod-mean pytree
    without the leading axis.

    mode: 'none' | 'int8' | 'int16'.
    """
    if mode == "none":
        return jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_podded)
    bits = _INT_BITS[mode]
    dt = _DTYPES[mode]
    qmax = float(2 ** (bits - 1) - 1)

    qeff = float(int(qmax) // npods)                    # per-pod level budget

    grads_podded = constrain_like_params(grads_podded, lead_axis="pod")

    def one(g):
        # shared scale: amax over *all* pods (tiny fp32 all-reduce)
        amax = jnp.max(jnp.abs(g))                      # reduces pod axis too
        scale = jnp.maximum(amax / qeff, 1e-30)
        q = jnp.clip(jnp.rint(g / scale), -qeff, qeff).astype(dt)
        # integer sum over the pod-sharded axis -> *narrow* integer
        # all-reduce in HLO.  No overflow: |q| <= floor(qmax/npods) by the
        # shared scale, so the sum stays within the narrow type.
        s = jnp.sum(q, axis=0, dtype=dt)
        return s.astype(jnp.float32) * (scale / npods)

    return jax.tree.map(one, grads_podded)


# ---------------------------------------------------------------------------
# Full-pipeline cuSZ gradient blobs (cross-pod WAN link / gradient
# accumulation offload).  The int8 psum path above stays the in-step
# collective; these produce a storable error-bounded blob at an explicit
# bound.  Kernel dispatch policy flows through `cfg.kernel_impl`.
# ---------------------------------------------------------------------------

def cusz_compress_gradient(g: jax.Array, cfg) -> Tuple[dict, float]:
    """Run one gradient tensor through the full cuSZ pipeline.

    cfg: a `compressor.CompressorConfig` (carries eb, nbins, chunking AND
    the kernel dispatch policy).  Returns (packed host blob, resolved eb);
    decompression needs the same cfg parameters.
    """
    from repro.core import compressor as CZ

    blob, eb = CZ.compress(g, cfg)
    return CZ.pack_blob(blob), eb


def cusz_decompress_gradient(packed: dict, eb: float, shape, cfg) -> jax.Array:
    """Inverse of `cusz_compress_gradient` (same cfg on both sides)."""
    from repro.core import compressor as CZ

    return CZ.decompress(CZ.unpack_blob(packed), cfg, eb, tuple(shape))


def quantize_tensor(g: jax.Array, mode: str) -> Tuple[jax.Array, jax.Array]:
    """Standalone PREQUANT of one tensor (used by tests & the checkpoint
    codec fast path).  Returns (q, scale)."""
    bits = _INT_BITS[mode]
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / qmax, 1e-30)
    q = jnp.clip(jnp.rint(g / scale), -qmax, qmax).astype(_DTYPES[mode])
    return q, scale


def dequantize_tensor(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def error_bound_of(g: jax.Array, mode: str) -> jax.Array:
    """The effective absolute error bound (= scale/2) for a tensor."""
    bits = _INT_BITS[mode]
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.max(jnp.abs(g)) / qmax / 2.0
