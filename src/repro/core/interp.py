"""Multi-level interpolation predictor (cuSZ-i, arXiv 2312.05492) behind
the `Predictor` stage protocol.

Scheme: prequantize ONCE to exact int32 (the pipeline's only lossy
step), then lift level by level — along each axis the samples split into
even/odd strides, every odd sample is predicted with an integer cubic
stencil over its four even neighbors, and only the residual is kept; the
even half recurses until every dim is at the anchor size.  The tiny
anchor grid rides in the payload uncompressed (int32), exactly like
cuSZ-i stores its anchor points every 2^L stride.

Because the lifting runs on prequantized integers with floor-division
arithmetic, encode and decode are exact inverses: the single prequant
rounding bounds the error by eb regardless of level count (unlike
per-level float requantization, which compounds).  On smooth fields the
cubic stencil leaves far smaller residuals than the blocked
first-difference Lorenzo predictor (no per-block boundary resets
either), which concentrates the quant-code histogram and directly buys
compression ratio from the downstream encoder at the same bound.

The level plan is static (a pure function of the field shape), so the
whole multi-level loop unrolls inside one jit trace — per-level shapes
change, which rules out `lax.scan`, but level count is log2(max dim).
The residual stream order (level-major, then row-major in working-axis-
moved layout) is likewise static and shared by predict/reconstruct.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.interp import ops as interp_ops

from . import dualquant as dq
from . import stages

#: stop splitting once every dim is at most this (the anchor grid)
ANCHOR = 4


@functools.lru_cache(maxsize=512)
def interp_plan(shape: Tuple[int, ...]
                ) -> Tuple[Tuple[Tuple[int, Tuple[int, ...]], ...],
                           Tuple[int, ...]]:
    """Static level plan for `shape`.

    Returns (steps, anchor_shape): each step is (axis, shape-before-
    split); the split replaces size s with ceil(s/2) evens, emitting
    floor(s/2) odd residuals.  At least one step is forced for tiny
    fields (so the encoder always sees a nonempty code stream) unless
    every dim is 1.
    """
    s = list(shape)
    steps: List[Tuple[int, Tuple[int, ...]]] = []
    while max(s) > ANCHOR:
        for a in range(len(s)):
            if s[a] > ANCHOR:
                steps.append((a, tuple(s)))
                s[a] = (s[a] + 1) // 2
    if not steps and max(s) >= 2:
        a = int(np.argmax(s))
        steps.append((a, tuple(s)))
        s[a] = (s[a] + 1) // 2
    return tuple(steps), tuple(s)


def _n_residuals(shape: Tuple[int, ...]) -> Tuple[int, int]:
    steps, anchor_shape = interp_plan(shape)
    n_res = int(np.prod(shape)) - int(np.prod(anchor_shape))
    return n_res, int(np.prod(anchor_shape))


def _pad_even(e2: jax.Array) -> jax.Array:
    """[R, me] -> [R, me+3]: edge-replicate 1 left / 2 right so every odd
    position gathers four even neighbors at static offsets."""
    return jnp.concatenate([e2[:, :1], e2, e2[:, -1:], e2[:, -1:]], axis=1)


def _interleave(even: jax.Array, odd: jax.Array) -> jax.Array:
    """Merge even/odd strides back along the last axis (exact inverse of
    the [0::2]/[1::2] split)."""
    s = even.shape[-1] + odd.shape[-1]
    out = jnp.zeros(even.shape[:-1] + (s,), even.dtype)
    out = out.at[..., 0::2].set(even)
    return out.at[..., 1::2].set(odd)


class InterpPredictor(stages.Predictor):
    name = "interp"
    kernels = ("interp.predict", "interp.reconstruct")
    payload_keys = ("out_idx", "out_val", "n_outliers", "anchor")

    def n_codes(self, shape, cfg) -> int:
        n_res, _ = _n_residuals(shape)
        return max(1, n_res)

    def predict(self, data, cfg, eb, pp):
        steps, anchor_shape = interp_plan(data.shape)
        n_res, _ = _n_residuals(data.shape)
        kw = pp.for_kernel("interp.predict").as_kwargs()
        x = dq.prequant(data, eb)
        parts = []
        for axis, _ in steps:
            xm = jnp.moveaxis(x, axis, -1)
            even, odd = xm[..., 0::2], xm[..., 1::2]
            e2 = even.reshape(-1, even.shape[-1])
            o2 = odd.reshape(-1, odd.shape[-1])
            r2 = interp_ops.residual_rows(_pad_even(e2), o2, **kw)
            parts.append(r2.reshape(-1))
            x = jnp.moveaxis(even, -1, axis)
        resid = (jnp.concatenate(parts) if parts
                 else jnp.zeros((0,), jnp.int32))
        if resid.shape[0] < self.n_codes(data.shape, cfg):
            # degenerate all-ones shape: emit one in-cap dummy symbol so
            # the encoder never sees an empty stream
            resid = jnp.zeros((1,), jnp.int32)
        codes, in_cap = dq.postquant_codes(resid, cfg.nbins)
        cap = stages.outlier_capacity(int(np.prod(data.shape)), cfg)
        oidx, oval, n_out = dq.extract_outliers(resid, in_cap.reshape(-1),
                                                cap)
        return codes, {"out_idx": oidx, "out_val": oval,
                       "n_outliers": n_out,
                       "anchor": x.reshape(-1).astype(jnp.int32)}

    def reconstruct(self, codes_flat, payload, cfg, eb, shape, pp):
        steps, anchor_shape = interp_plan(shape)
        kw = pp.for_kernel("interp.reconstruct").as_kwargs()
        nc = self.n_codes(shape, cfg)
        delta = dq.codes_to_delta(codes_flat[:nc], cfg.nbins)
        delta = dq.scatter_outliers(delta, payload["out_idx"],
                                    payload["out_val"])
        # replay the plan to get each step's residual segment offset and
        # moved-layout odd shape (all static)
        segs = []
        off = 0
        for axis, shp in steps:
            moved = shp[:axis] + shp[axis + 1:] + (shp[axis],)
            mo = shp[axis] // 2
            odd_shape = moved[:-1] + (mo,)
            segs.append((axis, odd_shape, off))
            off += int(np.prod(odd_shape))
        x = payload["anchor"].reshape(anchor_shape)
        for axis, odd_shape, off in reversed(segs):
            em = jnp.moveaxis(x, axis, -1)
            e2 = em.reshape(-1, em.shape[-1])
            mo = odd_shape[-1]
            r2 = delta[off:off + int(np.prod(odd_shape))].reshape(-1, mo)
            o2 = interp_ops.odd_rows(_pad_even(e2), r2, **kw)
            om = o2.reshape(odd_shape)
            x = jnp.moveaxis(_interleave(em, om), -1, axis)
        return dq.dequant(x, eb)

    def header_params(self, shape, cfg):
        return {"outlier_frac": float(cfg.outlier_frac)}

    def valid(self, payload):
        return stages._outlier_valid(payload)

    def pack_payload(self, payload):
        d = stages._pack_outliers(payload)
        d["anchor"] = np.asarray(payload["anchor"], np.int32)
        return d

    def unpack_payload(self, packed, cfg, shape):
        d = stages._unpack_outliers(packed)
        d["anchor"] = np.asarray(packed["anchor"], np.int32)
        return d

    def stored_nbytes(self, packed):
        return (len(packed["out_idx"]) * 8
                + np.asarray(packed["anchor"]).size * 4)


stages.register_predictor("interp", InterpPredictor)
