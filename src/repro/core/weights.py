"""Error-bounded weight compression for FSDP parameter gathers.

The train-cell roofline is dominated by the ZeRO-3 all-gather of bf16
weights (2 gathers x microbatches x P·2B/TP per device per step).  The
paper's PREQUANT applied to the gather: each FSDP-sharded leaf is
quantized to int8 with blockwise scales BEFORE use; the consumer
dequantizes after the (now int8) gather, halving the dominant collective
term.  A straight-through estimator keeps the backward exact w.r.t. the
master weights, so the optimizer still updates fp32 masters — this is
quantized *communication/compute*, not quantized storage.

Error bound per element: scale/2 with scale = blockmax/127 (the paper's
eb semantics, weight-relative).
"""
from __future__ import annotations

import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.codecs import int8 as I8

QBLOCK = 128
_SKIP_SUBSTR = ("norm",)     # tiny / sensitive leaves stay uncompressed


def _quantizable(path_names, x) -> bool:
    if any(s in n for n in path_names for s in _SKIP_SUBSTR):
        return False
    return x.ndim >= 1 and x.shape[-1] % QBLOCK == 0 and x.size >= 4096


def _qdq(x: jax.Array) -> jax.Array:
    """quantize->dequantize (the value the forward pass sees) — the
    `"int8-block"` codec's math with (axis=-1, block=QBLOCK)."""
    q, scale = I8.block_quantize(x.astype(jnp.float32), -1, QBLOCK)
    return I8.block_dequantize(q, scale, -1, QBLOCK, x.dtype)


def compress_for_gather(params: Any) -> Any:
    """Single-device / mesh-less variant: forward sees int8-quantized
    values, gradient w.r.t. the fp32 masters is the identity (additive
    STE).  NOTE: on a mesh this form gathers the fp master anyway (the
    `p +` term needs p replicated) — §Perf iteration A1 refuted it; the
    mesh-aware path is `gather_dequant_tree` (custom_vjp STE + int8
    resharding constraint), hooked inside the period scan."""

    def one(path, p):
        names = [str(getattr(k, "key", "")) for k in path]
        if not _quantizable(names, p):
            return p
        return p + jax.lax.stop_gradient(_qdq(p) - p)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Mesh-aware int8 weight gather (§Perf iteration A2)
# ---------------------------------------------------------------------------

def _drop_data(spec):
    """Remove the FSDP axis from a PartitionSpec (keep TP axes)."""
    from jax.sharding import PartitionSpec as P

    def clean(el):
        if el == "data":
            return None
        if isinstance(el, (tuple, list)):
            kept = tuple(a for a in el if a != "data")
            return kept if kept else None
        return el
    return P(*[clean(e) for e in spec])


def _has_data(spec) -> bool:
    for el in spec:
        if el == "data" or (isinstance(el, (tuple, list)) and "data" in el):
            return True
    return False


def gather_dequant_leaf(p: jax.Array, spec, mesh):
    """forward: quantize the SHARDED master -> force the resharding on the
    int8 representation (the all-gather moves s8 + 1/128 scales) ->
    dequantize replicated-over-data values for compute.
    backward: identity to the master (custom_vjp STE)."""
    from jax.sharding import NamedSharding

    tgt = _drop_data(spec)
    stgt = tgt  # scale shares the layout (last dim replicated anyway)

    @jax.custom_vjp
    def qdq_ste(x):
        q, scale = I8.block_quantize(x.astype(jnp.float32), -1, QBLOCK)
        # the resharding (FSDP all-gather) happens HERE, on int8 + scales
        q = jax.lax.with_sharding_constraint(q, NamedSharding(mesh, tgt))
        scale = jax.lax.with_sharding_constraint(
            scale, NamedSharding(mesh, stgt))
        return I8.block_dequantize(q, scale, -1, QBLOCK, x.dtype)

    def fwd(x):
        return qdq_ste(x), None

    def bwd(_, g):
        return (g,)          # straight-through to the fp32 master

    qdq_ste.defvjp(fwd, bwd)
    return qdq_ste(p)


def gather_dequant_tree(params: Any, specs: Any, mesh) -> Any:
    """Apply gather_dequant_leaf to every quantizable FSDP-sharded leaf
    (call INSIDE the per-period scan body so only one period's weights are
    resident gathered at a time)."""

    def one(path, p, spec):
        names = [str(getattr(k, "key", "")) for k in path]
        if not _quantizable(names, p) or not _has_data(spec):
            return p
        # local (post-data-shard) last dim must still be block-aligned
        last_ax = spec[-1] if len(spec) == p.ndim else None
        div = 1
        if last_ax is not None:
            axes = last_ax if isinstance(last_ax, (tuple, list)) else (last_ax,)
            for a in axes:
                div *= mesh.shape[a]
        if (p.shape[-1] // div) % QBLOCK != 0:
            return p
        return gather_dequant_leaf(p, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, params, specs)


def checkpoint_codec_config(eb_valrel: float = 1e-5,
                            kernel_impl=None, chunk_size: int = 4096):
    """DEPRECATED: the weight-checkpoint codec policy now lives in
    `io.checkpoint.CheckpointPolicy` (per-leaf codec selection from one
    config).  Kept for one release; returns the same cuSZ config the
    policy's "cusz" leaf codec uses (value-range-relative bound,
    lane-aligned TPU blocks)."""
    warnings.warn("checkpoint_codec_config is deprecated; configure "
                  "io.checkpoint.CheckpointPolicy (or "
                  "codecs.get('cusz', eb=..., eb_mode='valrel', "
                  "use_tpu_blocks=True)) instead",
                  DeprecationWarning, stacklevel=2)
    from repro.core import compressor as CZ

    return CZ.CompressorConfig(eb=eb_valrel, eb_mode="valrel",
                               chunk_size=chunk_size, use_tpu_blocks=True,
                               kernel_impl=kernel_impl)


def max_weight_error(params: Any) -> float:
    """Worst relative (blockmax-relative) quantization error across
    leaves: = 1/(2·127) by construction; measured for tests."""
    worst = 0.0
    for path, p in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [str(getattr(k, "key", "")) for k in path]
        if not _quantizable(names, p):
            continue
        err = jnp.max(jnp.abs(_qdq(p) - p))
        ref = jnp.max(jnp.abs(p))
        # repro-lint: allow[host-sync] per-leaf readback in test-only metric
        worst = max(worst, float(err / (ref + 1e-30)))
    return worst
