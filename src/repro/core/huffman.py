"""Customized canonical Huffman coding (cuSZ §3.2) in JAX, adapted for TPU.

Stages (paper Fig. 1, bottom):
  1. histogram of quant codes                      -> `histogram`
  2. Huffman tree + base codebook                  -> `codeword_lengths`
  3. canonization                                  -> `canonical_codebook`
  4. encode (codebook gather) + deflate (bit-pack) -> `encode`, `deflate`
  decode: reverse-codebook retrieval + inflate     -> `inflate`

TPU adaptations (DESIGN.md §2):
  * tree build: two-queue O(k) merge over frequency-sorted symbols inside a
    single `lax.fori_loop` (device-resident, like the paper's one-GPU-thread
    build which avoids PCIe round trips); a NumPy heap oracle is provided
    for testing.
  * canonization: pure vectorized math from bitlengths (first-code
    recurrence over ≤32 lengths) — replaces the cooperative-groups kernel.
  * deflate: exclusive prefix-sum of bitwidths gives each codeword its bit
    offset; every codeword splits into ≤2 32-bit word fragments combined by
    scatter-add (add ≡ OR on disjoint bits).  Chunked exactly like the
    paper so that inflate retains coarse-grained chunk parallelism.  The
    same prefix sum is sampled every `sub_size` symbols into a per-chunk
    GAP ARRAY (Rivera et al., arXiv 2201.09118): the bit offset and the
    valid-symbol offset at each subchunk boundary.
  * inflate: gap-array two-phase decode.  Phase 1 is the gap array emitted
    by deflate; phase 2 (`inflate_gap`) decodes every subchunk
    independently from its recorded bit offset — the RAW-bound sequential
    walk shrinks from `chunk_size` symbols to `sub_size` symbols, with
    nc·(chunk/sub) subchunks running in lockstep.  Decode-side tables
    (`DecodeTable`: the LUT when max codeword length ≤ LUT_BITS, else the
    canonical length-interval bounds) are built ONCE per codebook via the
    identity-keyed `decode_table` cache, not re-executed on-device per
    call.  The legacy per-chunk sequential decoders (`inflate_lut` /
    `inflate_bitscan`) remain for gap-less (format v1) containers.

This module holds the reference algorithms; the pipeline's hot stages
(histogram / encode / deflate / inflate) are *dispatched* through
`repro.kernels.*.ops`, which select between these forms and the Pallas
kernels per backend (see kernels/dispatch.py).
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MAXLEN = 32          # hard cap on codeword bitlength (u32 stream words)
LUT_BITS = 16        # use table decoder when max bitlength <= this
SUBCHUNK = 128       # default gap-array subchunk (symbols per decode unit):
#   6 B of gap per boundary => ~0.05 B/symbol storage overhead, while the
#   sequential decode walk drops from chunk_size to SUBCHUNK steps
# static LUT-size buckets: every max codeword length maps to the next
# bucket so decode compiles one executable per bucket, not one per field
LUT_BUCKETS = (8, 12, 16)


def bucket_max_len(max_len: int) -> int:
    """Round a practical max codeword length up to the static bucket set.

    The decoder specializes on `max_len_static` (it sizes the LUT), so
    passing the raw per-field value compiles a distinct executable for
    every distinct max length.  Bucketing to {8, 12, 16} keeps the
    adaptive-repr win (small books get small LUTs) while capping the
    number of compiled decode variants; anything above LUT_BITS falls
    into the single bit-interval (bitscan) regime at MAXLEN."""
    for b in LUT_BUCKETS:
        if max_len <= b:
            return b
    return MAXLEN


def histogram(codes: jax.Array, nbins: int) -> jax.Array:
    """Frequency of each quant bin (paper §3.2.1).  `jnp.bincount` lowers to
    a scatter-add; the Pallas one-hot-MXU variant lives in kernels/histogram."""
    return jnp.bincount(codes.reshape(-1), length=nbins).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Tree build -> codeword lengths
# ---------------------------------------------------------------------------

def codeword_lengths_host(freq: np.ndarray) -> np.ndarray:
    """NumPy heap-based Huffman (oracle).  Returns bitlength per symbol
    (0 for unused symbols)."""
    freq = np.asarray(freq)
    k = freq.shape[0]
    active = [int(s) for s in np.nonzero(freq)[0]]
    if not active:
        return np.zeros(k, np.int32)
    if len(active) == 1:
        out = np.zeros(k, np.int32)
        out[active[0]] = 1
        return out
    heap = [(int(freq[s]), i, (s,)) for i, s in enumerate(active)]
    heapq.heapify(heap)
    lengths = np.zeros(k, np.int64)
    uid = len(heap)
    while len(heap) > 1:
        f1, _, s1 = heapq.heappop(heap)
        f2, _, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            lengths[s] += 1
        heapq.heappush(heap, (f1 + f2, uid, s1 + s2))
        uid += 1
    return lengths.astype(np.int32)


@partial(jax.jit, static_argnames=())
def codeword_lengths(freq: jax.Array) -> jax.Array:
    """Two-queue Huffman on device.

    With symbols sorted by frequency, merged internal nodes are produced in
    non-decreasing frequency order, so two pointer-queues replace the heap:
    O(k) merges in one fori_loop.  Returns int32 bitlengths (0 = unused).
    """
    k = freq.shape[0]
    n_active = jnp.sum(freq > 0)
    big = jnp.iinfo(jnp.int32).max // 4
    keyed = jnp.where(freq > 0, freq.astype(jnp.int32), big)
    order = jnp.argsort(keyed)                       # active symbols first
    lf = keyed[order]                                # leaf freqs, sorted

    n_int = k - 1                                    # max internal nodes
    intq = jnp.full((n_int,), big, jnp.int32)        # merged-node freqs
    ch1 = jnp.zeros((n_int,), jnp.int32)             # children (node ids:
    ch2 = jnp.zeros((n_int,), jnp.int32)             #  leaf t<k, internal k+t)

    def pick(i, j, m, intq_):
        take_leaf = (i < n_active) & ((j >= m) | (lf[jnp.clip(i, 0, k - 1)] <= intq_[jnp.clip(j, 0, n_int - 1)]))
        f = jnp.where(take_leaf, lf[jnp.clip(i, 0, k - 1)], intq_[jnp.clip(j, 0, n_int - 1)])
        node = jnp.where(take_leaf, i, k + j)
        return f, node, i + take_leaf, j + (~take_leaf)

    def body(t, st):
        i, j, intq_, ch1_, ch2_ = st
        f1, n1, i, j = pick(i, j, t, intq_)
        f2, n2, i, j = pick(i, j, t, intq_)
        intq_ = intq_.at[t].set(f1 + f2)
        ch1_ = ch1_.at[t].set(n1)
        ch2_ = ch2_.at[t].set(n2)
        return (i, j, intq_, ch1_, ch2_)

    i, j, intq, ch1, ch2 = jax.lax.fori_loop(
        0, jnp.maximum(n_active - 1, 0), body,
        (jnp.int32(0), jnp.int32(0), intq, ch1, ch2))

    # Depth pass: parents are created after children, so walk internal nodes
    # in reverse creation order propagating depth.
    depth = jnp.zeros((k + n_int,), jnp.int32)

    def dbody(s, depth_):
        t = n_active - 2 - s                          # last created -> first
        d = depth_[jnp.clip(k + t, 0, k + n_int - 1)]
        depth_ = depth_.at[ch1[jnp.clip(t, 0, n_int - 1)]].set(d + 1)
        depth_ = depth_.at[ch2[jnp.clip(t, 0, n_int - 1)]].set(d + 1)
        return depth_

    depth = jax.lax.fori_loop(0, jnp.maximum(n_active - 1, 0), dbody, depth)

    lengths_sorted = depth[:k]
    lengths = jnp.zeros((k,), jnp.int32).at[order].set(lengths_sorted)
    # single-symbol edge case: give it a 1-bit code
    lengths = jnp.where((freq > 0) & (n_active == 1), 1, lengths)
    return jnp.where(freq > 0, lengths, 0)


# ---------------------------------------------------------------------------
# Canonical codebook (paper §3.2.3)
# ---------------------------------------------------------------------------

class Codebook(NamedTuple):
    lengths: jax.Array      # [k] int32 bitlength per symbol (0 = unused)
    codes: jax.Array        # [k] uint32 canonical codeword (right-aligned)
    first_code: jax.Array   # [MAXLEN+1] uint32 canonical first code per length
    start_idx: jax.Array    # [MAXLEN+1] int32 index of first symbol of length l
    sym_canon: jax.Array    # [k] int32 symbols in canonical order
    max_len: jax.Array      # scalar int32


def canonical_codebook(lengths: jax.Array) -> Codebook:
    """Canonical codes from bitlengths alone (Schwartz-Kallick).

    Bijective, bitlength-preserving (same ratio as the base tree, paper
    §3.2.3) and decodable without the tree via (first_code, start_idx,
    sym_canon)."""
    k = lengths.shape[0]
    cnt = jnp.bincount(jnp.clip(lengths, 0, MAXLEN), length=MAXLEN + 1
                       ).at[0].set(0)                  # [MAXLEN+1]
    # first_code[l] = (first_code[l-1] + cnt[l-1]) << 1
    def fc_body(l, fc):
        return fc.at[l].set((fc[l - 1] + cnt[l - 1].astype(jnp.uint32)) << 1)
    first_code = jax.lax.fori_loop(1, MAXLEN + 1, fc_body,
                                   jnp.zeros((MAXLEN + 1,), jnp.uint32))
    start_idx = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(cnt)[:-1].astype(jnp.int32)])
    # canonical order: (length, symbol) ascending, unused symbols last
    key = jnp.where(lengths > 0, lengths, MAXLEN + 1) * jnp.int32(2 * k) \
        + jnp.arange(k, dtype=jnp.int32)
    sym_canon = jnp.argsort(key).astype(jnp.int32)
    pos = jnp.zeros((k,), jnp.int32).at[sym_canon].set(
        jnp.arange(k, dtype=jnp.int32))               # canonical rank of sym
    rank = pos - start_idx[jnp.clip(lengths, 0, MAXLEN)]
    codes = (first_code[jnp.clip(lengths, 0, MAXLEN)]
             + rank.astype(jnp.uint32))
    codes = jnp.where(lengths > 0, codes, 0).astype(jnp.uint32)
    return Codebook(lengths.astype(jnp.int32), codes, first_code,
                    start_idx, sym_canon, jnp.max(lengths).astype(jnp.int32))


def packed_codebook(cb: Codebook, unit_bits: int) -> jax.Array:
    """Paper Fig. 4: fixed-width unit holding bitwidth (MSB side) and the
    codeword (LSB side).  `unit_bits` in {32, 64}; the adaptive u32/u64
    selection (paper §3.2.2) picks 32 when max_len + 6 <= 32."""
    if unit_bits == 32:
        return (cb.lengths.astype(jnp.uint32) << 26) | cb.codes
    hi = cb.lengths.astype(jnp.uint32)        # emulate u64 as 2x u32
    return jnp.stack([hi, cb.codes], axis=-1)


def select_repr(max_len) -> int:
    """Adaptive codeword representation (paper §3.2.2)."""
    return 32 if int(max_len) + 6 <= 32 else 64


# ---------------------------------------------------------------------------
# Encode + deflate
# ---------------------------------------------------------------------------

def encode(codes: jax.Array, cb: Codebook) -> Tuple[jax.Array, jax.Array]:
    """Codebook gather: per-symbol (codeword, bitwidth).  Massively parallel
    (paper §3.2.4: 'basically memory copy')."""
    flat = codes.reshape(-1)
    return cb.codes[flat], cb.lengths[flat]


def norm_sub_size(chunk_size: int, sub_size: int) -> int:
    """Clamp the gap-array subchunk to the chunk and check divisibility."""
    sub = min(int(sub_size), int(chunk_size))
    if chunk_size % sub:
        raise ValueError(f"sub_size {sub} must divide chunk_size "
                         f"{chunk_size}")
    return sub


def deflate(cw: jax.Array, bw: jax.Array, chunk_size: int,
            sub_size: int = SUBCHUNK
            ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Concatenate variable-length codes into dense per-chunk bitstreams.

    Prefix-sum formulation: exclusive cumsum of bitwidths = bit offset of
    every codeword; each codeword contributes <=2 disjoint u32 fragments,
    combined with scatter-add.  MSB-first within each word.

    The exclusive prefix sum is additionally sampled every `sub_size`
    symbols into the GAP ARRAY (Rivera et al., arXiv 2201.09118) that
    makes inflate parallel over subchunks: `gap_bits[c, s]` is the bit
    offset of subchunk s inside chunk c, `gap_syms[c, s]` the count of
    valid (non-pad) symbols before it.

    Returns (words[nc, chunk_size] uint32, bits_used[nc] int32,
    gap_bits[nc, chunk_size//sub_size] int32, gap_syms[...] int32).
    """
    sub = norm_sub_size(chunk_size, sub_size)
    n = cw.shape[0]
    nc = -(-n // chunk_size)
    pad = nc * chunk_size - n
    cw = jnp.pad(cw.astype(jnp.uint32), (0, pad)).reshape(nc, chunk_size)
    bw = jnp.pad(bw.astype(jnp.int32), (0, pad)).reshape(nc, chunk_size)

    offs = jnp.cumsum(bw, axis=1) - bw                    # exclusive
    bits_used = (offs[:, -1] + bw[:, -1]).astype(jnp.int32)
    gap_bits = offs[:, ::sub].astype(jnp.int32)           # [nc, n_sub]
    valid_cnt = jnp.cumsum((bw > 0).astype(jnp.int32), axis=1) - (bw > 0)
    gap_syms = valid_cnt[:, ::sub].astype(jnp.int32)

    w = (offs >> 5).astype(jnp.int32)
    b = (offs & 31).astype(jnp.int32)
    sh = 32 - b - bw                                       # may be negative
    shp = jnp.clip(sh, 0, 31)
    shn = jnp.clip(-sh, 0, 31)
    hi = jnp.where(sh >= 0, cw << shp.astype(jnp.uint32),
                   cw >> shn.astype(jnp.uint32))
    lo = jnp.where(sh < 0,
                   cw << jnp.clip(32 + sh, 0, 31).astype(jnp.uint32),
                   jnp.uint32(0))
    valid = bw > 0
    hi = jnp.where(valid, hi, 0)
    lo = jnp.where(valid, lo, 0)

    out = jnp.zeros((nc, chunk_size), jnp.uint32)          # 32 bits/symbol cap
    ci = jnp.broadcast_to(jnp.arange(nc)[:, None], w.shape)
    out = out.at[ci, w].add(hi, mode="drop")
    out = out.at[ci, w + 1].add(lo, mode="drop")
    return out, bits_used, gap_bits, gap_syms


# ---------------------------------------------------------------------------
# Inflate (decode)
# ---------------------------------------------------------------------------

def _build_lut(cb: Codebook, lut_bits: int) -> Tuple[jax.Array, jax.Array]:
    """Dense (symbol, length) table keyed by the next `lut_bits` bits.

    Left-aligned canonical codes are strictly increasing in canonical order,
    so a scatter of group starts + cummax fill builds the table without
    variable-length repeats."""
    k = cb.lengths.shape[0]
    L = lut_bits
    len_canon = cb.lengths[cb.sym_canon]
    shift = jnp.clip(L - len_canon, 0, 31).astype(jnp.uint32)
    starts = (cb.codes[cb.sym_canon] << shift).astype(jnp.uint32)
    active = len_canon > 0
    starts = jnp.where(active, starts, jnp.uint32(1) << L)  # OOB -> dropped
    mark = jnp.zeros((1 << L,), jnp.int32)
    mark = mark.at[starts.astype(jnp.int32)].max(
        jnp.where(active, jnp.arange(k, dtype=jnp.int32) + 1, 0), mode="drop")
    fill = jax.lax.cummax(mark) - 1                        # canonical rank
    fill = jnp.clip(fill, 0)
    return cb.sym_canon[fill], len_canon[fill]


def inflate_lut(words: jax.Array, n_valid: jax.Array, cb: Codebook,
                lut_bits: int = LUT_BITS,
                lut: Optional[Tuple[jax.Array, jax.Array]] = None
                ) -> jax.Array:
    """O(symbols) per-chunk decode via the LUT; vmapped over chunks.

    words: [nc, W] uint32; n_valid: [nc] symbols per chunk.
    Returns codes [nc, chunk_symbols] (chunk_symbols == W: one u32 per
    symbol capacity, mirroring deflate).  Pass `lut` (from a cached
    `DecodeTable`) to skip the in-trace table build."""
    lut_sym, lut_len = lut if lut is not None else _build_lut(cb, lut_bits)
    nc, W = words.shape
    n_sym = W

    def chunk_decode(wrow, nv):
        wext = jnp.concatenate([wrow, jnp.zeros((1,), jnp.uint32)])

        def step(bitpos, i):
            wi = bitpos >> 5
            bo = (bitpos & 31).astype(jnp.uint32)
            cur = wext[wi] << bo
            nxt = jnp.where(bo > 0, wext[wi + 1] >> (jnp.uint32(32) - bo),
                            jnp.uint32(0))
            peek = ((cur | nxt) >> jnp.uint32(32 - lut_bits)).astype(jnp.int32)
            sym = lut_sym[peek]
            ln = lut_len[peek]
            ok = i < nv
            return bitpos + jnp.where(ok, ln, 0), jnp.where(ok, sym, 0)

        _, syms = jax.lax.scan(step, jnp.int32(0),
                               jnp.arange(n_sym, dtype=jnp.int32))
        return syms

    return jax.vmap(chunk_decode)(words, n_valid)


def inflate_bitscan(words: jax.Array, bits_used: jax.Array, n_valid: jax.Array,
                    cb: Codebook) -> jax.Array:
    """O(bits) per-chunk decode (fallback when max_len > LUT_BITS).  Walks
    one bit at a time exactly like the paper's sequential inflate."""
    nc, W = words.shape
    n_sym = W
    total_bits = W * 32

    def chunk_decode(wrow, nb, nv):
        def step(carry, bitpos):
            acc, ln, outpos, out = carry
            wi = bitpos >> 5
            bit = (wrow[wi] >> jnp.uint32(31 - (bitpos & 31))) & 1
            acc = (acc << 1) | bit
            ln = ln + 1
            lnc = jnp.clip(ln, 0, MAXLEN)
            # match if there are codes of this length and acc falls in range
            lo = cb.first_code[lnc]
            idx = cb.start_idx[lnc] + (acc - lo).astype(jnp.int32)
            in_range = (acc >= lo) & (idx < cb.start_idx[lnc] +
                                      _len_count(cb, lnc))
            active = (bitpos < nb) & (outpos < nv)
            emit = in_range & active
            sym = cb.sym_canon[jnp.clip(idx, 0, cb.sym_canon.shape[0] - 1)]
            out = jnp.where(emit, out.at[outpos].set(sym, mode="drop"), out)
            acc = jnp.where(emit, jnp.uint32(0), acc)
            ln = jnp.where(emit, 0, ln)
            outpos = outpos + emit.astype(jnp.int32)
            return (acc, ln, outpos, out), None

        init = (jnp.uint32(0), jnp.int32(0), jnp.int32(0),
                jnp.zeros((n_sym,), jnp.int32))
        (_, _, _, out), _ = jax.lax.scan(
            step, init, jnp.arange(total_bits, dtype=jnp.int32))
        return out

    return jax.vmap(chunk_decode)(words, bits_used, n_valid)


def _len_count(cb: Codebook, l: jax.Array) -> jax.Array:
    nxt = jnp.where(l < MAXLEN,
                    cb.start_idx[jnp.clip(l + 1, 0, MAXLEN)],
                    jnp.sum(cb.lengths > 0).astype(jnp.int32))
    return nxt - cb.start_idx[l]


def inflate(words: jax.Array, bits_used: jax.Array, n_valid: jax.Array,
            cb: Codebook, max_len_static: int) -> jax.Array:
    """Dispatch LUT vs bit-scan on the *static* bound for max codeword
    length (callers pass the practical bound; paper's adaptive-repr idea).
    This is the legacy per-chunk SEQUENTIAL decode, kept for gap-less
    (format v1) streams; gap-array streams use `inflate_gap`."""
    if max_len_static <= LUT_BITS:
        return inflate_lut(words, n_valid, cb,
                           lut_bits=max(1, max_len_static))
    return inflate_bitscan(words, bits_used, n_valid, cb)


# ---------------------------------------------------------------------------
# Gap-array two-phase decode (Rivera et al., arXiv 2201.09118)
# ---------------------------------------------------------------------------

class DecodeTable(NamedTuple):
    """Everything the decode side derives from a codebook, built once per
    codebook (see `decode_table`) instead of inside every decode trace.

    `lut_sym`/`lut_len` are the dense LUT (LUT regime, max_len <= LUT_BITS;
    [1]-sized dummies otherwise).  `thresh`/`lmask` are the canonical
    length-interval bounds used by the LUT-free decoders: left-aligned
    canonical code intervals tile [0, 2^32) contiguously in length order
    (base_al[l+1] == end_al[l]), so for a 32-bit left-aligned peek of a
    valid stream the codeword length is

        len = 1 + sum_l lmask[l] * [peek >= thresh[l]]

    with thresh[l] = (first_code[l] + count[l]) << (32 - l) and lmask
    enabling 1 <= l < max_len (for those l the end never reaches 2^32, so
    the u32 compare is exact)."""
    cb: Codebook
    lut_sym: jax.Array      # [1 << lut_bits] int32 (or [1] dummy)
    lut_len: jax.Array      # [1 << lut_bits] int32 (or [1] dummy)
    thresh: jax.Array       # [MAXLEN + 1] uint32 end-of-interval bounds
    lmask: jax.Array        # [MAXLEN + 1] int32 validity of each bound


def _length_bounds(cb: Codebook) -> Tuple[jax.Array, jax.Array]:
    cnt = jnp.bincount(jnp.clip(cb.lengths, 0, MAXLEN),
                       length=MAXLEN + 1).at[0].set(0)
    ell = jnp.arange(MAXLEN + 1, dtype=jnp.int32)
    span = cb.first_code + cnt.astype(jnp.uint32)     # first_code[l]+count[l]
    thresh = span << jnp.clip(32 - ell, 0, 31).astype(jnp.uint32)
    lmask = ((ell >= 1) & (ell < cb.max_len)).astype(jnp.int32)
    return thresh, lmask


@partial(jax.jit, static_argnames=("max_len_static",))
def build_decode_table(lengths: jax.Array, max_len_static: int) -> DecodeTable:
    """Codebook + decode tables from stored bitlengths (one jit per
    (nbins, bucketed max_len) — NOT per field)."""
    cb = canonical_codebook(lengths)
    thresh, lmask = _length_bounds(cb)
    if max_len_static <= LUT_BITS:
        lut_sym, lut_len = _build_lut(cb, max(1, max_len_static))
    else:
        lut_sym = jnp.zeros((1,), jnp.int32)
        lut_len = jnp.zeros((1,), jnp.int32)
    return DecodeTable(cb, lut_sym, lut_len, thresh, lmask)


# identity-keyed LRU: repeated decodes of the same stored codebook (serve
# eviction-restore, checkpoint restore retries) reuse the built tables
# with zero host syncs; entries hold a strong ref to the key array so an
# id() can never be reused while its entry is alive.
_DECODE_TABLE_CACHE: "OrderedDict[Tuple[int, int], Tuple[jax.Array, DecodeTable]]" = OrderedDict()
_DECODE_TABLE_CACHE_SIZE = 64


def decode_table(lengths: jax.Array, max_len_static: int) -> DecodeTable:
    """Cached `build_decode_table`: the (1 << lut_bits)-entry scatter +
    cummax LUT build runs once per codebook array, not on-device at every
    restore / eviction-restore step."""
    key = (id(lengths), int(max_len_static))
    hit = _DECODE_TABLE_CACHE.get(key)
    if hit is not None and hit[0] is lengths:
        _DECODE_TABLE_CACHE.move_to_end(key)
        return hit[1]
    tbl = build_decode_table(lengths, int(max_len_static))
    _DECODE_TABLE_CACHE[key] = (lengths, tbl)
    while len(_DECODE_TABLE_CACHE) > _DECODE_TABLE_CACHE_SIZE:
        _DECODE_TABLE_CACHE.popitem(last=False)
    return tbl


def inflate_gap(words: jax.Array, n_valid: jax.Array, gap_bits: jax.Array,
                table: DecodeTable, sub_size: int, max_len_static: int
                ) -> jax.Array:
    """Phase-2 gap-array decode: every subchunk decodes independently from
    its recorded bit offset, so the sequential walk is `sub_size` symbols
    (not `chunk_size`) and nc·n_sub subchunks run in lockstep.

    words: [nc, W] uint32; n_valid: [nc]; gap_bits: [nc, W // sub_size].
    LUT regime (max_len <= LUT_BITS) peeks `lut_bits` bits through the
    cached LUT; otherwise the canonical length-interval compare decodes a
    full 32-bit peek without any table (see `DecodeTable`).  Returns
    codes [nc, W], bit-exact with the sequential `inflate`."""
    nc, W = words.shape
    n_sub = gap_bits.shape[1]
    if n_sub * sub_size != W:
        raise ValueError(f"gap array [{nc}, {n_sub}] does not tile chunks "
                         f"of {W} symbols with sub_size={sub_size}")
    use_lut = max_len_static <= LUT_BITS
    lut_bits = max(1, max_len_static)
    cb = table.cb

    def chunk_decode(wrow, nv, gaps):
        wext = jnp.concatenate([wrow, jnp.zeros((1,), jnp.uint32)])
        base = jnp.arange(n_sub, dtype=jnp.int32) * sub_size

        def step(bitpos, i):
            wi = bitpos >> 5
            bo = (bitpos & 31).astype(jnp.uint32)
            cur = wext[wi] << bo
            nxt = jnp.where(bo > 0,
                            wext[jnp.minimum(wi + 1, W)]
                            >> (jnp.uint32(32) - bo), jnp.uint32(0))
            peek = cur | nxt                      # 32-bit left-aligned window
            if use_lut:
                slot = (peek >> jnp.uint32(32 - lut_bits)).astype(jnp.int32)
                sym = table.lut_sym[slot]
                ln = table.lut_len[slot]
            else:
                hit = (peek[:, None] >= table.thresh[None, :]) \
                    & (table.lmask[None, :] > 0)
                ln = 1 + jnp.sum(hit.astype(jnp.int32), axis=1)
                lnc = jnp.clip(ln, 1, MAXLEN)
                code = peek >> (jnp.uint32(32) - lnc.astype(jnp.uint32))
                idx = cb.start_idx[lnc] \
                    + (code - cb.first_code[lnc]).astype(jnp.int32)
                sym = cb.sym_canon[jnp.clip(idx, 0,
                                            cb.sym_canon.shape[0] - 1)]
            ok = (base + i) < nv
            return bitpos + jnp.where(ok, ln, 0), jnp.where(ok, sym, 0)

        _, syms = jax.lax.scan(step, gaps.astype(jnp.int32),
                               jnp.arange(sub_size, dtype=jnp.int32))
        return syms.T.reshape(W)                  # [sub, n_sub] -> chunk order

    return jax.vmap(chunk_decode)(words, n_valid, gap_bits)
