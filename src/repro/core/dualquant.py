"""DUAL-QUANTIZATION (cuSZ §3.1) in JAX, adapted for TPU.

The paper's scheme:
  PREQUANT   d° = round(d / (2·eb))           (the ONLY lossy step)
  PREDICT    p° = ℓ(d°_neighbors)             (Lorenzo predictor)
  POSTQUANT  δ° = d° − p°                     (exact integer arithmetic)

On pre-quantized integers the 1st-order Lorenzo predictor is exactly the
d-dimensional first-difference operator, so

  δ = Π_axes (1 − S_axis) d°     (S = shift-by-one with zero fill)

and its inverse is integration: an inclusive prefix sum (cumsum) along each
axis.  This is the central TPU adaptation (DESIGN.md §2): the paper's
decompression is sequential per chunk (RAW chain); here the reverse
dual-quant becomes a stack of `jnp.cumsum` calls — fully parallel and exact
in int32.

Blocking follows the paper (§3.1.1): data is split into independent blocks
with an implicit zero padding layer, so the outer-layer points fall back to
lower-order Lorenzo, every point is handled uniformly, and blocks are
embarrassingly parallel in both directions.  Default block shapes are the
paper's (32 / 16×16 / 8×8×8); larger TPU-friendly blocks are available and
benchmarked (bigger VMEM tiles, fewer boundary resets → better ratio).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Paper defaults (§3.1.1).
DEFAULT_BLOCKS = {1: (256,), 2: (16, 16), 3: (8, 8, 8)}
# TPU-friendly blocks (lane-aligned; see EXPERIMENTS.md §Perf).
TPU_BLOCKS = {1: (4096,), 2: (64, 128), 3: (8, 16, 128)}


def prequant(data: jax.Array, eb: float) -> jax.Array:
    """PREQUANT: d° = round(d/(2·eb)), stored as int32 (exact domain).

    |d − d°·2eb| ≤ eb by construction; this is the only lossy step of the
    whole pipeline.  Valid while |d|/(2·eb) < 2**31 (guarded in compressor).
    """
    return jnp.rint(data.astype(jnp.float32) / (2.0 * eb)).astype(jnp.int32)


def dequant(dq: jax.Array, eb: float, dtype=jnp.float32) -> jax.Array:
    """Inverse of PREQUANT: d• = d°·(2·eb)."""
    return (dq.astype(jnp.float32) * (2.0 * eb)).astype(dtype)


def lorenzo_delta(dq: jax.Array, axes: Sequence[int]) -> jax.Array:
    """POSTQUANT deltas: apply (1 − S) along each axis (zero-padded shift).

    Equivalent to δ = d° − ℓ(d°_sr) with the paper's zero padding layer.
    Exact in int32.
    """
    delta = dq
    for ax in axes:
        delta = delta - _shift1(delta, ax)
    return delta


def lorenzo_reconstruct(delta: jax.Array, axes: Sequence[int]) -> jax.Array:
    """Inverse of `lorenzo_delta`: inclusive cumsum along each axis.

    This replaces the paper's sequential cascading reconstruction (§3.3)
    with an associative-scan-friendly form — the TPU-native inverse.
    """
    dq = delta
    for ax in axes:
        dq = jnp.cumsum(dq, axis=ax, dtype=delta.dtype)
    return dq


def _shift1(x: jax.Array, axis: int) -> jax.Array:
    """Shift by +1 along `axis`, filling with 0 (the padding layer)."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (1, 0)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, x.shape[axis])
    return jnp.pad(x, pad)[tuple(sl)]


# ---------------------------------------------------------------------------
# Blocking (paper §3.1.1): reshape into independent blocks so that both
# compression and decompression parallelize coarsely, with the zero padding
# layer at every block boundary.
# ---------------------------------------------------------------------------

def padded_shape(shape: Sequence[int], block: Sequence[int]) -> Tuple[int, ...]:
    return tuple(-(-s // b) * b for s, b in zip(shape, block))


def pad_to_blocks(x: jax.Array, block: Sequence[int]) -> jax.Array:
    """Edge-replicate pad to a multiple of the block shape (cropped on
    decompress; replicate keeps the pad region cheap to encode)."""
    tgt = padded_shape(x.shape, block)
    pad = [(0, t - s) for s, t in zip(x.shape, tgt)]
    if all(p == (0, 0) for p in pad):
        return x
    return jnp.pad(x, pad, mode="edge")


def block_split(x: jax.Array, block: Sequence[int]) -> jax.Array:
    """[D1,..,Dn] -> [nb1,..,nbn, b1,..,bn] (block axes last)."""
    n = x.ndim
    assert len(block) == n
    shp = []
    for s, b in zip(x.shape, block):
        assert s % b == 0, (x.shape, block)
        shp += [s // b, b]
    x = x.reshape(shp)
    perm = list(range(0, 2 * n, 2)) + list(range(1, 2 * n, 2))
    return x.transpose(perm)


def block_merge(x: jax.Array, block: Sequence[int]) -> jax.Array:
    """Inverse of block_split."""
    n = x.ndim // 2
    perm = []
    for i in range(n):
        perm += [i, n + i]
    x = x.transpose(perm)
    shp = [x.shape[2 * i] * x.shape[2 * i + 1] for i in range(n)]
    return x.reshape(shp)


def blocked_delta(x: jax.Array, eb: float, block: Sequence[int]) -> jax.Array:
    """pad → PREQUANT → block → Lorenzo delta on in-block axes.

    Returns int32 deltas shaped [nb..., b...].

    NOTE: the compressor hot path no longer calls this two-stage form —
    it routes through `kernels.lorenzo.ops.dualquant_blocks`, the fused
    PREQUANT+delta+POSTQUANT op (one blocked kernel invocation, no
    standalone delta tree between stage dispatches).  This form remains
    the building block of the reference oracle and the unfused baseline
    in `benchmarks/throughput.py`.
    """
    n = x.ndim
    xb = block_split(pad_to_blocks(x, block), block)
    dq = prequant(xb, eb)
    return lorenzo_delta(dq, axes=range(n, 2 * n))


def blocked_reconstruct(delta: jax.Array, eb: float, block: Sequence[int],
                        orig_shape: Sequence[int], dtype=jnp.float32) -> jax.Array:
    """cumsum inverse per block → merge → crop → dequant."""
    n = len(block)
    dq = lorenzo_reconstruct(delta, axes=range(n, 2 * n))
    full = block_merge(dq, block)
    crop = tuple(slice(0, s) for s in orig_shape)
    return dequant(full[crop], eb, dtype)


# ---------------------------------------------------------------------------
# POSTQUANT code mapping + outliers (paper Algorithm 2).
# Code 0 is reserved for OUTLIER; in-cap deltas map to 1..cap-1 around the
# radius.  Outliers keep their exact integer delta in a sparse side channel
# (DESIGN.md §2: delta-outliers keep the cumsum inverse linear & exact).
# ---------------------------------------------------------------------------

def postquant_codes(delta: jax.Array, cap: int) -> Tuple[jax.Array, jax.Array]:
    """Map int32 deltas to quant codes in [0, cap). Returns (codes, in_cap)."""
    radius = cap // 2
    in_cap = (delta > -radius) & (delta < radius)
    codes = jnp.where(in_cap, delta + radius, 0).astype(jnp.int32)
    return codes, in_cap


def codes_to_delta(codes: jax.Array, cap: int) -> jax.Array:
    """In-cap codes back to deltas; outlier positions (code 0) become 0 and
    are overwritten by the sparse outlier scatter."""
    radius = cap // 2
    return jnp.where(codes == 0, 0, codes - radius).astype(jnp.int32)


def extract_outliers(delta_flat: jax.Array, in_cap_flat: jax.Array,
                     capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gather up to `capacity` outlier (index, delta) pairs.

    Returns (idx[int32, capacity] with -1 fill, val[int32, capacity],
    n_outliers).  n_outliers > capacity means overflow (caller surfaces it;
    capacity is a config, default 10% of N as in SZ practice).
    """
    n = delta_flat.shape[0]
    n_out = jnp.sum(~in_cap_flat)
    # fill with an out-of-range index: scatter mode="drop" ignores it
    # (NB: -1 would WRAP to the last element in jax scatter semantics)
    (idx,) = jnp.nonzero(~in_cap_flat, size=capacity, fill_value=n)
    val = jnp.where(idx < n, delta_flat[jnp.clip(idx, 0, n - 1)], 0
                    ).astype(jnp.int32)
    return idx.astype(jnp.int32), val, n_out.astype(jnp.int32)


def scatter_outliers(delta_flat: jax.Array, idx: jax.Array,
                     val: jax.Array) -> jax.Array:
    """Write exact outlier deltas back (mode=drop ignores the -1 fill)."""
    return delta_flat.at[idx].set(val, mode="drop")
