"""Error-bounded KV-cache compression for long-context serving.

PREQUANT applied to the decode-time KV cache: K/V are stored as int8 with
per-(head, seq-block) scales, an explicit error bound of scale/2 per
element, and dequantized on the fly inside attention.  For `decode_32k` /
`long_500k` this shrinks the dominant serving memory term 4x (bf16->int8
with fp32 scales amortized over SEQ_BLOCK elements).

For Mamba/hybrid archs the same codec compresses the SSD state (it *is*
the cache there — DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import _compat

SEQ_BLOCK = 128          # scale granularity along the sequence axis
#: scale floor for all-zero blocks: matches `int8.block_quantize`'s
#: clamp, so a zero-extension block assembled by hand (cache init, paged
#: slot adoption) is bit-identical to one produced by quantizing zeros
SCALE_FLOOR = 1e-30
_QMAX = 127.0


class QuantKV(NamedTuple):
    """In-memory quantized-cache format: the `"int8-block"` codec's
    payload as a NamedTuple (the decode-step hot path indexes it
    directly; `kv_quantize`/`kv_dequantize` are the codec's math)."""
    q: jax.Array          # int8, same shape as the source
    scale: jax.Array      # f32, shape = source with seq axis / SEQ_BLOCK


def kv_quantize(x: jax.Array, seq_axis: int) -> QuantKV:
    """Blockwise int8 quantization along `seq_axis` (length must be a
    multiple of SEQ_BLOCK; cache buffers are allocated that way).
    Delegates to the registered `"int8-block"` codec's quantization."""
    from repro.codecs import int8 as I8

    assert x.shape[seq_axis] % SEQ_BLOCK == 0, (x.shape, seq_axis)
    q, scale = I8.block_quantize(x, seq_axis, SEQ_BLOCK)
    return QuantKV(q, scale)


def kv_dequantize(qkv: QuantKV, seq_axis: int, dtype=jnp.bfloat16) -> jax.Array:
    from repro.codecs import int8 as I8

    return I8.block_dequantize(qkv.q, qkv.scale, seq_axis, SEQ_BLOCK, dtype)


def kv_update_block(qkv: QuantKV, new: jax.Array, pos, seq_axis: int) -> QuantKV:
    """Write `new` (one token slot, already sized [..,1,..] on seq_axis)
    into the quantized cache at `pos`.  The owning SEQ_BLOCK's scale is
    monotonically widened (never shrunk) so previously written tokens keep
    their bound.  Widening is per scale coordinate — the scale tensor has
    one entry per (batch, head, dim) coordinate, so one coordinate's large
    value must not widen (and thus requantize-destroy) the others; this
    also keeps the all-zero s_max-extension blocks at the 1e-30 floor
    until *their own* coordinate sees a value."""
    blk = pos // SEQ_BLOCK
    old_scale = jax.lax.dynamic_index_in_dim(qkv.scale, blk, seq_axis,
                                             keepdims=True)
    need = jnp.max(jnp.abs(new), axis=seq_axis,
                   keepdims=True).astype(jnp.float32) / _QMAX
    new_scale = jnp.maximum(old_scale, jnp.maximum(need, SCALE_FLOOR))
    # requantize the block's existing tokens under the widened scale so
    # their dequantized values are preserved (bound becomes new_scale/2)
    old_blk = jax.lax.dynamic_slice_in_dim(qkv.q, blk * SEQ_BLOCK, SEQ_BLOCK,
                                           seq_axis)
    requant = jnp.clip(jnp.rint(old_blk.astype(jnp.float32)
                                * (old_scale / new_scale)),
                       -_QMAX, _QMAX).astype(jnp.int8)
    q = jax.lax.dynamic_update_slice_in_dim(qkv.q, requant, blk * SEQ_BLOCK,
                                            seq_axis)
    qn = jnp.clip(jnp.rint(new.astype(jnp.float32) / new_scale),
                  -_QMAX, _QMAX).astype(jnp.int8)
    q = jax.lax.dynamic_update_index_in_dim(q, jnp.squeeze(qn, seq_axis),
                                            pos, seq_axis)
    scale = jax.lax.dynamic_update_slice_in_dim(qkv.scale, new_scale, blk,
                                                seq_axis)
    return QuantKV(q, scale)


# ---------------------------------------------------------------------------
# cuSZ offload: evicted / resharded cache blocks go through the full
# dual-quant + Huffman pipeline (host offload, prefill->decode reshard).
# The int8 path above is the in-memory format; the wire/disk one is the
# `"cusz"` codec:
#
#     c = codecs.get("cusz", cfg=cfg).encode(block)   # keeps bf16 dtype
#     block2 = codecs.decode(c)
#
# The entry points below are DEPRECATED shims over that path: they lose
# the source dtype (restore hardcodes the caller's) and need eb/shape fed
# back out-of-band — exactly the bug class the Container header fixes.
# ---------------------------------------------------------------------------

def kv_offload_pack(x: jax.Array, cfg) -> Tuple[dict, float]:
    """DEPRECATED: use `codecs.get("cusz", cfg=cfg).encode(x)`."""
    _compat.warn_once(
        "kv_offload_pack",
        "kv_offload_pack is deprecated; use "
        "repro.codecs.get('cusz', cfg=cfg).encode(x) — the "
        "returned Container records dtype/shape/eb itself")
    from repro.core import compressor as CZ

    blob, eb = CZ.compress(jnp.asarray(x, jnp.float32), cfg)
    return CZ.pack_blob(blob), eb


def kv_offload_restore(packed: dict, eb: float, shape, cfg,
                       dtype=jnp.bfloat16) -> jax.Array:
    """DEPRECATED: use `codecs.decode(container)` (dtype comes from the
    container header, not a caller-side default)."""
    _compat.warn_once(
        "kv_offload_restore",
        "kv_offload_restore is deprecated; use "
        "repro.codecs.decode(container)")
    from repro.core import compressor as CZ

    out = CZ.decompress(CZ.unpack_blob(packed), cfg, eb, tuple(shape))
    return out.astype(dtype)


def error_bound(qkv: QuantKV) -> jax.Array:
    """Per-block abs error bound = scale/2 (the paper's eb semantics)."""
    return qkv.scale / 2.0


# ---------------------------------------------------------------------------
# Prefill -> decode handoff wire format: per-seq-slab registry Containers.
#
# The disaggregated-serving reshard moves each cache tensor as a tuple of
# self-describing Containers sliced along the sequence axis (one slab per
# SEQ_BLOCK group by default).  The wire codec is a registry choice:
#
#   * "int8-block" (default): split-stable blockwise quantization — a
#     QuantKV source is re-sliced in *payload space* (no dequantize) and
#     the decode side adopts the payload directly as its in-memory
#     QuantKV cache, so compressed bytes cross the boundary with zero
#     f32 round trip.
#   * "cusz": the full dual-quant + Huffman pipeline per slab (the
#     host-offload / storage leg; each slab container is independent).
#   * "fz": Lorenzo + fused bitshuffle with zero-plane elision — the
#     throughput-class error-bounded wire (no codebook build on encode,
#     no host prep on decode).
#   * "lossless": raw bytes (the baseline the benchmarks compare against).
# ---------------------------------------------------------------------------

#: default cusz wire configuration for cache slabs: a serving-tolerance
#: value-range-relative bound and full outlier capacity (never overflows)
CUSZ_WIRE_CFG = {"eb": 1e-2, "eb_mode": "valrel", "outlier_frac": 1.0}

#: default fz wire configuration: same serving-tolerance bound; the
#: 512-symbol chunk keeps plane-elision granularity near head-dim slabs
FZ_WIRE_CFG = {"eb": 1e-2, "eb_mode": "valrel", "outlier_frac": 1.0,
               "chunk_size": 512}

#: wires that encode a whole dequantized slab through a registry codec
#: (vs. the payload-space int8-block path)
WHOLE_SLAB_WIRES = ("cusz", "fz", "lossless")


def _wire_codec(wire: str, seq_axis: int, wire_cfg: Optional[dict] = None):
    from repro import codecs

    if wire == "cusz":
        return codecs.get("cusz", **(wire_cfg or CUSZ_WIRE_CFG))
    if wire == "fz":
        return codecs.get("fz", **(wire_cfg or FZ_WIRE_CFG))
    if wire == "lossless":
        return codecs.get("lossless")
    return codecs.get_block_codec(wire, axis=seq_axis, block=SEQ_BLOCK)


def _n_slabs(length: int, nslabs: Optional[int]) -> int:
    if nslabs is None:
        nslabs = max(1, length // SEQ_BLOCK)
    assert length % nslabs == 0, (length, nslabs)
    return nslabs


def _slice_axis(x, axis: int, start: int, stop: int):
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(start, stop)
    return x[tuple(sl)]


def _encode_slab(codec, slab, seq_axis: int):
    """Encode one slab through a whole-slab (non-blockwise) codec,
    flattened to [tokens, features] first: the chunked-transform codecs
    pad every dim to Lorenzo-block multiples, and a cache's small
    head/dim axes would blow that padding up 4-8x.  The slab's logical
    shape rides in the header (`kv_shape`) so the decode side restores
    it."""
    feat = 1
    for s in slab.shape[seq_axis + 1:]:
        feat *= int(s)
    flat = slab.reshape(-1, feat) if feat > 1 else slab.reshape(-1)
    c = codec.encode(flat)
    return c.replace(header=c.header.with_params(
        kv_shape=tuple(int(s) for s in slab.shape)))


def kv_wire_encode(x, seq_axis: int, *, wire: str = "int8-block",
                   nslabs: Optional[int] = None,
                   source_dtype=jnp.bfloat16,
                   wire_cfg: Optional[dict] = None,
                   pack: bool = True) -> Tuple:
    """Encode one cache tensor (raw array or in-memory ``QuantKV``) into
    per-seq-slab Containers.  Returns a tuple of (packed) containers whose
    seq-axis shapes sum to the source length.  With the int8-block wire a
    QuantKV source never leaves payload space, and a raw source encodes
    bit-identically to whole-tensor ``kv_quantize`` (slab boundaries are
    SEQ_BLOCK-aligned, so no scale block straddles a slice)."""
    from repro import codecs

    codec = _wire_codec(wire, seq_axis, wire_cfg)
    if isinstance(x, QuantKV):
        if wire == "int8-block":
            n = _n_slabs(x.q.shape[seq_axis], nslabs)
            step = x.q.shape[seq_axis] // n
            assert step % SEQ_BLOCK == 0, (x.q.shape, seq_axis, n)
            sstep = step // SEQ_BLOCK
            parts = []
            for i in range(n):
                q = _slice_axis(x.q, seq_axis, i * step, (i + 1) * step)
                scale = _slice_axis(x.scale, seq_axis, i * sstep,
                                    (i + 1) * sstep)
                header = codecs.make_header(
                    codec.name, codec.version,
                    jax.ShapeDtypeStruct(q.shape, source_dtype),
                    axis=seq_axis, block=SEQ_BLOCK)
                parts.append(codecs.Container(header,
                                              {"q": q, "scale": scale}))
            return tuple(codec.pack(p) for p in parts) if pack \
                else tuple(parts)
        x = kv_dequantize(x, seq_axis, dtype=source_dtype)

    n = _n_slabs(x.shape[seq_axis], nslabs)
    if wire == "int8-block":
        assert (x.shape[seq_axis] // n) % SEQ_BLOCK == 0, \
            (x.shape, seq_axis, n)
        parts = codec.encode_parts(x, seq_axis, n)
    else:
        step = x.shape[seq_axis] // n
        parts = []
        for i in range(n):
            slab = _slice_axis(x, seq_axis, i * step, (i + 1) * step)
            c = _encode_slab(codec, slab, seq_axis)
            if wire != "lossless" and not codec.valid(c):
                # graceful degradation: a slab the codec cannot represent
                # faithfully (cusz outlier overflow) ships raw instead of
                # aborting the handoff; the decode side reads each part's
                # own header, so mixed slabs restore transparently
                c = _encode_slab(codecs.get("lossless"), slab, seq_axis)
            parts.append(c)

    def _pack(p):
        own = codec if p.header.codec == codec.name \
            else codecs.get(p.header.codec)
        return own.pack(p)

    return tuple(_pack(p) for p in parts) if pack else tuple(parts)


def kv_wire_adopt(parts: Sequence, seq_axis: int) -> QuantKV:
    """Adopt int8-block wire containers directly as the in-memory QuantKV
    cache: the quantized payload (q int8 + f32 block scales) is
    concatenated along the seq axis and becomes the cache — no dequantize
    and no re-quantization round trip.  Raises for non-int8-block wires
    (those must go through ``kv_wire_restore``)."""
    for p in parts:
        if p.header.codec != "int8-block":
            raise ValueError(
                f"cannot adopt codec {p.header.codec!r} as QuantKV; only "
                f"the int8-block wire payload IS the in-memory format")
    q = jnp.concatenate([jnp.asarray(p.payload["q"]) for p in parts],
                        axis=seq_axis)
    scale = jnp.concatenate([jnp.asarray(p.payload["scale"])
                             for p in parts], axis=seq_axis)
    return QuantKV(q, scale)


def kv_slab_shape(part) -> Tuple[int, ...]:
    """Logical (un-flattened) slab shape of a wire container."""
    kv_shape = part.header.param("kv_shape")
    return tuple(kv_shape) if kv_shape is not None else part.header.shape


def kv_wire_restore(parts: Sequence, seq_axis: int,
                    dtype=jnp.bfloat16) -> jax.Array:
    """Decode wire containers back to a dense cache tensor (any codec),
    concatenated along the seq axis."""
    from repro import codecs

    vals = []
    for p in parts:
        v = codecs.decode(p).reshape(kv_slab_shape(p))
        vals.append(v.astype(dtype))
    return jnp.concatenate(vals, axis=seq_axis)


def kv_wire_nbytes(parts: Sequence) -> int:
    """Bytes the containers occupy on the wire (packed payload bytes)."""
    return sum(p.nbytes for p in parts)


# ---------------------------------------------------------------------------
# Page-granular layer: one *page* = one SEQ_BLOCK-aligned seq slab of a
# cache tensor, kept in the in-memory QuantKV payload form.  The paged
# serve pool (`repro.serve.pool`) slices sequences into pages, parks them
# in a shared device pool and evicts cold ones to host through a wire
# codec; everything here stays in payload space for the int8-block case,
# so pool pages adopted back into a decode slot are bit-identical to the
# whole-tensor quantize path (the PR-5 zero-requantize trick, one block
# at a time).
# ---------------------------------------------------------------------------

def kv_page_count(length: int) -> int:
    """Pages needed to back `length` written cache positions."""
    return -(-int(length) // SEQ_BLOCK)


def kv_page_slice(qkv: QuantKV, seq_axis: int, idx: int) -> QuantKV:
    """Payload-space slice of page `idx`: q gets SEQ_BLOCK rows, scale
    gets the one matching block row — no dequantize."""
    q = _slice_axis(qkv.q, seq_axis, idx * SEQ_BLOCK, (idx + 1) * SEQ_BLOCK)
    scale = _slice_axis(qkv.scale, seq_axis, idx, idx + 1)
    return QuantKV(q, scale)


def kv_page_concat(slabs: Sequence[QuantKV], seq_axis: int) -> QuantKV:
    """Payload-space concat of page slabs along the seq axis (inverse of
    `kv_page_slice` over consecutive pages)."""
    q = jnp.concatenate([jnp.asarray(s.q) for s in slabs], axis=seq_axis)
    scale = jnp.concatenate([jnp.asarray(s.scale) for s in slabs],
                            axis=seq_axis)
    return QuantKV(q, scale)


def kv_page_encode(slab: QuantKV, seq_axis: int, *,
                   codec: str = "int8-block",
                   source_dtype=jnp.bfloat16,
                   codec_cfg: Optional[dict] = None) -> Tuple:
    """Page-granular wire encode (the pool's eviction leg): one page slab
    becomes a 1-tuple of packed Containers.  "int8-block" never leaves
    payload space (bit-exact restore); the whole-slab wires ("cusz",
    "fz", "lossless") dequantize the slab and re-encode it whole (the
    restore side re-quantizes, stacking the codec's bound on top of the
    page's scale/2)."""
    return kv_wire_encode(slab, seq_axis, wire=codec, nslabs=1,
                          source_dtype=source_dtype, wire_cfg=codec_cfg)


def kv_page_adopt(parts: Sequence, seq_axis: int) -> QuantKV:
    """Adopt packed int8-block page containers back as the in-memory
    QuantKV slab — payload-space, bit-exact (`kv_wire_adopt` per page)."""
    return kv_wire_adopt(parts, seq_axis)
