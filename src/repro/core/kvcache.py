"""Error-bounded KV-cache compression for long-context serving.

PREQUANT applied to the decode-time KV cache: K/V are stored as int8 with
per-(head, seq-block) scales, an explicit error bound of scale/2 per
element, and dequantized on the fly inside attention.  For `decode_32k` /
`long_500k` this shrinks the dominant serving memory term 4x (bf16->int8
with fp32 scales amortized over SEQ_BLOCK elements).

For Mamba/hybrid archs the same codec compresses the SSD state (it *is*
the cache there — DESIGN.md §7).
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

SEQ_BLOCK = 128          # scale granularity along the sequence axis
_QMAX = 127.0


class QuantKV(NamedTuple):
    """In-memory quantized-cache format: the `"int8-block"` codec's
    payload as a NamedTuple (the decode-step hot path indexes it
    directly; `kv_quantize`/`kv_dequantize` are the codec's math)."""
    q: jax.Array          # int8, same shape as the source
    scale: jax.Array      # f32, shape = source with seq axis / SEQ_BLOCK


def kv_quantize(x: jax.Array, seq_axis: int) -> QuantKV:
    """Blockwise int8 quantization along `seq_axis` (length must be a
    multiple of SEQ_BLOCK; cache buffers are allocated that way).
    Delegates to the registered `"int8-block"` codec's quantization."""
    from repro.codecs import int8 as I8

    assert x.shape[seq_axis] % SEQ_BLOCK == 0, (x.shape, seq_axis)
    q, scale = I8.block_quantize(x, seq_axis, SEQ_BLOCK)
    return QuantKV(q, scale)


def kv_dequantize(qkv: QuantKV, seq_axis: int, dtype=jnp.bfloat16) -> jax.Array:
    from repro.codecs import int8 as I8

    return I8.block_dequantize(qkv.q, qkv.scale, seq_axis, SEQ_BLOCK, dtype)


def kv_update_block(qkv: QuantKV, new: jax.Array, pos, seq_axis: int) -> QuantKV:
    """Write `new` (one token slot, already sized [..,1,..] on seq_axis)
    into the quantized cache at `pos`.  The owning SEQ_BLOCK's scale is
    monotonically widened (never shrunk) so previously written tokens keep
    their bound."""
    blk = pos // SEQ_BLOCK
    old_scale = jax.lax.dynamic_index_in_dim(qkv.scale, blk, seq_axis,
                                             keepdims=True)
    need = jnp.max(jnp.abs(new)).astype(jnp.float32) / _QMAX
    new_scale = jnp.maximum(old_scale, jnp.maximum(need, 1e-30))
    # requantize the block's existing tokens under the widened scale so
    # their dequantized values are preserved (bound becomes new_scale/2)
    old_blk = jax.lax.dynamic_slice_in_dim(qkv.q, blk * SEQ_BLOCK, SEQ_BLOCK,
                                           seq_axis)
    requant = jnp.clip(jnp.rint(old_blk.astype(jnp.float32)
                                * (old_scale / new_scale)),
                       -_QMAX, _QMAX).astype(jnp.int8)
    q = jax.lax.dynamic_update_slice_in_dim(qkv.q, requant, blk * SEQ_BLOCK,
                                            seq_axis)
    qn = jnp.clip(jnp.rint(new.astype(jnp.float32) / new_scale),
                  -_QMAX, _QMAX).astype(jnp.int8)
    q = jax.lax.dynamic_update_index_in_dim(q, jnp.squeeze(qn, seq_axis),
                                            pos, seq_axis)
    scale = jax.lax.dynamic_update_slice_in_dim(qkv.scale, new_scale, blk,
                                                seq_axis)
    return QuantKV(q, scale)


# ---------------------------------------------------------------------------
# cuSZ offload: evicted / resharded cache blocks go through the full
# dual-quant + Huffman pipeline (host offload, prefill->decode reshard).
# The int8 path above is the in-memory format; the wire/disk one is the
# `"cusz"` codec:
#
#     c = codecs.get("cusz", cfg=cfg).encode(block)   # keeps bf16 dtype
#     block2 = codecs.decode(c)
#
# The entry points below are DEPRECATED shims over that path: they lose
# the source dtype (restore hardcodes the caller's) and need eb/shape fed
# back out-of-band — exactly the bug class the Container header fixes.
# ---------------------------------------------------------------------------

def kv_offload_pack(x: jax.Array, cfg) -> Tuple[dict, float]:
    """DEPRECATED: use `codecs.get("cusz", cfg=cfg).encode(x)`."""
    warnings.warn("kv_offload_pack is deprecated; use "
                  "repro.codecs.get('cusz', cfg=cfg).encode(x) — the "
                  "returned Container records dtype/shape/eb itself",
                  DeprecationWarning, stacklevel=2)
    from repro.core import compressor as CZ

    blob, eb = CZ.compress(jnp.asarray(x, jnp.float32), cfg)
    return CZ.pack_blob(blob), eb


def kv_offload_restore(packed: dict, eb: float, shape, cfg,
                       dtype=jnp.bfloat16) -> jax.Array:
    """DEPRECATED: use `codecs.decode(container)` (dtype comes from the
    container header, not a caller-side default)."""
    warnings.warn("kv_offload_restore is deprecated; use "
                  "repro.codecs.decode(container)",
                  DeprecationWarning, stacklevel=2)
    from repro.core import compressor as CZ

    out = CZ.decompress(CZ.unpack_blob(packed), cfg, eb, tuple(shape))
    return out.astype(dtype)


def error_bound(qkv: QuantKV) -> jax.Array:
    """Per-block abs error bound = scale/2 (the paper's eb semantics)."""
    return qkv.scale / 2.0
