"""Error-bounded KV-cache compression for long-context serving.

PREQUANT applied to the decode-time KV cache: K/V are stored as int8 with
per-(head, seq-block) scales, an explicit error bound of scale/2 per
element, and dequantized on the fly inside attention.  For `decode_32k` /
`long_500k` this shrinks the dominant serving memory term 4x (bf16->int8
with fp32 scales amortized over SEQ_BLOCK elements).

For Mamba/hybrid archs the same codec compresses the SSD state (it *is*
the cache there — DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

SEQ_BLOCK = 128          # scale granularity along the sequence axis
_QMAX = 127.0


class QuantKV(NamedTuple):
    q: jax.Array          # int8, same shape as the source
    scale: jax.Array      # f32, shape = source with seq axis / SEQ_BLOCK


def kv_quantize(x: jax.Array, seq_axis: int) -> QuantKV:
    """Blockwise int8 quantization along `seq_axis` (length must be a
    multiple of SEQ_BLOCK; cache buffers are allocated that way)."""
    s = x.shape[seq_axis]
    assert s % SEQ_BLOCK == 0, (x.shape, seq_axis)
    xb = _split(x, seq_axis)                     # [..., nb, SEQ_BLOCK, ...]
    amax = jnp.max(jnp.abs(xb), axis=seq_axis + 1, keepdims=True)
    scale = jnp.maximum(amax / _QMAX, 1e-30).astype(jnp.float32)
    q = jnp.clip(jnp.rint(xb.astype(jnp.float32) / scale), -_QMAX, _QMAX
                 ).astype(jnp.int8)
    return QuantKV(_merge(q, seq_axis), jnp.squeeze(scale, seq_axis + 1))


def kv_dequantize(qkv: QuantKV, seq_axis: int, dtype=jnp.bfloat16) -> jax.Array:
    qb = _split(qkv.q, seq_axis)
    x = qb.astype(jnp.float32) * jnp.expand_dims(qkv.scale, seq_axis + 1)
    return _merge(x.astype(dtype), seq_axis)


def kv_update_block(qkv: QuantKV, new: jax.Array, pos, seq_axis: int) -> QuantKV:
    """Write `new` (one token slot, already sized [..,1,..] on seq_axis)
    into the quantized cache at `pos`.  The owning SEQ_BLOCK's scale is
    monotonically widened (never shrunk) so previously written tokens keep
    their bound."""
    blk = pos // SEQ_BLOCK
    old_scale = jax.lax.dynamic_index_in_dim(qkv.scale, blk, seq_axis,
                                             keepdims=True)
    need = jnp.max(jnp.abs(new)).astype(jnp.float32) / _QMAX
    new_scale = jnp.maximum(old_scale, jnp.maximum(need, 1e-30))
    # requantize the block's existing tokens under the widened scale so
    # their dequantized values are preserved (bound becomes new_scale/2)
    old_blk = jax.lax.dynamic_slice_in_dim(qkv.q, blk * SEQ_BLOCK, SEQ_BLOCK,
                                           seq_axis)
    requant = jnp.clip(jnp.rint(old_blk.astype(jnp.float32)
                                * (old_scale / new_scale)),
                       -_QMAX, _QMAX).astype(jnp.int8)
    q = jax.lax.dynamic_update_slice_in_dim(qkv.q, requant, blk * SEQ_BLOCK,
                                            seq_axis)
    qn = jnp.clip(jnp.rint(new.astype(jnp.float32) / new_scale),
                  -_QMAX, _QMAX).astype(jnp.int8)
    q = jax.lax.dynamic_update_index_in_dim(q, jnp.squeeze(qn, seq_axis),
                                            pos, seq_axis)
    scale = jax.lax.dynamic_update_slice_in_dim(qkv.scale, new_scale, blk,
                                                seq_axis)
    return QuantKV(q, scale)


# ---------------------------------------------------------------------------
# cuSZ offload codec: evicted / resharded cache blocks go through the full
# dual-quant + Huffman pipeline (host offload, prefill->decode reshard).
# The int8 path above is the in-memory format; this is the wire/disk one.
# Kernel dispatch policy flows through `cfg.kernel_impl`.
# ---------------------------------------------------------------------------

def kv_offload_pack(x: jax.Array, cfg) -> Tuple[dict, float]:
    """Compress a cache block (f32/bf16 tensor) into a packed host blob.

    cfg: a `compressor.CompressorConfig`; returns (packed blob, resolved
    eb).  Restore with `kv_offload_restore` under the same cfg.
    """
    from repro.core import compressor as CZ

    blob, eb = CZ.compress(jnp.asarray(x, jnp.float32), cfg)
    return CZ.pack_blob(blob), eb


def kv_offload_restore(packed: dict, eb: float, shape, cfg,
                       dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of `kv_offload_pack`; returns the block in `dtype`."""
    from repro.core import compressor as CZ

    out = CZ.decompress(CZ.unpack_blob(packed), cfg, eb, tuple(shape))
    return out.astype(dtype)


def error_bound(qkv: QuantKV) -> jax.Array:
    """Per-block abs error bound = scale/2 (the paper's eb semantics)."""
    return qkv.scale / 2.0


def _split(x: jax.Array, seq_axis: int) -> jax.Array:
    s = x.shape[seq_axis]
    shp = x.shape[:seq_axis] + (s // SEQ_BLOCK, SEQ_BLOCK) + x.shape[seq_axis + 1:]
    return x.reshape(shp)


def _merge(xb: jax.Array, seq_axis: int) -> jax.Array:
    shp = xb.shape[:seq_axis] + (xb.shape[seq_axis] * SEQ_BLOCK,) \
        + xb.shape[seq_axis + 2:]
    return xb.reshape(shp)
