"""cuZFP-like fixed-rate block-transform compressor (comparison baseline).

The paper's quality evaluation (Tables 5/8, Figs 6-8) compares cuSZ against
cuZFP in *fixed-rate* mode.  This module re-implements ZFP's pipeline in
JAX so the comparison is reproducible offline:

  4^d blocks -> block exponent alignment -> fixed-point int32 ->
  near-orthogonal lifting transform (per axis; inv∘fwd = identity up to
  low-bit truncation, exactly as in ZFP) -> negabinary ->
  keep top `planes` bit-planes per coefficient (fixed rate) -> inverse.

Simplification vs real cuZFP (documented, DESIGN.md §6): real ZFP uses
embedded group-testing bit-plane coding; here every coefficient keeps the
same number of planes.  This costs the baseline a small constant rate
overhead, so measured cuSZ-vs-baseline ratios are reported alongside the
paper's cuSZ-vs-cuZFP numbers rather than substituted for them.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dualquant import block_split, block_merge, pad_to_blocks, padded_shape

_Q = 30  # fixed-point fraction bits


def _fwd_lift(v: jax.Array, axis: int) -> jax.Array:
    """ZFP forward lifting on a length-4 axis (int arithmetic; the fwd/inv
    pair matches zfp's fwd_lift/inv_lift incl. their low-bit truncation)."""
    x, y, z, w = [jax.lax.index_in_dim(v, i, axis, keepdims=False)
                  for i in range(4)]
    x = x + w; x = x >> 1; w = w - x
    z = z + y; z = z >> 1; y = y - z
    x = x + z; x = x >> 1; z = z - x
    w = w + y; w = w >> 1; y = y - w
    w = w + (y >> 1); y = y - (w >> 1)
    return jnp.stack([x, y, z, w], axis=axis)


def _inv_lift(v: jax.Array, axis: int) -> jax.Array:
    x, y, z, w = [jax.lax.index_in_dim(v, i, axis, keepdims=False)
                  for i in range(4)]
    y = y + (w >> 1); w = w - (y >> 1)
    y = y + w; w = w << 1; w = w - y
    z = z + x; x = x << 1; x = x - z
    y = y + z; z = z << 1; z = z - y
    w = w + x; x = x << 1; x = x - w
    return jnp.stack([x, y, z, w], axis=axis)


def _negabinary(i: jax.Array) -> jax.Array:
    u = i.astype(jnp.uint32)
    mask = jnp.uint32(0xAAAAAAAA)
    return (u + mask) ^ mask


def _inv_negabinary(u: jax.Array) -> jax.Array:
    mask = jnp.uint32(0xAAAAAAAA)
    return ((u ^ mask) - mask).astype(jnp.int32)


@partial(jax.jit, static_argnames=("planes", "nblock"))
def encode_blocks(xb: jax.Array, planes: int, nblock: int):
    """xb: [..., 4,..,4] float32 blocks (block axes = the LAST `nblock`
    axes).  Returns (u, e): the plane-truncated negabinary coefficients
    (uint32, xb.shape) and the per-block exponents (f32, block dims 1).
    This is the storable half; `decode_blocks` is its inverse."""
    baxes = tuple(range(xb.ndim - nblock, xb.ndim))
    # block exponent alignment
    amax = jnp.max(jnp.abs(xb), axis=baxes, keepdims=True)
    e = jnp.where(amax > 0, jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-38))), 0.0)
    scale = jnp.exp2(-e)
    q = jnp.clip(jnp.rint(xb * scale * (1 << _Q)),
                 -(2 ** 31 - 1), 2 ** 31 - 1).astype(jnp.int32)
    for ax in baxes:
        q = _fwd_lift(q, ax)
    u = _negabinary(q)
    # fixed rate: keep top `planes` bit planes of each 32-bit coefficient
    keep = jnp.uint32(0xFFFFFFFF) << jnp.uint32(32 - min(planes, 32)) \
        if planes < 32 else jnp.uint32(0xFFFFFFFF)
    return u & keep, e


@partial(jax.jit, static_argnames=("nblock",))
def decode_blocks(u: jax.Array, e: jax.Array, nblock: int) -> jax.Array:
    baxes = tuple(range(u.ndim - nblock, u.ndim))
    q = _inv_negabinary(u)
    for ax in reversed(baxes):
        q = _inv_lift(q, ax)
    return q.astype(jnp.float32) / (1 << _Q) * jnp.exp2(e)


def _roundtrip_blocks(xb: jax.Array, planes: int) -> jax.Array:
    """xb: [..., 4,4,..] float32 blocks (block axes last ndim)."""
    nd = xb.ndim // 2
    u, e = encode_blocks(xb, planes, nd)
    return decode_blocks(u, e, nd)


def compress_decompress(x: jax.Array, rate_bits: float) -> Tuple[jax.Array, float]:
    """Fixed-rate roundtrip.  Returns (reconstruction, achieved bits/value).

    rate_bits ~= planes kept per coefficient + block header amortization
    (16 bits/block for the exponent+flag, as in ZFP)."""
    nd = min(x.ndim, 3)
    if x.ndim > 3:                      # 4D handled as batched 3D (paper: QMCPACK)
        lead = int(np.prod(x.shape[:-3]))
        flat = x.reshape((lead,) + x.shape[-3:])
        rec = jax.vmap(lambda xi: compress_decompress(xi, rate_bits)[0])(flat)
        planes = max(1, int(round(rate_bits)))
        return rec.reshape(x.shape), planes + 16.0 / 4 ** 3
    block = (4,) * nd
    xb = block_split(pad_to_blocks(x, block), block)
    planes = max(1, int(round(rate_bits)))
    rec = _roundtrip_blocks(xb, planes)
    full = block_merge(rec, block)
    crop = tuple(slice(0, s) for s in x.shape)
    achieved = planes + 16.0 / (4 ** nd)
    return full[crop], achieved
