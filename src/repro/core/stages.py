"""Staged compression pipeline: `Predictor` and `Encoder` stage protocols
with string-keyed registries mirroring `repro.codecs.base`.

The cuSZ pipeline decomposes into two orthogonal stages:

  Predictor  lossy-maps a float field to integer quant codes (plus a
             sparse exact side channel for out-of-cap residuals) and
             reconstructs the field from them within the error bound.
  Encoder    losslessly encodes the quant-code stream to a compact
             payload and decodes it back bit-exactly.

`core.compressor.StagedPipeline` composes one of each under the existing
`CompressorConfig` / dispatch machinery; `CompressorConfig.predictor` /
`.encoder` select the stages by registry id.  Registered stages:

  predictors  "lorenzo"    blocked first-difference (paper §3.1)
              "interp"     multi-level cubic interpolation (cuSZ-i,
                           arXiv 2312.05492) — `core.interp`
  encoders    "huffman"    canonical Huffman + gap-array deflate (§3.2)
              "bitshuffle" bit-plane shuffle + zero-plane elision
                           (FZ-GPU, arXiv 2304.12557) — `core.bitplane`

Stage methods that run inside the jitted pipeline (`predict`,
`reconstruct`, `encode`, `decode`) receive the static
`dispatch.PipelinePolicy` and route every hot kernel through
`repro.kernels.*.ops`; each stage declares its kernel names in
`kernels` so repro-lint R4 can statically tie the stage to its
jax-reference + Pallas registrations.  Host-only methods (`decode_meta`,
`pack_payload`, `unpack_payload`, `stored_nbytes`, `valid`) handle the
jit-boundary readbacks and the storage form.

Payloads are flat dicts of arrays; a predictor's and an encoder's key
sets are disjoint, so the composed pipeline payload is their union.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.deflate import ops as deflate_ops
from repro.kernels.encode import ops as encode_ops
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.inflate import ops as inflate_ops
from repro.kernels.lorenzo import ops as lorenzo_ops

from . import dualquant as dq
from . import huffman as hf

Payload = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Stage protocols
# ---------------------------------------------------------------------------

class Predictor:
    """Lossy prediction stage: float field <-> integer quant codes.

    Implementations are stateless singletons (all per-field knobs ride in
    `CompressorConfig`), hashable by identity, so an instance is a valid
    jit static argument.
    """
    name: str = "abstract"
    #: dispatch kernel names this stage routes through (repro-lint R4
    #: checks each is registered by a kernels/<op>/ops.py)
    kernels: Tuple[str, ...] = ()
    #: payload keys this stage owns (disjoint from any encoder's)
    payload_keys: Tuple[str, ...] = ()

    def n_codes(self, shape: Tuple[int, ...], cfg) -> int:
        """Static quant-code count for a field of `shape` (the encoder
        contract: `predict` emits exactly this many symbols in row-major
        order; `reconstruct` consumes `codes_flat[:n_codes]`)."""
        raise NotImplementedError

    def predict(self, data: jax.Array, cfg, eb: float,
                pp: dispatch.PipelinePolicy) -> Tuple[jax.Array, Payload]:
        """data -> (quant codes, predictor payload).  Traced (inside jit).

        Codes may be any shape with `n_codes` elements; code 0 is the
        OUTLIER sentinel, in-cap codes are >= 1 (`dq.postquant_codes`).
        """
        raise NotImplementedError

    def reconstruct(self, codes_flat: jax.Array, payload: Payload, cfg,
                    eb: float, shape: Tuple[int, ...],
                    pp: dispatch.PipelinePolicy) -> jax.Array:
        """(decoded flat codes [>= n_codes], payload) -> float32 field.
        Traced (inside jit)."""
        raise NotImplementedError

    def header_params(self, shape: Tuple[int, ...], cfg) -> Dict[str, Any]:
        """Decode-side parameters a codec should record in its header."""
        return {}

    def valid(self, payload: Payload) -> bool:
        """Host-side post-encode validity check (e.g. outlier overflow)."""
        return True

    def pack_payload(self, payload: Dict[str, np.ndarray]
                     ) -> Dict[str, np.ndarray]:
        """Device payload (host-fetched) -> compact storage arrays."""
        return dict(payload)

    def unpack_payload(self, packed: Dict[str, np.ndarray], cfg,
                       shape: Tuple[int, ...]) -> Dict[str, np.ndarray]:
        """Inverse of `pack_payload` (dense, decode-ready arrays)."""
        return dict(packed)

    def stored_nbytes(self, packed: Dict[str, np.ndarray]) -> int:
        """Accounted storage bytes of this stage's packed payload."""
        return sum(int(np.asarray(packed[k]).nbytes) for k in packed)


class Encoder:
    """Lossless quant-code encoding stage (same singleton contract)."""
    name: str = "abstract"
    kernels: Tuple[str, ...] = ()
    payload_keys: Tuple[str, ...] = ()

    def encode(self, codes: jax.Array, cfg,
               pp: dispatch.PipelinePolicy) -> Payload:
        """Quant codes (any shape, row-major symbol order) -> payload.
        Traced (inside jit)."""
        raise NotImplementedError

    def decode_meta(self, payload: Payload, cfg
                    ) -> Tuple[Tuple[Any, ...], Any]:
        """Host-side decode preparation, OUTSIDE the jitted decode.

        Returns (static_meta, aux): `static_meta` is a hashable tuple of
        jit-static decode parameters (may require a host readback — e.g.
        Huffman's practical max codeword length); `aux` is a pytree of
        device arrays derived from the payload (e.g. the cached decode
        table).  Both feed `decode`.
        """
        return ((), None)

    def decode(self, payload: Payload, aux: Any,
               static_meta: Tuple[Any, ...], cfg,
               pp: dispatch.PipelinePolicy) -> jax.Array:
        """payload -> flat int32 codes (padded to the encoder's chunk
        granularity; callers slice `[:n_codes]`).  Traced (inside jit)."""
        raise NotImplementedError

    def pack_payload(self, payload: Dict[str, np.ndarray]
                     ) -> Dict[str, np.ndarray]:
        return dict(payload)

    def unpack_payload(self, packed: Dict[str, np.ndarray], cfg,
                       n_sym: int) -> Dict[str, np.ndarray]:
        return dict(packed)

    def stored_nbytes(self, packed: Dict[str, np.ndarray]) -> int:
        return sum(int(np.asarray(packed[k]).nbytes) for k in packed)


# ---------------------------------------------------------------------------
# Registries (mirroring codecs.base: string id -> factory, instantiated
# once — stages are stateless singletons)
# ---------------------------------------------------------------------------

_PREDICTORS: Dict[str, Predictor] = {}
_ENCODERS: Dict[str, Encoder] = {}


def register_predictor(name: str, factory: Callable[[], Predictor]) -> None:
    _PREDICTORS[name] = factory()


def register_encoder(name: str, factory: Callable[[], Encoder]) -> None:
    _ENCODERS[name] = factory()


def get_predictor(name: str) -> Predictor:
    try:
        return _PREDICTORS[name]
    except KeyError:
        raise KeyError(f"unknown predictor {name!r}; registered: "
                       f"{sorted(_PREDICTORS)}") from None


def get_encoder(name: str) -> Encoder:
    try:
        return _ENCODERS[name]
    except KeyError:
        raise KeyError(f"unknown encoder {name!r}; registered: "
                       f"{sorted(_ENCODERS)}") from None


def predictor_names() -> Tuple[str, ...]:
    return tuple(sorted(_PREDICTORS))


def encoder_names() -> Tuple[str, ...]:
    return tuple(sorted(_ENCODERS))


# ---------------------------------------------------------------------------
# Shared shape metadata (formerly compressor._shape_meta)
# ---------------------------------------------------------------------------

def shape_meta(shape: Tuple[int, ...], cfg):
    ndim = len(shape)
    block = cfg.block_for(ndim)
    pshape = dq.padded_shape(shape, block)
    n = int(np.prod(pshape))
    cap = max(16, int(n * cfg.outlier_frac))
    return ndim, block, pshape, n, cap


def outlier_capacity(n: int, cfg) -> int:
    return max(16, int(n * cfg.outlier_frac))


def _outlier_valid(payload: Dict[str, np.ndarray]) -> bool:
    # repro-lint: allow[host-sync] one scalar readback per validity check
    n_out = int(jax.device_get(payload["n_outliers"]))
    return n_out <= int(payload["out_idx"].shape[0])


def _pack_outliers(payload: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Trim the fixed-capacity outlier store to its used prefix."""
    n_out = int(payload["n_outliers"])
    return {
        "out_idx": np.asarray(payload["out_idx"][:n_out], np.int32),
        "out_val": np.asarray(payload["out_val"][:n_out], np.int32),
        "out_capacity": np.int32(payload["out_idx"].shape[0]),
    }


def _unpack_outliers(packed: Dict[str, np.ndarray]
                     ) -> Dict[str, np.ndarray]:
    cap = int(packed["out_capacity"])
    n_out = len(packed["out_idx"])
    # out-of-range fill: the decode-side scatter (mode="drop") ignores it
    oi = np.full((cap,), 2 ** 31 - 1, np.int32)
    ov = np.zeros((cap,), np.int32)
    oi[:n_out] = packed["out_idx"]
    ov[:n_out] = packed["out_val"]
    return {"out_idx": oi, "out_val": ov,
            "n_outliers": np.int32(n_out)}


# ---------------------------------------------------------------------------
# "lorenzo": the paper's blocked first-difference predictor, ported onto
# the protocol bit-identically (same ops, same order, same payload).
# ---------------------------------------------------------------------------

class LorenzoPredictor(Predictor):
    name = "lorenzo"
    kernels = ("lorenzo.dualquant", "lorenzo.reverse")
    payload_keys = ("out_idx", "out_val", "n_outliers")

    def n_codes(self, shape, cfg) -> int:
        return shape_meta(shape, cfg)[3]

    def predict(self, data, cfg, eb, pp):
        ndim, block, pshape, n, cap = shape_meta(data.shape, cfg)
        xb = dq.block_split(dq.pad_to_blocks(data, block), block)
        # fused PREQUANT + ℓ-delta + POSTQUANT: one blocked kernel call
        codes, delta = lorenzo_ops.dualquant_blocks(
            xb, eb, cfg.nbins, **pp.for_kernel("lorenzo.dualquant")
            .as_kwargs())
        # code 0 <=> outlier (in-cap codes are >= 1), so the fused outputs
        # feed outlier extraction directly — no recomputed in_cap tree
        oidx, oval, n_out = dq.extract_outliers(
            delta.reshape(-1), (codes != 0).reshape(-1), cap)
        return codes, {"out_idx": oidx, "out_val": oval, "n_outliers": n_out}

    def reconstruct(self, codes_flat, payload, cfg, eb, shape, pp):
        ndim, block, pshape, n, cap = shape_meta(shape, cfg)
        delta = dq.codes_to_delta(codes_flat[:n], cfg.nbins)
        delta = dq.scatter_outliers(delta, payload["out_idx"],
                                    payload["out_val"])
        nb = tuple(p // b for p, b in zip(pshape, block))
        delta = delta.reshape(nb + tuple(block))
        recon = lorenzo_ops.reverse_blocks(
            delta, eb, **pp.for_kernel("lorenzo.reverse").as_kwargs())
        full = dq.block_merge(recon, block)
        return full[tuple(slice(0, s) for s in shape)]

    def header_params(self, shape, cfg):
        return {"block": tuple(cfg.block_for(len(shape))),
                "outlier_frac": float(cfg.outlier_frac)}

    def valid(self, payload):
        return _outlier_valid(payload)

    def pack_payload(self, payload):
        return _pack_outliers(payload)

    def unpack_payload(self, packed, cfg, shape):
        return _unpack_outliers(packed)

    def stored_nbytes(self, packed):
        # (idx, delta) int32 pairs of the used prefix, as in the paper's
        # sparse outlier accounting
        return len(packed["out_idx"]) * 8


# ---------------------------------------------------------------------------
# "huffman": canonical Huffman + gap-array deflate, ported bit-identically
# (payload keys match CompressedBlob field names so the cusz v2 container
# format is unchanged).
# ---------------------------------------------------------------------------

class HuffmanEncoder(Encoder):
    name = "huffman"
    kernels = ("histogram", "encode", "deflate", "inflate")
    payload_keys = ("words", "bits_used", "n_valid", "lengths", "max_len",
                    "gap_bits", "gap_syms")

    def encode(self, codes, cfg, pp):
        hist = hist_ops.histogram(codes, cfg.nbins,
                                  **pp.for_kernel("histogram").as_kwargs())
        lengths = hf.codeword_lengths(hist)
        cb = hf.canonical_codebook(lengths)
        cw, bw = encode_ops.encode(codes, cb,
                                   **pp.for_kernel("encode").as_kwargs())
        words, bits, gap_bits, gap_syms = deflate_ops.deflate(
            cw, bw, cfg.chunk_size, cfg.sub_size,
            **pp.for_kernel("deflate").as_kwargs())
        nc = words.shape[0]
        n_sym = codes.size
        n_valid = jnp.minimum(
            jnp.full((nc,), cfg.chunk_size, jnp.int32),
            jnp.maximum(n_sym - jnp.arange(nc, dtype=jnp.int32)
                        * cfg.chunk_size, 0))
        return {"words": words, "bits_used": bits, "n_valid": n_valid,
                "lengths": lengths, "max_len": cb.max_len,
                "gap_bits": gap_bits, "gap_syms": gap_syms}

    def decode_meta(self, payload, cfg):
        # repro-lint: allow[host-sync] max_len picks the LUT-vs-bitscan
        # decode variant, a static jit arg; one readback per decode
        max_len = int(jax.device_get(payload["max_len"]))
        # bucket the static max length (8/12/16/32) so decode compiles
        # once per bucket, not once per field's exact max codeword length
        ml_b = hf.bucket_max_len(max(1, max_len))
        # decode tables built OUTSIDE the jitted decode, cached per book
        table = hf.decode_table(payload["lengths"], ml_b)
        return (ml_b,), table

    def decode(self, payload, aux, static_meta, cfg, pp):
        (ml_b,) = static_meta
        gaps = payload.get("gap_bits")
        return inflate_ops.inflate(
            payload["words"], payload["bits_used"], payload["n_valid"],
            aux, ml_b, gaps=gaps,
            **pp.for_kernel("inflate").as_kwargs()).reshape(-1)

    def pack_payload(self, payload):
        bits = np.asarray(payload["bits_used"], dtype=np.int64)
        words = np.asarray(payload["words"])
        chunk_ids, cols = _packed_coords(bits)
        d = {
            "words_packed": words[chunk_ids, cols].astype(np.uint32),
            "bits_used": np.asarray(payload["bits_used"], np.int32),
            "n_valid": np.asarray(payload["n_valid"], np.int32),
            "lengths": np.asarray(payload["lengths"], np.uint8),
            "max_len": np.asarray(payload["max_len"], np.int32),
            "chunk_words": np.int32(words.shape[1]),
        }
        if payload.get("gap_bits") is not None:
            d["gap_bits"] = np.asarray(payload["gap_bits"], np.int32)
            # symbol offsets are < chunk_size; u16 when that fits
            sdt = np.uint16 if words.shape[1] <= (1 << 16) else np.int32
            d["gap_syms"] = np.asarray(payload["gap_syms"]).astype(sdt)
        return d

    def unpack_payload(self, packed, cfg, n_sym):
        bits = np.asarray(packed["bits_used"], np.int64)
        nc = bits.shape[0]
        cw = int(packed["chunk_words"])
        words = np.zeros((nc, cw), np.uint32)
        chunk_ids, cols = _packed_coords(bits)
        words[chunk_ids, cols] = np.asarray(packed["words_packed"],
                                            np.uint32)
        d = {"words": words,
             "bits_used": np.asarray(packed["bits_used"], np.int32),
             "n_valid": np.asarray(packed["n_valid"], np.int32),
             "lengths": np.asarray(packed["lengths"], np.int32),
             "max_len": np.asarray(packed["max_len"], np.int32)}
        if packed.get("gap_bits") is not None:
            d["gap_bits"] = np.asarray(packed["gap_bits"], np.int32)
            d["gap_syms"] = np.asarray(packed["gap_syms"], np.int32)
        return d

    def stored_nbytes(self, packed):
        bits = np.asarray(packed["bits_used"], dtype=np.int64)
        stream = int(np.sum((bits + 31) // 32) * 4)
        book = len(packed["lengths"])          # 1 B bitlength per symbol
        gaps = 0
        if packed.get("gap_bits") is not None:
            gaps = (np.asarray(packed["gap_bits"]).size * 4
                    + np.asarray(packed["gap_syms"]).size * 2)
        return stream + book + gaps


def _packed_coords(bits: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(chunk_id, in-chunk column) of every used word, packed order."""
    nwords = (bits + 31) // 32                       # [nc]
    chunk_ids = np.repeat(np.arange(bits.shape[0]), nwords)
    starts = np.cumsum(nwords) - nwords              # packed offset per chunk
    cols = np.arange(int(nwords.sum())) - np.repeat(starts, nwords)
    return chunk_ids, cols


register_predictor("lorenzo", LorenzoPredictor)
register_encoder("huffman", HuffmanEncoder)

# Populate the rest of the registry: sibling stage modules register on
# import (they import this module for the protocol, so the imports live
# at the bottom — the standard registry-population idiom, mirroring
# codecs/__init__).
from . import interp as _interp          # noqa: E402,F401  (registers "interp")
from . import bitplane as _bitplane      # noqa: E402,F401  (registers "bitshuffle")
