"""Bit-plane shuffle encoder with zero-plane elision (FZ-GPU, arXiv
2304.12557) behind the `Encoder` stage protocol.

Built for the wire-codec throughput class: where Huffman pays for a
histogram, a device codebook build and a scatter-heavy deflate, this
stage is one fused kernel — zigzag-map the quant codes and transpose
each chunk into bit planes — plus a cheap nonzero reduction.  The
device payload stays fixed-shape (dense [nc, P, W] planes + a per-
(chunk, plane) nonzero flag); `pack_payload` drops the all-zero planes
host-side at the storage boundary, which is where the ratio comes from:
near-prediction codes have tiny zigzag values, so high bit planes of
well-predicted chunks vanish.

Decode needs no host prep (no codebook, no max-length readback): the
dense planes invert in one kernel.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.bitshuffle import ops as bitshuffle_ops
from repro.kernels.bitshuffle.ref import nplanes

from . import stages


class BitshuffleEncoder(stages.Encoder):
    name = "bitshuffle"
    kernels = ("bitshuffle.encode", "bitshuffle.decode")
    payload_keys = ("planes", "plane_nz")

    def encode(self, codes, cfg, pp):
        flat = codes.reshape(-1)
        chunk = int(cfg.chunk_size)
        n = flat.shape[0]
        nc = -(-n // chunk)
        pad = nc * chunk - n
        if pad:
            # pad with the zigzag-zero code (= radius): contributes only
            # zero bits, so it never un-elides a plane
            flat = jnp.concatenate(
                [flat, jnp.full((pad,), cfg.nbins // 2, jnp.int32)])
        planes = bitshuffle_ops.encode_planes(
            flat.reshape(nc, chunk), cfg.nbins,
            **pp.for_kernel("bitshuffle.encode").as_kwargs())
        nz = jnp.any(planes != 0, axis=-1).astype(jnp.int32)
        return {"planes": planes, "plane_nz": nz}

    def decode(self, payload, aux, static_meta, cfg, pp):
        codes2 = bitshuffle_ops.decode_planes(
            payload["planes"], cfg.nbins,
            **pp.for_kernel("bitshuffle.decode").as_kwargs())
        return codes2.reshape(-1)

    def pack_payload(self, payload):
        planes = np.asarray(payload["planes"])
        nz = np.asarray(payload["plane_nz"]).astype(bool)
        kept = planes[nz]                       # [K, W] nonzero planes only
        return {
            "planes_packed": kept.reshape(-1).astype(np.uint32),
            "plane_nz": np.packbits(nz.reshape(-1)),
            "n_chunks": np.int32(planes.shape[0]),
            "chunk_words": np.int32(planes.shape[2]),
        }

    def unpack_payload(self, packed, cfg, n_sym):
        nc = int(packed["n_chunks"])
        w = int(packed["chunk_words"])
        p_count = nplanes(int(cfg.nbins))
        nz = np.unpackbits(np.asarray(packed["plane_nz"], np.uint8),
                           count=nc * p_count).astype(bool).reshape(nc,
                                                                    p_count)
        planes = np.zeros((nc, p_count, w), np.uint32)
        planes[nz] = np.asarray(packed["planes_packed"],
                                np.uint32).reshape(-1, w)
        return {"planes": planes, "plane_nz": nz.astype(np.int32)}

    def stored_nbytes(self, packed):
        # kept plane words + the elision bitmap + O(1) shape scalars
        return (np.asarray(packed["planes_packed"]).nbytes
                + np.asarray(packed["plane_nz"]).nbytes + 8)


stages.register_encoder("bitshuffle", BitshuffleEncoder)
