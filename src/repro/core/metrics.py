"""Quality metrics used in the paper's evaluation (§4.2.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmse(a, b) -> jax.Array:
    a = jnp.asarray(a, jnp.float64 if jax.config.x64_enabled else jnp.float32)
    b = jnp.asarray(b, a.dtype)
    return jnp.sqrt(jnp.mean((a - b) ** 2))


def psnr(orig, recon) -> jax.Array:
    """PSNR = 20·log10((max−min)/RMSE)  (paper footnote 6)."""
    rng = jnp.max(orig) - jnp.min(orig)
    r = rmse(orig, recon)
    return 20.0 * jnp.log10(jnp.where(r > 0, rng / r, jnp.inf))


def max_abs_err(orig, recon) -> jax.Array:
    return jnp.max(jnp.abs(jnp.asarray(orig) - jnp.asarray(recon)))


def nrmse(orig, recon) -> jax.Array:
    rng = jnp.max(orig) - jnp.min(orig)
    return rmse(orig, recon) / rng


def bitrate(n_elements: int, compressed_bytes: int) -> float:
    """Bits per element (the x-axis of the paper's rate-distortion plots)."""
    return compressed_bytes * 8.0 / n_elements


def verify_error_bound(orig, recon, eb: float) -> bool:
    """The paper's defining guarantee |d − d•| ≤ eb, up to float32
    representability: the PREQUANT divide and the dequant multiply each
    round once, so the mathematically-exact bound eb widens by
    O(|d|·eps32).  (The paper's fp32 CPU SZ is subject to the same limit;
    DESIGN.md §8.)"""
    # repro-lint: allow[host-sync] verification is host-side by design
    m = float(jax.device_get(max_abs_err(orig, recon)))
    amax = float(jax.device_get(jnp.max(jnp.abs(orig))))  # repro-lint: allow[host-sync] verification is host-side
    eps = float(np.finfo(np.float32).eps)
    return m <= eb * (1.0 + 1e-5) + 4.0 * eps * amax + np.finfo(np.float32).tiny
