"""cuSZ core: dual-quantization + customized canonical Huffman coding,
plus the framework integration surfaces (gradient / KV-cache / checkpoint
compression) and the cuZFP-like comparison baseline.

These modules are the *engines*; the public compression contract is the
`repro.codecs` registry (`codecs.get("cusz").encode/decode` etc.), which
wraps them behind one Codec protocol and a self-describing Container."""
from . import dualquant, huffman, compressor, metrics, zfp_like, gradient, kvcache  # noqa: F401
from .compressor import CompressorConfig, CompressedBlob, compress, decompress, roundtrip  # noqa: F401
