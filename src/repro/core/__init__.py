"""cuSZ core: dual-quantization + customized canonical Huffman coding,
plus the framework integration surfaces (gradient / KV-cache / checkpoint
compression) and the cuZFP-like comparison baseline."""
from . import dualquant, huffman, compressor, metrics, zfp_like, gradient, kvcache  # noqa: F401
from .compressor import CompressorConfig, CompressedBlob, compress, decompress, roundtrip  # noqa: F401
