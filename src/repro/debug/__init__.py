"""Runtime sanitizers: recompile / transfer / host-sync guards."""
from .guards import (GuardError, HostSyncError,  # noqa: F401
                     RecompileError, host_sync_guard, no_implicit_transfers,
                     no_recompiles)

__all__ = ["GuardError", "HostSyncError", "RecompileError",
           "host_sync_guard", "no_implicit_transfers", "no_recompiles"]
