"""Runtime JAX sanitizers as context managers.

Three guards, each wrapping a jax debugging facility into a pass/fail
scope for tests (the static layer is ``tools/lint``; these catch what
static analysis cannot — actual compiles and actual syncs):

* `no_recompiles(max_compiles=N, match=...)` — counts XLA executable
  compilations via ``jax.log_compiles`` while the scope is active and
  raises `RecompileError` when the count exceeds the budget.  Eager ops
  compile tiny helper executables (``jit(convert_element_type)`` …), so
  pass ``match=`` with the jitted function's name to count only the
  executable under test.
* `no_implicit_transfers()` — arms ``jax.transfer_guard``.  On CPU the
  device→host direction is zero-copy and never fires, but implicit
  host→device transfers (e.g. a Python scalar fed to an eager op) DO
  fire even on CPU; on gpu/tpu both directions are guarded.  Prepare
  inputs (``device_put``/``jnp.asarray``) before entering the scope.
* `host_sync_guard(allowed)` — patches ``jax.device_get`` and
  ``jax.block_until_ready`` to attribute each blocking sync to the
  first `repro` source frame on the stack and raises `HostSyncError`
  at scope exit for any site not in `allowed` (the statically waived
  ``allow[host-sync]`` spans, see ``tools.lint.waived_spans``).  This
  is the CPU-meaningful complement to the transfer guard.  Limitation:
  ``float()``/``bool()`` on an array sync inside C code and cannot be
  intercepted here — the static layer covers those.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import os
import re
import traceback
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax

_COMPILE_RE = re.compile(
    r"Finished XLA compilation of (?:jit\()?([\w<>\-.]+)\)? in")
# loggers that carry compile/trace markers across jax versions
_COMPILE_LOGGERS = ("jax._src.dispatch", "jax._src.interpreters.pxla",
                    "jax.dispatch", "jax.interpreters.pxla")


class GuardError(RuntimeError):
    """Base class for sanitizer failures."""


class RecompileError(GuardError):
    pass


class HostSyncError(GuardError):
    pass


# ---------------------------------------------------------------------------
# no_recompiles
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompileLog:
    """Mutable scope state: names of executables compiled so far."""
    compiles: List[str] = dataclasses.field(default_factory=list)

    def count(self) -> int:
        return len(self.compiles)


class _CompileCounter(logging.Handler):
    def __init__(self, log: CompileLog, match: Optional[str]):
        super().__init__(level=logging.DEBUG)
        self._log = log
        self._match = re.compile(match) if match else None

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILE_RE.search(record.getMessage())
        if not m:
            return
        name = m.group(1)
        if self._match is not None and not self._match.search(name):
            return
        self._log.compiles.append(name)


@contextlib.contextmanager
def no_recompiles(max_compiles: int = 1,
                  match: Optional[str] = None) -> Iterator[CompileLog]:
    """Fail if more than `max_compiles` XLA compilations happen in scope.

    The common shapes: warm up a function once, then assert steady state
    with ``no_recompiles(max_compiles=0)``; or cover first use with the
    default budget of 1 (compile once, never again).  `match` restricts
    counting to executables whose name matches the regex — e.g.
    ``match=r"^step$"`` for the serve decode step.
    """
    log = CompileLog()
    handler = _CompileCounter(log, match)
    loggers = [logging.getLogger(n) for n in _COMPILE_LOGGERS]
    old = [(lg.level, lg.propagate) for lg in loggers]
    for lg in loggers:
        lg.addHandler(handler)
        if lg.level > logging.WARNING:
            lg.setLevel(logging.WARNING)
        lg.propagate = False      # count, don't spam test output
    try:
        with jax.log_compiles(True):
            yield log
    finally:
        for lg, (lv, prop) in zip(loggers, old):
            lg.removeHandler(handler)
            lg.setLevel(lv)
            lg.propagate = prop
    if log.count() > max_compiles:
        raise RecompileError(
            f"{log.count()} XLA compilation(s) inside a "
            f"no_recompiles(max_compiles={max_compiles}) scope"
            + (f" (match={match!r})" if match else "")
            + f": {log.compiles}")


# ---------------------------------------------------------------------------
# no_implicit_transfers
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def no_implicit_transfers(level: str = "disallow") -> Iterator[None]:
    """Arm ``jax.transfer_guard(level)`` for the scope.

    Levels: "log", "disallow", "log_explicit", "disallow_explicit".
    NOTE: on CPU-only backends host/device transfers are zero-copy and
    jax never classifies them as guarded transfers, so this is a no-op
    there — pair it with `host_sync_guard` for CPU-meaningful coverage.
    """
    with jax.transfer_guard(level):
        yield


# ---------------------------------------------------------------------------
# host_sync_guard
# ---------------------------------------------------------------------------

#: {absolute file path: [(start_line, end_line, reason), ...]}
AllowedSites = Dict[str, Sequence[Tuple[int, int, str]]]


@dataclasses.dataclass
class SyncLog:
    """Syncs attributed to repro source lines during the scope."""
    violations: List[str] = dataclasses.field(default_factory=list)
    allowed_hits: List[str] = dataclasses.field(default_factory=list)


def _attribute_frame(skip_file: str) -> Optional[Tuple[str, int]]:
    """(abs file, line) of the innermost repro-source frame below us."""
    sep = os.sep
    marker = f"{sep}repro{sep}"
    for frame in reversed(traceback.extract_stack()):
        fn = frame.filename
        if fn == skip_file or f"{sep}debug{sep}guards" in fn:
            continue
        if marker in fn and f"{sep}tests{sep}" not in fn:
            return os.path.abspath(fn), frame.lineno
    return None


@contextlib.contextmanager
def host_sync_guard(allowed: Optional[AllowedSites] = None,
                    *, strict: bool = True) -> Iterator[SyncLog]:
    """Intercept blocking syncs (`jax.device_get`, `jax.block_until_ready`)
    issued from `repro` library code during the scope.

    Syncs from statement spans in `allowed` are recorded as hits; any
    other repro-attributed sync is a violation — raised as
    `HostSyncError` at scope exit when `strict`.  Syncs issued directly
    by test/driver code (no repro frame on the stack) are ignored: the
    guard polices the library, not the harness.
    """
    allowed = allowed or {}
    log = SyncLog()
    real_get, real_block = jax.device_get, jax.block_until_ready
    here = __file__

    def _check(kind: str) -> None:
        site = _attribute_frame(here)
        if site is None:
            return
        path, line = site
        for (lo, hi, reason) in allowed.get(path, ()):
            if lo <= line <= hi:
                log.allowed_hits.append(
                    f"{path}:{line} {kind} [waived: {reason}]")
                return
        log.violations.append(f"{path}:{line} {kind}")

    def guarded_get(x):
        _check("jax.device_get")
        return real_get(x)

    def guarded_block(x):
        _check("jax.block_until_ready")
        return real_block(x)

    jax.device_get, jax.block_until_ready = guarded_get, guarded_block
    try:
        yield log
    finally:
        jax.device_get, jax.block_until_ready = real_get, real_block
    if strict and log.violations:
        raise HostSyncError(
            "unwaived host sync(s) from repro code inside a "
            f"host_sync_guard scope: {log.violations}")
