"""Bounded async write worker for the checkpoint pipeline.

The old ``save_checkpoint(background=True)`` fired a daemon thread that
was never joined and whose exceptions evaporated with the thread — a
failed write silently *lost the checkpoint*.  `AsyncWriter` is the real
version of that idea:

  * one worker thread drains a bounded queue of write closures;
  * ``submit`` blocks when the queue is full — this is the natural
    back-pressure barrier the trainer relies on when the writer falls
    behind the step loop;
  * transient failures (``retryable``, default: `OSError`) are retried
    in the worker with exponential backoff up to ``retries`` times
    before being captured — a flaky-filesystem blip costs latency, not
    the checkpoint;
  * the first exception a task exhausts its retries on is captured and
    re-raised (same exception object) at the next
    ``submit``/``wait``/``close`` call, so a failed checkpoint write
    surfaces in the training loop instead of vanishing;
  * ``wait(timeout=)`` joins every pending task (the pre-shutdown /
    pre-restore barrier), raising `TimeoutError` if the writer is stuck.

Thread-safety note: tasks run JAX host transfers (``device_get``) and
numpy I/O; both are safe off the main thread, and the single worker
serializes writes so shard files of step N never interleave with step
N+1.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from typing import Any, Callable, Optional

_SENTINEL = object()


def _default_retryable(e: BaseException) -> bool:
    """Transient-by-default classification: I/O layer errors (including
    `dist.chaos.TransientWriteError`, an OSError) retry; everything else
    — bugs, assertion failures, encode errors — fails fast."""
    return isinstance(e, OSError)


class AsyncWriter:
    """One worker thread + bounded task queue with retry and re-raise."""

    def __init__(self, max_pending: int = 2, name: str = "ckpt-writer",
                 retries: int = 0, backoff_s: float = 0.01,
                 retryable: Callable[[BaseException], bool]
                 = _default_retryable):
        assert max_pending >= 1, max_pending
        assert retries >= 0, retries
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=max_pending)
        self._err: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        self._closed = False
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.retryable = retryable
        self.n_retries = 0          # telemetry: total retry attempts made
        self._thread = threading.Thread(target=self._worker, name=name,
                                        daemon=True)
        self._thread.start()

    # -- public API ---------------------------------------------------------

    def submit(self, fn: Callable[..., Any], *args, **kwargs) -> None:
        """Enqueue ``fn(*args, **kwargs)``; blocks while the queue is full
        (the writer-fell-behind barrier).  Raises any pending error from
        an earlier task before accepting new work."""
        self._raise_pending()
        if self._closed:
            raise RuntimeError("AsyncWriter is closed")
        self._q.put((fn, args, kwargs))

    def wait(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted task has finished, then re-raise
        the first captured task exception, if any.  With ``timeout`` (in
        seconds), raise `TimeoutError` if tasks are still pending when it
        expires — the stuck-writer escape hatch for shutdown paths."""
        if timeout is None:
            self._q.join()
        else:
            # Queue.join() has no timeout; wait on the same condition it
            # uses, bounded by a deadline.
            deadline = time.monotonic() + timeout
            with self._q.all_tasks_done:
                while self._q.unfinished_tasks:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"AsyncWriter.wait: {self._q.unfinished_tasks} "
                            f"task(s) still pending after {timeout}s")
                    self._q.all_tasks_done.wait(remaining)
        self._raise_pending()

    # legacy spelling: the old API returned a Thread with .join()
    join = wait

    def close(self) -> None:
        """Drain, stop the worker thread, and surface any pending error
        — including one captured *after* the final submit, which a caller
        that never reaches ``wait`` would otherwise lose."""
        if not self._closed:
            self._closed = True
            self._q.put(_SENTINEL)
            self._thread.join()
        self._raise_pending()

    @property
    def pending_error(self) -> Optional[BaseException]:
        """The captured-but-not-yet-re-raised task exception, if any."""
        return self._err

    def __enter__(self) -> "AsyncWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # don't mask an in-flight exception with a writer error — but
        # don't silently drop it either: it stays in `pending_error` and
        # is announced as a warning alongside the propagating exception
        if exc_type is None:
            self.close()
        else:
            self._closed = True
            self._q.put(_SENTINEL)
            self._thread.join()
            if self._err is not None:
                warnings.warn(
                    f"AsyncWriter: a write task also failed "
                    f"({self._err!r}); it is masked by the in-flight "
                    f"{exc_type.__name__} and kept in .pending_error",
                    RuntimeWarning, stacklevel=2)

    # -- internals ----------------------------------------------------------

    def _raise_pending(self) -> None:
        with self._err_lock:
            err, self._err = self._err, None
        if err is not None:
            raise err

    def _run_task(self, fn, args, kwargs) -> None:
        for attempt in range(self.retries + 1):
            try:
                fn(*args, **kwargs)
                return
            except BaseException as e:             # noqa: BLE001
                if attempt < self.retries and self.retryable(e):
                    self.n_retries += 1
                    time.sleep(self.backoff_s * (2 ** attempt))
                    continue
                with self._err_lock:
                    if self._err is None:          # keep the first failure
                        self._err = e
                return

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _SENTINEL:
                    return
                fn, args, kwargs = item
                self._run_task(fn, args, kwargs)
            finally:
                self._q.task_done()
