"""Bounded async write worker for the checkpoint pipeline.

The old ``save_checkpoint(background=True)`` fired a daemon thread that
was never joined and whose exceptions evaporated with the thread — a
failed write silently *lost the checkpoint*.  `AsyncWriter` is the real
version of that idea:

  * one worker thread drains a bounded queue of write closures;
  * ``submit`` blocks when the queue is full — this is the natural
    back-pressure barrier the trainer relies on when the writer falls
    behind the step loop;
  * the first exception a task raises is captured and re-raised (same
    exception object) at the next ``submit``/``wait``/``close`` call, so
    a failed checkpoint write surfaces in the training loop instead of
    vanishing;
  * ``wait`` joins every pending task (the pre-shutdown / pre-restore
    barrier).

Thread-safety note: tasks run JAX host transfers (``device_get``) and
numpy I/O; both are safe off the main thread, and the single worker
serializes writes so shard files of step N never interleave with step
N+1.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional

_SENTINEL = object()


class AsyncWriter:
    """One worker thread + bounded task queue with exception re-raise."""

    def __init__(self, max_pending: int = 2, name: str = "ckpt-writer"):
        assert max_pending >= 1, max_pending
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=max_pending)
        self._err: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        self._closed = False
        self._thread = threading.Thread(target=self._worker, name=name,
                                        daemon=True)
        self._thread.start()

    # -- public API ---------------------------------------------------------

    def submit(self, fn: Callable[..., Any], *args, **kwargs) -> None:
        """Enqueue ``fn(*args, **kwargs)``; blocks while the queue is full
        (the writer-fell-behind barrier).  Raises any pending error from
        an earlier task before accepting new work."""
        self._raise_pending()
        if self._closed:
            raise RuntimeError("AsyncWriter is closed")
        self._q.put((fn, args, kwargs))

    def wait(self) -> None:
        """Block until every submitted task has finished, then re-raise
        the first captured task exception, if any."""
        self._q.join()
        self._raise_pending()

    # legacy spelling: the old API returned a Thread with .join()
    join = wait

    def close(self) -> None:
        """Drain, stop the worker thread, and surface any pending error."""
        if not self._closed:
            self._closed = True
            self._q.put(_SENTINEL)
            self._thread.join()
        self._raise_pending()

    @property
    def pending_error(self) -> Optional[BaseException]:
        """The captured-but-not-yet-re-raised task exception, if any."""
        return self._err

    def __enter__(self) -> "AsyncWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # don't mask an in-flight exception with a writer error
        if exc_type is None:
            self.close()
        else:
            self._closed = True
            self._q.put(_SENTINEL)
            self._thread.join()

    # -- internals ----------------------------------------------------------

    def _raise_pending(self) -> None:
        with self._err_lock:
            err, self._err = self._err, None
        if err is not None:
            raise err

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _SENTINEL:
                    return
                fn, args, kwargs = item
                try:
                    fn(*args, **kwargs)
                except BaseException as e:          # noqa: BLE001
                    with self._err_lock:
                        if self._err is None:       # keep the first failure
                            self._err = e
            finally:
                self._q.task_done()
