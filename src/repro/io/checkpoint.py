"""Async, per-host-sharded, crash-safe checkpointing on `repro.codecs`.

Saving is a two-phase pipeline:

  1. **encode** (caller thread, on-device): every leaf goes through the
     codec its `CheckpointPolicy` selects.  Split-stable codecs
     (lossless / int8 / int16 / int8-block — see `Codec.shard_axis`)
     split large leaves into one slice per host shard and encode each
     slice so it decodes bit-identically to a whole-tensor encode;
     chunked-transform codecs (cusz, zfp) keep the leaf whole and assign
     it to the least-loaded owner shard.  Nothing gathers a replicated
     full array to host: what leaves the device is the encoded payload,
     and only in the write phase.
  2. **write** (optionally async via `io.async_writer.AsyncWriter`):
     pack each container to its storage form, stream one
     ``shard_<host>.npz`` per shard, write ``manifest.json`` *last*, and
     commit atomically by renaming the temp dir over the final name —
     an interrupted save can never shadow the last complete checkpoint.

The manifest (format 3) records, per tensor, the codec id/version, the
split axis, and each shard part's self-describing container header — so
`load_checkpoint` reassembles from **any** host count (elastic restore):
parts are concatenated in payload space when the codec supports it
(`Codec.payload_axes`), and the decode runs jitted on-device with the
*new* mesh's shardings — the bytes moved host->device are the stored
compressed containers, not decoded f32 (the s8/huffman-on-the-wire
trick, restore leg).  Arming `dist.context.use_restore_compress`
additionally re-encodes raw (lossless-stored) float leaves over the
int8-block wire codec for that move (lossy, eb = scale/2, off by
default).  Manifest format 2 (single ``arrays.npz``) stays loadable
behind a format gate.

Async semantics: pass ``writer=AsyncWriter(...)`` (or the legacy
``background=True``, which uses a module-default writer).  ``submit``
blocks when the writer falls behind (bounded queue), and write failures
re-raise at the next save / ``writer.wait()`` — never silently lost.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import _compat, codecs
from repro.io.async_writer import AsyncWriter

CUSZ_MIN_SIZE = 4096
MANIFEST_FORMAT = 3
WIRE_BLOCK = 128                 # restore-leg int8-block wire granularity
_SEP = "::"
_FIELD_MARK = "__c__"
_SHARD_FMT = "shard_{:05d}.npz"
# codecs whose decode is jit-safe from the outside: the elastic restore
# runs them on device with the target sharding as out_shardings.  cusz
# reads max_len concretely (decompress jits internally, around that
# host value) and zfp's block merge/pad helpers are host-side, so both
# decode on host before placement.
_JIT_DECODE = frozenset({"lossless", "int8", "int16", "int8-block"})

#: telemetry of the most recent `load_checkpoint` call: step, manifest
#: format, saved shard count, the restore-leg wire accounting (bytes
#: that moved host->device in container form vs. raw size), and — when
#: corrupted steps were skipped — a ``quarantine`` list of structured
#: per-step corruption reports.
LAST_RESTORE_STATS: Dict[str, Any] = {}

_QUARANTINE_MARK = "QUARANTINE.json"


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint step failed integrity verification (bad zip, payload
    checksum mismatch, missing/garbled manifest).  Carries the structured
    per-step ``reports`` that restore accumulated before giving up."""

    def __init__(self, msg: str, reports: List[Dict[str, Any]]):
        super().__init__(msg)
        self.reports = reports


#: error classes that mean "these bytes are damaged", as opposed to
#: "this checkpoint is from an incompatible writer" (format-gate
#: ValueErrors, which must propagate, not quarantine).
_CORRUPTION_ERRORS = (codecs.ChecksumError, zipfile.BadZipFile, zlib.error,
                      OSError, EOFError, KeyError,
                      json.JSONDecodeError)

_default_writer: Optional[AsyncWriter] = None


def default_writer() -> AsyncWriter:
    """The module-level writer `background=True` saves go through."""
    global _default_writer
    if _default_writer is None:
        _default_writer = AsyncWriter(max_pending=2)
    return _default_writer


def wait_for_writes() -> None:
    """Barrier on the default background writer; re-raises any captured
    write failure (the fix for the old fire-and-forget thread that
    swallowed exceptions and lost checkpoints)."""
    if _default_writer is not None:
        _default_writer.wait()


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Per-leaf codec selection from one config.

    `codec` applies to every eligible float leaf; `rules` overrides by
    key substring (first match wins, value is a registry name — use
    "lossless" to exempt a subtree).  Ineligible leaves (non-float,
    small, non-finite, zero-range) always store lossless.
    """
    codec: str = "lossless"                      # codec for eligible leaves
    eb_valrel: float = 1e-5                      # cusz-family valrel bound
    min_size: int = CUSZ_MIN_SIZE                # lossy-eligibility floor
    kernel_impl: Optional[str] = None            # cusz dispatch policy
    rules: Tuple[Tuple[str, str], ...] = ()      # (key substring, codec id)

    def codec_for(self, key: str, arr) -> str:
        name = self.codec
        for sub, override in self.rules:
            if sub in key:
                name = override
                break
        if name == "lossless" or not self._eligible(arr):
            return "lossless"
        return name

    def make_codec(self, name: str) -> codecs.Codec:
        if name in ("cusz", "cusz-i", "fz"):
            # the staged family shares the valrel bound discipline; the
            # new-stage codecs get full outlier capacity — packed storage
            # prices only the used prefix, and interp's residual tail
            # overflows the default capacity at tight bounds
            extra = {} if name == "cusz" else {"outlier_frac": 1.0}
            return codecs.get(name, eb=self.eb_valrel, eb_mode="valrel",
                              use_tpu_blocks=True,
                              kernel_impl=self.kernel_impl, **extra)
        return codecs.get(name)

    def _eligible(self, arr) -> bool:
        try:
            floating = jnp.issubdtype(arr.dtype, jnp.floating)
        except TypeError:
            floating = False
        if not floating or arr.size < self.min_size:
            return False
        if isinstance(arr, jax.Array):
            # one jitted reduction; only the bool scalar crosses to host
            # (the old form np.asarray'd the full leaf)
            f = arr.astype(jnp.float32)
            ok = jnp.all(jnp.isfinite(f)) & (jnp.max(f) - jnp.min(f) > 0)
            # repro-lint: allow[host-sync] one bool scalar gates the
            # compress-vs-raw decision; unavoidable host branch
            return bool(ok)
        f = np.asarray(arr, np.float32) if arr.dtype != np.float32 else arr
        return bool(np.all(np.isfinite(f))
                    and float(np.max(f) - np.min(f)) > 0)


def _flatten(tree) -> Dict[str, Any]:
    """key -> leaf, keeping device arrays on device (no host gather)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = leaf if isinstance(leaf, jax.Array) else jnp.asarray(leaf)
    return flat


def _legacy_policy(mode, eb_valrel, kernel_impl) -> CheckpointPolicy:
    _compat.warn_once(
        "save_checkpoint-mode",
        "save_checkpoint(mode=..., eb_valrel=..., kernel_impl=...) is "
        "deprecated; pass policy=CheckpointPolicy(codec=..., "
        "eb_valrel=..., kernel_impl=...) instead",
        stacklevel=4)
    return CheckpointPolicy(
        codec="cusz" if mode == "cusz" else "lossless",
        eb_valrel=1e-5 if eb_valrel is None else eb_valrel,
        kernel_impl=kernel_impl)


# ---------------------------------------------------------------------------
# Phase 1: encode + shard planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _LeafPlan:
    key: str
    codec: str                       # final codec id (post-fallback)
    version: int
    axis: Optional[int]              # split axis, None = owner-assigned
    parts: List[codecs.Container]    # device-form, one per shard slot
    shards: List[int]                # host shard id per part
    raw_nbytes: int


def _stored_size_estimate(codec: codecs.Codec, parts) -> int:
    """Storage bytes without packing: shape metadata plus (for the staged
    family) the per-chunk word counts, kept-plane counts and outlier
    count — scalar-sized host syncs, never a payload gather."""
    if codec.name in ("cusz", "cusz-i"):
        from repro.core import compressor as CZ
        total = 0
        for p in parts:
            blob = CZ.CompressedBlob(**{f: p.payload.get(f)
                                        for f in CZ.CompressedBlob._fields})
            total += CZ.compressed_bytes(blob, int(p.header.param("nbins")))
        return total
    if codec.name == "fz":
        # zero-plane elision happens at pack time: count the kept planes
        # (one scalar sync) instead of the dense device form
        total = 0
        for p in parts:
            # repro-lint: allow[host-sync] two scalar reductions per leaf
            kept = int(jax.device_get(jnp.sum(p.payload["plane_nz"])))
            n_out = int(jax.device_get(p.payload["n_outliers"]))  # repro-lint: allow[host-sync] scalar readback for the size estimate
            nwords = int(p.payload["planes"].shape[2])
            bitmap = (int(p.payload["plane_nz"].size) + 7) // 8
            total += kept * nwords * 4 + bitmap + n_out * 8 + 8
        return total
    return sum(codec.stored_nbytes(p) if codec.name == "zfp"
               else sum(np.dtype(v.dtype).itemsize * v.size
                        for v in p.payload.values())
               for p in parts)


def _encode_tree(flat: Dict[str, Any], policy: CheckpointPolicy,
                 nshards: int, snapshot: bool) -> List[_LeafPlan]:
    """Run every leaf's codec on device and plan shard placement.

    `snapshot` (async mode): identity-encoded payloads that alias the
    live leaf buffer are copied, so donation/mutation of the train state
    during the overlapped write cannot corrupt the checkpoint.
    """
    codec_cache: Dict[str, codecs.Codec] = {"lossless": codecs.get("lossless")}
    plans: List[_LeafPlan] = []
    owner_load = [0] * nshards

    def lossless_parts(leaf, axis):
        codec = codec_cache["lossless"]
        if axis is None or nshards == 1:
            axis = codec.shard_axis(leaf.shape, nshards)
        if axis is None:
            return None, [codec.encode(leaf)]
        return axis, codec.encode_parts(leaf, axis, nshards)

    # pass A: dispatch every encode (device work pipelines across leaves)
    staged = []
    for key, leaf in flat.items():
        name = policy.codec_for(key, leaf)
        if name not in codec_cache:
            codec_cache[name] = policy.make_codec(name)
        codec = codec_cache[name]
        axis = codec.shard_axis(leaf.shape, nshards) if nshards > 1 else None
        try:
            if axis is not None:
                parts = codec.encode_parts(leaf, axis, nshards)
            else:
                parts = [codec.encode(leaf)]
        except (ValueError, AssertionError):
            # codec cannot represent the leaf (eb below f32 resolution,
            # block-misaligned dims): store raw
            name, codec = "lossless", codec_cache["lossless"]
            axis, parts = lossless_parts(leaf, None)
        staged.append((key, leaf, name, axis, parts))

    # pass B: validity + does-it-win decisions (scalar-sized syncs only),
    # falling back to lossless so the codec never expands a checkpoint
    for key, leaf, name, axis, parts in staged:
        raw = int(leaf.size) * np.dtype(leaf.dtype).itemsize
        codec = codec_cache[name]
        if name != "lossless":
            ok = all(codec.valid(p) for p in parts)
            if not ok or _stored_size_estimate(codec, parts) >= raw:
                name, codec = "lossless", codec_cache["lossless"]
                axis, parts = lossless_parts(leaf, axis)
        if snapshot and name == "lossless":
            parts = [p.replace(payload={
                k: (jnp.copy(v) if v is leaf else v)
                for k, v in p.payload.items()}) for p in parts]
        if axis is not None:
            shards = list(range(nshards))
        else:                         # owner shard: least-loaded so far
            h = int(np.argmin(owner_load)) if nshards > 1 else 0
            shards = [h]
            owner_load[h] += raw
        plans.append(_LeafPlan(key, name, codec.version, axis, parts,
                               shards, raw))
    return plans


# ---------------------------------------------------------------------------
# Phase 2: pack + shard files + manifest + atomic commit
# ---------------------------------------------------------------------------

def _write_shard(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """One host's shard file.  Module-level so crash-consistency tests
    can inject failures mid-save.  Consults the ambient chaos monkey
    (`dist.chaos`): armed write faults raise here (retried/ surfaced by
    the writer) or silently damage the file after the write (caught by
    container checksums at restore)."""
    from repro.dist import chaos
    monkey = chaos.current()
    if monkey is not None:
        monkey.pre_write(path)
    np.savez(path, **arrays)
    if monkey is not None:
        # np.savez appends .npz when the target has no extension
        monkey.post_write(path if os.path.exists(path) else path + ".npz")


def _write_step(ckpt_dir: str, step: int, plans: Sequence[_LeafPlan],
                policy_codec: str, nshards: int) -> str:
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    shutil.rmtree(tmp, ignore_errors=True)       # stale crashed attempt
    os.makedirs(tmp, exist_ok=True)
    codec_cache: Dict[str, codecs.Codec] = {}
    shard_arrays: List[Dict[str, np.ndarray]] = [{} for _ in range(nshards)]
    manifest: Dict[str, Any] = {"step": step, "format": MANIFEST_FORMAT,
                                "nshards": nshards, "policy": policy_codec,
                                "tensors": {}}
    for plan in plans:
        if plan.codec not in codec_cache:
            codec_cache[plan.codec] = codecs.get(plan.codec)
        codec = codec_cache[plan.codec]
        entry: Dict[str, Any] = {"codec": plan.codec, "version": plan.version,
                                 "axis": plan.axis, "shards": []}
        stored = 0
        for i, (part, h) in enumerate(zip(plan.parts, plan.shards)):
            header, fields = codecs.to_arrays(codec.pack(part))
            stored += sum(v.nbytes for v in fields.values())
            for f, v in fields.items():
                shard_arrays[h][_SEP.join((plan.key, _FIELD_MARK,
                                           str(i), f))] = v
            entry["shards"].append({"shard": h, "header": header})
        if plan.codec != "lossless":
            entry["ratio"] = plan.raw_nbytes / max(1, stored)
        manifest["tensors"][plan.key] = entry
    for h in range(nshards):
        _write_shard(os.path.join(tmp, _SHARD_FMT.format(h)),
                     shard_arrays[h])
    # manifest last: its presence marks the step complete inside the tmp
    # dir; the rename below makes completeness atomic from the outside
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_checkpoint(ckpt_dir: str, step: int, tree, mode: Optional[str] = None,
                    eb_valrel: Optional[float] = None,
                    background: bool = False,
                    kernel_impl: Optional[str] = None,
                    policy: Optional[CheckpointPolicy] = None,
                    nshards: Optional[int] = None,
                    writer: Optional[AsyncWriter] = None):
    """Write `tree` under `ckpt_dir/step_<step>` via the codec registry.

    `policy` selects codecs per leaf.  `nshards` splits the write into
    per-host shard files (default: `jax.process_count()`).  `writer`
    makes the write phase asynchronous: the call returns after the
    on-device encode, the file I/O runs on the writer thread, and errors
    re-raise at the next `submit`/`wait`.  `background=True` is the
    legacy spelling (module-default writer).  Returns the final step dir
    (sync) or the writer (async).  The legacy `mode=`/`eb_valrel=`/
    `kernel_impl=` kwargs still work behind a DeprecationWarning."""
    if policy is None:
        if mode is not None or eb_valrel is not None \
                or kernel_impl is not None:
            policy = _legacy_policy(mode, eb_valrel, kernel_impl)
        else:
            policy = CheckpointPolicy()
    if writer is None and background:
        writer = default_writer()
    if nshards is None:
        nshards = max(1, jax.process_count())
    os.makedirs(ckpt_dir, exist_ok=True)
    plans = _encode_tree(_flatten(tree), policy, int(nshards),
                         snapshot=writer is not None)
    if writer is not None:
        writer.submit(_write_step, ckpt_dir, step, plans, policy.codec,
                      int(nshards))
        return writer
    return _write_step(ckpt_dir, step, plans, policy.codec, int(nshards))


def available_steps(ckpt_dir: str) -> List[int]:
    """Complete, non-quarantined steps, ascending.  In-flight
    ``.tmp_step_*`` dirs and steps carrying a ``QUARANTINE.json`` marker
    (written when restore hit corruption there) are excluded."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_"):
            continue
        if os.path.exists(os.path.join(ckpt_dir, name, _QUARANTINE_MARK)):
            continue
        steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest complete, non-quarantined step."""
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def _mark_quarantined(step_dir: str, report: Dict[str, Any]) -> None:
    """Drop the quarantine marker (best-effort: a read-only checkpoint
    store still falls back correctly, it just re-detects next time)."""
    try:
        with open(os.path.join(step_dir, _QUARANTINE_MARK), "w") as f:
            json.dump(report, f, indent=2)
    except OSError:
        pass


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------

def _leaf_key(path) -> str:
    return _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)


def _container_fields(arrays, prefix: str) -> Dict[str, np.ndarray]:
    return {k[len(prefix):]: arrays[k] for k in arrays.files
            if k.startswith(prefix)}


def _assemble_v3(d: str, key: str, entry, shard_files, verify: bool):
    """Read a tensor's shard parts and merge them into one container, or
    (when the codec has no payload-space concat) a decoded host array.
    With ``verify`` each part's payload is checked against its header
    crc32 *before* merge/decode — corruption surfaces as `ChecksumError`
    at the damaged part, not as garbage weights."""
    parts = []
    for i, sh in enumerate(entry["shards"]):
        arrays = shard_files(int(sh["shard"]))
        prefix = _SEP.join((key, _FIELD_MARK, str(i), ""))
        part = codecs.from_arrays(sh["header"],
                                  _container_fields(arrays, prefix))
        if verify:
            codecs.check_container(part)
        parts.append(part)
    if len(parts) == 1:
        return parts[0]
    codec = codecs.get(entry["codec"])
    axes = codec.payload_axes(int(entry["axis"]))
    if axes is not None:
        return codecs.concat_containers(parts, int(entry["axis"]), axes)
    # repro-lint: allow[host-sync] value-space fallback merge is host-side
    vals = [np.asarray(jax.device_get(codecs.decode(p))) for p in parts]
    return np.concatenate(vals, axis=int(entry["axis"]))


def _lossless_host_view(c: codecs.Container) -> np.ndarray:
    """The raw values of a packed lossless container, staying on host
    (no device round-trip; undoes the bf16 storage bitcast)."""
    arr = np.asarray(c.payload["data"])
    want = np.dtype(c.header.dtype)
    if arr.dtype != want and arr.dtype.itemsize == want.itemsize:
        arr = arr.view(want)
    return arr.reshape(c.header.shape)


def _wire_recode(raw: np.ndarray, wire_name: str):
    """Re-encode a raw leaf over the blockwise wire codec for the
    host->device reshard move (the armed `use_restore_compress` leg).
    Quantizes with host numpy — the whole point is that only q + scales
    ever cross to the device — producing the exact payload/header layout
    the registry codec decodes.  Returns (codec, container, n_valid);
    decode slices the edge padding off."""
    wire = codecs.get_block_codec(wire_name, axis=0, block=WIRE_BLOCK)
    flat = np.asarray(raw, np.float32).reshape(-1)
    pad = (-flat.size) % WIRE_BLOCK
    if pad:
        flat = np.pad(flat, (0, pad), mode="edge")
    xb = flat.reshape(-1, WIRE_BLOCK)
    scale = np.maximum(np.abs(xb).max(axis=1, keepdims=True) / 127.0,
                       1e-30).astype(np.float32)
    q = np.clip(np.rint(xb / scale), -127, 127).astype(np.int8)
    cont = codecs.Container(
        codecs.make_header(wire.name, wire.version, flat,
                           axis=0, block=WIRE_BLOCK),
        {"q": q.reshape(-1), "scale": scale.reshape(-1)})
    return wire, cont, flat.size - pad


# jitted-decode cache: one compile per (codec, target shape/dtype,
# placement) signature instead of one per leaf per load call
_decode_fn_cache: Dict[Any, Any] = {}


def _jitted_decode(codec: codecs.Codec, like, shd, postslice: int = 0):
    key = (codec, tuple(like.shape), np.dtype(like.dtype).str, shd,
           postslice)
    if key not in _decode_fn_cache:
        if postslice:
            def fn(c):
                return codec.decode(c)[:postslice].reshape(
                    tuple(like.shape)).astype(like.dtype)
        else:
            def fn(c):
                return codec.decode(c, like=like)
        _decode_fn_cache[key] = (jax.jit(fn, out_shardings=shd)
                                 if shd is not None else jax.jit(fn))
    return _decode_fn_cache[key]


def _load_step(d: str, step: int, template, shardings,
               kernel_impl: Optional[str], verify: bool):
    """Load one specific step dir; returns ``(tree, stats)``.  Raises one
    of `_CORRUPTION_ERRORS` when the bytes are damaged (the caller's
    quarantine loop handles those) or ValueError for format-gate
    mismatches (which must propagate)."""
    from repro.dist import context as dist_ctx

    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    fmt = manifest.get("format", 1)
    if fmt == 1:
        raise ValueError(
            f"checkpoint {d} uses manifest format 1, which predates the "
            f"repro.codecs API — re-save from a checkout that wrote it.")
    if fmt not in (2, MANIFEST_FORMAT):
        raise ValueError(
            f"checkpoint {d} uses manifest format {fmt}; this reader "
            f"supports formats 2 (single-file containers) and "
            f"{MANIFEST_FORMAT} (sharded containers).")

    file_cache: Dict[Any, Any] = {}

    def shard_files(h: int):
        if h not in file_cache:
            file_cache[h] = np.load(os.path.join(d, _SHARD_FMT.format(h)))
        return file_cache[h]

    def v2_arrays():
        if "v2" not in file_cache:
            file_cache["v2"] = np.load(os.path.join(d, "arrays.npz"))
        return file_cache["v2"]

    stats = {"step": step, "format": fmt,
             "saved_nshards": int(manifest.get("nshards", 1)),
             "leaves": 0, "wire_leaves": 0, "recoded_leaves": 0,
             "wire_bytes": 0, "raw_bytes": 0}
    wire_name = dist_ctx.restore_codec()

    def assemble(key, entry):
        if fmt == 2:
            prefix = _SEP.join((key, _FIELD_MARK, ""))
            cont = codecs.from_arrays(
                entry["header"], _container_fields(v2_arrays(), prefix))
            if verify:
                codecs.check_container(cont)
            return cont
        return _assemble_v3(d, key, entry, shard_files, verify)

    def place(key, entry, leaf, shd):
        got = assemble(key, entry)
        name = entry["codec"]
        like = jax.ShapeDtypeStruct(tuple(leaf.shape), leaf.dtype)
        stats["leaves"] += 1
        stats["raw_bytes"] += int(leaf.size) * np.dtype(leaf.dtype).itemsize
        kw = {"kernel_impl": kernel_impl} \
            if name == "cusz" and kernel_impl is not None else {}
        if isinstance(got, codecs.Container):
            # optional restore-leg wire compression of raw float leaves:
            # quantized on host, so only q + scales cross to the device
            if (wire_name is not None and name == "lossless"
                    and jnp.issubdtype(np.dtype(got.header.dtype),
                                       jnp.floating)
                    and got.header.shape
                    and int(np.prod(got.header.shape)) >= CUSZ_MIN_SIZE):
                wire, cont, n = _wire_recode(_lossless_host_view(got),
                                             wire_name)
                stats["recoded_leaves"] += 1
                stats["wire_leaves"] += 1
                stats["wire_bytes"] += cont.nbytes
                return _jitted_decode(wire, like, shd, postslice=n)(cont)
            if name in _JIT_DECODE and shd is not None:
                codec = codecs.get(name, **kw)
                cont = codec.unpack(got)
                stats["wire_leaves"] += 1
                stats["wire_bytes"] += sum(
                    int(v.size) * np.dtype(v.dtype).itemsize
                    for v in got.payload.values())
                return _jitted_decode(codec, like, shd)(cont)
            # repro-lint: allow[host-sync] legacy non-wire restore decodes
            # on host before placement
            got = np.asarray(jax.device_get(codecs.decode(got, **kw)))
        arr = got.astype(leaf.dtype).reshape(leaf.shape)
        return (jax.device_put(arr, shd) if shd is not None
                else jnp.asarray(arr))

    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None
                    else [None] * len(leaves_with_path))
    out = []
    for (path, leaf), shd in zip(leaves_with_path, shard_leaves):
        key = _leaf_key(path)
        out.append(place(key, manifest["tensors"][key], leaf, shd))
    return jax.tree_util.tree_unflatten(treedef, out), stats


def load_checkpoint(ckpt_dir: str, template, step: Optional[int] = None,
                    shardings=None, kernel_impl: Optional[str] = None,
                    verify: bool = True, quarantine: bool = True):
    """template: pytree with the target treedef (e.g. fresh init or
    eval_shape).  shardings: optional matching pytree of NamedSharding
    for elastic placement on the current mesh — reassembly then decodes
    jitted on-device with the new placement, moving the stored
    *containers* host->device rather than decoded arrays.  kernel_impl:
    dispatch policy for the cusz decode path (None = ambient/auto).

    ``verify`` (default on) checks every stored container payload
    against its header crc32.  ``quarantine`` (default on) makes
    corruption non-fatal: the damaged step dir gets a ``QUARANTINE.json``
    marker with a structured report, restore falls back to the newest
    older good step, and the per-step reports land in
    ``LAST_RESTORE_STATS["quarantine"]``.  With ``quarantine=False``
    corruption raises `CheckpointCorruptionError` immediately."""
    candidates = available_steps(ckpt_dir)
    if step is not None:
        candidates = [s for s in candidates if s <= step]
        if step not in candidates:
            candidates.append(step)      # explicit step: always tried first
    else:
        assert candidates, f"no checkpoints under {ckpt_dir}"
    reports: List[Dict[str, Any]] = []
    for s in sorted(set(candidates), reverse=True):
        d = os.path.join(ckpt_dir, f"step_{s:08d}")
        try:
            tree, stats = _load_step(d, s, template, shardings,
                                     kernel_impl, verify)
        except _CORRUPTION_ERRORS as e:
            report = {"step": int(s), "dir": d,
                      "error_type": type(e).__name__, "error": str(e)}
            reports.append(report)
            if not quarantine:
                LAST_RESTORE_STATS.clear()
                LAST_RESTORE_STATS.update({"quarantine": reports})
                raise CheckpointCorruptionError(
                    f"checkpoint step {s} under {ckpt_dir} is corrupted: "
                    f"{type(e).__name__}: {e}", reports) from e
            _mark_quarantined(d, report)
            continue
        if reports:
            stats["quarantine"] = reports
        LAST_RESTORE_STATS.clear()
        LAST_RESTORE_STATS.update(stats)
        return tree, s
    LAST_RESTORE_STATS.clear()
    LAST_RESTORE_STATS.update({"quarantine": reports})
    raise CheckpointCorruptionError(
        f"no loadable checkpoint under {ckpt_dir}: "
        f"{len(reports)} candidate step(s) all failed integrity checks "
        f"({[r['step'] for r in reports]})", reports)
