"""Checkpoint save/restore with the cuSZ codec on the write path.

Modes:
  'lossless' — raw arrays (npz)
  'cusz'     — float arrays >= CUSZ_MIN_SIZE go through the full cuSZ
               pipeline (dual-quant + canonical Huffman) at a value-range-
               relative error bound; everything else stays lossless.
               Manifest records eb + achieved ratio per tensor.

Restore is elastic: leaves are placed with whatever shardings the *new*
mesh prescribes (re-sharding on restore = the elastic-rescale path,
DESIGN.md §5).  Writes go through a temp dir + atomic rename, and an
optional background thread (async staging) so the step loop is not
blocked.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import compressor as CZ
from repro.core import weights as WZ

CUSZ_MIN_SIZE = 4096
_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree, mode: str = "lossless",
                    eb_valrel: float = 1e-5, background: bool = False,
                    kernel_impl: Optional[str] = None):
    """`kernel_impl` selects the compressor's kernel dispatch policy
    (None = ambient/auto); it flows through `CompressorConfig`."""
    if background:
        t = threading.Thread(target=save_checkpoint,
                             args=(ckpt_dir, step, tree, mode, eb_valrel,
                                   False, kernel_impl), daemon=True)
        t.start()
        return t
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "mode": mode, "tensors": {}}
    arrays: Dict[str, np.ndarray] = {}
    for key, arr in flat.items():
        entry: Dict[str, Any] = {"shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
        if (mode == "cusz" and arr.dtype == np.float32
                and arr.size >= CUSZ_MIN_SIZE and np.all(np.isfinite(arr))
                and float(np.max(arr) - np.min(arr)) > 0):
            cfg = WZ.checkpoint_codec_config(eb_valrel,
                                             kernel_impl=kernel_impl)
            blob, eb = CZ.compress(arr, cfg)
            packed = CZ.pack_blob(blob)
            # fall back to raw when the codec doesn't win (entropy-dense
            # tensors, e.g. random init at tight eb, would expand)
            if (int(blob.n_outliers) <= blob.out_idx.shape[0]
                    and CZ.packed_nbytes(packed) < arr.nbytes):
                entry.update(codec="cusz", eb=eb,
                             chunk_size=cfg.chunk_size,
                             ratio=arr.nbytes / CZ.packed_nbytes(packed))
                for f, v in packed.items():
                    arrays[f"{key}{_SEP}__cusz__{_SEP}{f}"] = np.asarray(v)
                manifest["tensors"][key] = entry
                continue
        entry["codec"] = "raw"
        arrays[key] = arr
        manifest["tensors"][key] = entry
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, template, step: Optional[int] = None,
                    shardings=None, kernel_impl: Optional[str] = None):
    """template: pytree with the target treedef (e.g. fresh init or
    eval_shape).  shardings: optional matching pytree of NamedSharding for
    elastic placement on the current mesh.  kernel_impl: dispatch policy
    for the decode path (None = ambient/auto)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    def restore_one(key, entry):
        if entry["codec"] == "cusz":
            prefix = f"{key}{_SEP}__cusz__{_SEP}"
            packed = {k[len(prefix):]: arrays[k] for k in arrays.files
                      if k.startswith(prefix)}
            blob = CZ.unpack_blob(packed)
            cfg = dataclasses.replace(
                WZ.checkpoint_codec_config(
                    kernel_impl=kernel_impl,
                    chunk_size=entry.get("chunk_size", 4096)),
                eb=1.0, eb_mode="abs")
            out = CZ.decompress(blob, cfg, entry["eb"],
                                tuple(entry["shape"]))
            return np.asarray(jax.device_get(out))
        return arrays[key]

    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_with_path))
    out = []
    for (path, leaf), shd in zip(leaves_with_path, shard_leaves):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = restore_one(key, manifest["tensors"][key]).astype(leaf.dtype)
        arr = arr.reshape(leaf.shape)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
