"""Checkpoint save/restore on the `repro.codecs` API.

Every leaf goes through a registered codec; which one is decided per
leaf by a single `CheckpointPolicy` (replacing the old `mode=` string +
`weights.checkpoint_codec_config` special case):

    policy = CheckpointPolicy(codec="cusz", eb_valrel=1e-5,
                              rules=(("opt", "int8"),))
    save_checkpoint(d, step, tree, policy=policy)

Per tensor, the manifest records the codec id, codec version and the
container header — so restore needs nothing from the caller: the
`Container` alone decodes (dtype/shape/eb all ride in the header; the
old code hardcoded restore dtypes and passed eb/shape out-of-band).
Lossy codecs that fail to beat raw bytes fall back to "lossless" per
tensor (the codec never expands a checkpoint).

Restore is elastic: leaves are placed with whatever shardings the *new*
mesh prescribes (re-sharding on restore = the elastic-rescale path,
DESIGN.md §5).  Writes go through a temp dir + atomic rename, and an
optional background thread (async staging) so the step loop is not
blocked.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro import codecs

CUSZ_MIN_SIZE = 4096
_SEP = "::"
_FIELD_MARK = "__c__"


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Per-leaf codec selection from one config.

    `codec` applies to every eligible float leaf; `rules` overrides by
    key substring (first match wins, value is a registry name — use
    "lossless" to exempt a subtree).  Ineligible leaves (non-float,
    small, non-finite, zero-range) always store lossless.
    """
    codec: str = "lossless"                      # codec for eligible leaves
    eb_valrel: float = 1e-5                      # cusz value-range-rel bound
    min_size: int = CUSZ_MIN_SIZE                # lossy-eligibility floor
    kernel_impl: Optional[str] = None            # cusz dispatch policy
    rules: Tuple[Tuple[str, str], ...] = ()      # (key substring, codec id)

    def codec_for(self, key: str, arr: np.ndarray) -> str:
        name = self.codec
        for sub, override in self.rules:
            if sub in key:
                name = override
                break
        if name == "lossless" or not self._eligible(arr):
            return "lossless"
        return name

    def make_codec(self, name: str) -> codecs.Codec:
        if name == "cusz":
            return codecs.get("cusz", eb=self.eb_valrel, eb_mode="valrel",
                              use_tpu_blocks=True,
                              kernel_impl=self.kernel_impl)
        return codecs.get(name)

    def _eligible(self, arr: np.ndarray) -> bool:
        try:
            floating = jax.numpy.issubdtype(arr.dtype, jax.numpy.floating)
        except TypeError:
            floating = False
        if not floating or arr.size < self.min_size:
            return False
        f = np.asarray(arr, np.float32) if arr.dtype != np.float32 else arr
        return bool(np.all(np.isfinite(f))
                    and float(np.max(f) - np.min(f)) > 0)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _legacy_policy(mode, eb_valrel, kernel_impl) -> CheckpointPolicy:
    warnings.warn(
        "save_checkpoint(mode=..., eb_valrel=..., kernel_impl=...) is "
        "deprecated; pass policy=CheckpointPolicy(codec=..., "
        "eb_valrel=..., kernel_impl=...) instead",
        DeprecationWarning, stacklevel=3)
    return CheckpointPolicy(
        codec="cusz" if mode == "cusz" else "lossless",
        eb_valrel=1e-5 if eb_valrel is None else eb_valrel,
        kernel_impl=kernel_impl)


def save_checkpoint(ckpt_dir: str, step: int, tree, mode: Optional[str] = None,
                    eb_valrel: Optional[float] = None,
                    background: bool = False,
                    kernel_impl: Optional[str] = None,
                    policy: Optional[CheckpointPolicy] = None):
    """Write `tree` under `ckpt_dir/step_<step>` via the codec registry.

    `policy` selects codecs per leaf; the legacy `mode=`/`eb_valrel=`/
    `kernel_impl=` kwargs still work behind a DeprecationWarning."""
    if policy is None:
        if mode is not None or eb_valrel is not None \
                or kernel_impl is not None:
            policy = _legacy_policy(mode, eb_valrel, kernel_impl)
        else:
            policy = CheckpointPolicy()
    if background:
        t = threading.Thread(target=save_checkpoint,
                             args=(ckpt_dir, step, tree),
                             kwargs={"policy": policy}, daemon=True)
        t.start()
        return t
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "format": 2,
                                "policy": policy.codec, "tensors": {}}
    arrays: Dict[str, np.ndarray] = {}
    codec_cache: Dict[str, codecs.Codec] = {}
    for key, arr in flat.items():
        name = policy.codec_for(key, arr)
        if name not in codec_cache:
            codec_cache[name] = policy.make_codec(name)
        packed, name = _encode_leaf(codec_cache, name, arr)
        header, fields = codecs.to_arrays(packed)
        for f, v in fields.items():
            arrays[f"{key}{_SEP}{_FIELD_MARK}{_SEP}{f}"] = v
        entry = {"codec": name, "version": packed.header.version,
                 "header": header}
        if name != "lossless":
            entry["ratio"] = arr.nbytes / max(1, packed.nbytes)
        manifest["tensors"][key] = entry
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _encode_leaf(codec_cache, name, arr):
    """encode+pack one leaf; lossy codecs that don't win (entropy-dense
    tensors, e.g. random init at tight eb, would expand) or can't
    represent the tensor (eb below f32 resolution, block-misaligned
    dims) fall back to raw."""
    if name != "lossless":
        try:
            codec = codec_cache[name]
            c = codec.encode(arr)
            if codec.valid(c):
                packed = codec.pack(c)
                if packed.nbytes < arr.nbytes:
                    return packed, name
        except (ValueError, AssertionError):
            pass
        name = "lossless"
        if name not in codec_cache:
            codec_cache[name] = codecs.get("lossless")
    return codec_cache[name].pack(codec_cache[name].encode(arr)), name


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, template, step: Optional[int] = None,
                    shardings=None, kernel_impl: Optional[str] = None):
    """template: pytree with the target treedef (e.g. fresh init or
    eval_shape).  shardings: optional matching pytree of NamedSharding for
    elastic placement on the current mesh.  kernel_impl: dispatch policy
    for the cusz decode path (None = ambient/auto)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    fmt = manifest.get("format", 1)
    if fmt != 2:
        raise ValueError(
            f"checkpoint {d} uses manifest format {fmt}; this reader "
            f"supports format 2 (per-tensor codec containers).  Format-1 "
            f"checkpoints predate the repro.codecs API — re-save from a "
            f"checkout that wrote them.")
    arrays = np.load(os.path.join(d, "arrays.npz"))

    def restore_one(key, entry):
        prefix = f"{key}{_SEP}{_FIELD_MARK}{_SEP}"
        fields = {k[len(prefix):]: arrays[k] for k in arrays.files
                  if k.startswith(prefix)}
        container = codecs.from_arrays(entry["header"], fields)
        kw = {"kernel_impl": kernel_impl} \
            if entry["codec"] == "cusz" and kernel_impl is not None else {}
        out = codecs.decode(container, **kw)
        return np.asarray(jax.device_get(out))

    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_with_path))
    out = []
    for (path, leaf), shd in zip(leaves_with_path, shard_leaves):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = restore_one(key, manifest["tensors"][key]).astype(leaf.dtype)
        arr = arr.reshape(leaf.shape)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
