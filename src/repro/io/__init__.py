from . import async_writer, checkpoint  # noqa: F401
